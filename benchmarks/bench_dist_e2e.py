"""End-to-end sharded eigensolve benchmark — the dist/core integration.

Emits machine-readable `results/BENCH_dist_e2e.json`
(`python benchmarks/bench_dist_e2e.py [--smoke] [--out PATH]`) tracking
the paper's headline pipeline: `core.eigsh` restarts driving the fused
shard_mapped SpMM+CGS2/CholQR2 step (`dist.DistOperator`) over a forced
multi-device host mesh. Three ladders:

  parity          nev eigenpairs of the same RMAT graph through the local
                  GraphOperator path and the sharded fused path; the JSON
                  carries both spectra, the max relative deviation, and
                  the rtol-1e-5 verdict (the acceptance bar).
  timings         wall seconds for both paths + fused-expansion count.
                  (On a forced-host CPU mesh the sharded path pays real
                  collective overhead for fake parallelism — the number
                  is a regression canary, not a speedup claim.)
  pod_compressed  the int8 cross-pod reduction variant run for a fixed
                  restart budget, recording the per-restart eigenvalue
                  deviation (by |λ| — near-±pairs make the smallest kept
                  magnitude's sign an arbitrary tie) — the ROADMAP's
                  "measure error accumulation over full Krylov
                  iterations" number.

The emitted JSON is self-validated (`validate`): a run that cannot
produce the parity/eigenvalue fields exits non-zero, which is what the
`scripts/run_tier1.sh` smoke hook relies on.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.hostdev import force_host_devices


REQUIRED_FIELDS = (
    ("parity", "max_rel_err"),
    ("parity", "rtol_1e5_ok"),
    ("eigenvalues", "local"),
    ("eigenvalues", "dist"),
    ("pod_compressed", "per_restart_abs_dev"),
    ("pod_compressed", "final_abs_dev"),
    ("timings", "local_s"),
    ("timings", "dist_s"),
)


def validate(metrics: dict) -> None:
    """Raise if the JSON is missing the parity/eigenvalue contract —
    run_tier1.sh treats that as a tier-1 failure."""
    for sect, key in REQUIRED_FIELDS:
        if sect not in metrics or key not in metrics[sect]:
            raise ValueError(f"BENCH_dist_e2e missing field {sect}.{key}")
    if not metrics["parity"]["rtol_1e5_ok"]:
        raise ValueError(
            f"dist-vs-local spectrum parity failed: max_rel_err="
            f"{metrics['parity']['max_rel_err']:.3e} (bar: rtol 1e-5)")
    if metrics["smoke"] and not (metrics["parity"]["local_converged"]
                                 and metrics["parity"]["dist_converged"]):
        # parity alone cannot tell "both converged to the same spectrum"
        # from "both diverge identically" — the smoke sizes are chosen to
        # converge at tol 1e-7, so the tier-1 gate demands it. (The full
        # sizes legitimately exhaust max_restarts before 1e-7 and only
        # record their flags.)
        raise ValueError("smoke-sized solves must converge: "
                         f"local={metrics['parity']['local_converged']} "
                         f"dist={metrics['parity']['dist_converged']}")


def collect(*, smoke: bool = False) -> dict:
    import jax
    import numpy as np
    from repro.core import GraphOperator, eigsh
    from repro.dist import DistOperator
    from repro.graphs import pack_tiles, rmat_spectral

    n, nnz, nev, bs = (1500, 15000, 4, 2) if smoke else (6000, 72000, 8, 4)
    out: dict = {"schema": "bench_dist_e2e/v1", "smoke": smoke,
                 "graph": {"n": n, "nnz": nnz, "nev": nev,
                           "block_size": bs, "seed": 1},
                 "devices": len(jax.devices())}
    r, c, v = rmat_spectral(n, nnz, seed=1)

    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    t0 = time.perf_counter()
    local = eigsh(GraphOperator(tm, impl="ref"), nev, block_size=bs,
                  tol=1e-7, max_restarts=100, impl="ref")
    t_local = time.perf_counter() - t0
    w_local = np.sort(local.eigenvalues)

    from repro.dist import e2e_mesh
    dop = DistOperator(n, r, c, v, mesh=e2e_mesh())
    t0 = time.perf_counter()
    dist = eigsh(dop, nev, block_size=bs, tol=1e-7, max_restarts=100,
                 impl="ref")
    t_dist = time.perf_counter() - t0
    w_dist = np.sort(dist.eigenvalues)

    # per-element relative error — the same bar assert_allclose(rtol=1e-5)
    # applies in the example/tests (normalizing by the spectral radius
    # would let a small kept eigenvalue regress unnoticed)
    rel = float(np.max(np.abs(w_dist - w_local)
                       / np.maximum(np.abs(w_local), 1e-30)))
    out["eigenvalues"] = {"local": [float(x) for x in w_local],
                          "dist": [float(x) for x in w_dist]}
    out["parity"] = {"max_rel_err": rel, "rtol_1e5_ok": bool(rel <= 1e-5),
                     "local_converged": bool(local.converged),
                     "dist_converged": bool(dist.converged)}
    out["timings"] = {"local_s": t_local, "dist_s": t_dist,
                      "fused_expansions": dop.n_fused_steps,
                      "local_restarts": int(local.n_restarts),
                      "dist_restarts": int(dist.n_restarts)}

    # --- pod_compressed error accumulation over full restart cycles ----
    from repro.dist import pod_compressed_deviation
    devs = pod_compressed_deviation(n, r, c, v, w_local, mesh=dop.mesh,
                                    nev=nev, block_size=bs,
                                    max_restarts=3 if smoke else 6)
    out["pod_compressed"] = {
        "per_restart_abs_dev": devs,
        "final_abs_dev": devs[-1] if devs else None,
        "restarts_measured": len(devs),
        # accumulation verdict: the deviation must settle, not grow, over
        # full restart cycles (last <= 2x the best seen after restart 0)
        "accumulates": bool(len(devs) >= 2
                            and devs[-1] > 2.0 * min(devs[1:]) + 1e-12),
    }
    return out


def run(csv_rows: list):
    """Harness entry (`benchmarks/run.py dist_e2e`): CSV rows off
    collect(). Single-process: uses however many devices exist (a 1-device
    harness run still exercises the full fused path on a (1,1,1) mesh)."""
    m = collect(smoke=True)
    csv_rows.append(("dist_e2e", f"n={m['graph']['n']},local",
                     m["timings"]["local_s"] * 1e6,
                     f"restarts={m['timings']['local_restarts']}"))
    csv_rows.append(("dist_e2e", f"n={m['graph']['n']},dist",
                     m["timings"]["dist_s"] * 1e6,
                     f"max_rel_err={m['parity']['max_rel_err']:.2e}"))
    csv_rows.append(("dist_e2e", "pod_compressed", 0.0,
                     f"final_abs_dev="
                     f"{m['pod_compressed']['final_abs_dev']:.2e}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down sizes (tier-1 trajectory tracking)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results", "BENCH_dist_e2e.json"))
    args = ap.parse_args()
    force_host_devices(args.devices)
    metrics = collect(smoke=args.smoke)
    validate(metrics)
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(metrics, f, indent=2)
    p = metrics["parity"]
    print(f"wrote {args.out}")
    print(f"parity: max_rel_err={p['max_rel_err']:.3e} "
          f"(rtol 1e-5 ok: {p['rtol_1e5_ok']})")
    pc = metrics["pod_compressed"]
    print(f"pod_compressed |λ| deviation per restart: "
          f"{['%.2e' % x for x in pc['per_restart_abs_dev']]} "
          f"(accumulates: {pc['accumulates']})")
    t = metrics["timings"]
    print(f"local {t['local_s']:.1f}s vs dist {t['dist_s']:.1f}s "
          f"({t['fused_expansions']} fused expansions)")
    if pc["accumulates"]:
        print("WARNING: pod-compressed deviation grew over restart cycles",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
