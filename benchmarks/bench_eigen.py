"""Paper Fig. 12 + Table 3 — end-to-end eigensolver — plus the solver
family head-to-head (`--smoke` / tier-1 gate).

Fig. 12: SEM (tiered, budgeted device memory) vs IM (everything in the fast
tier) Krylov–Schur runtime ratio for several #eigenvalues — the paper's
40–60 % claim. On CPU both variants run the same FLOPs; the SEM runtime is
modeled as compute + tier traffic at the paper's measured tier bandwidth,
with the traffic taken from the byte-exact TieredStore accounting.

Table 3: resource consumption of the scaled page-graph analogue: runtime,
device-memory high-water mark, tier reads, tier writes + the write/read
ratio (paper: 145 TB read, 4 TB written, 120 GB RAM, 4.2 h).

Solver family (`main()` → results/BENCH_solver_family.json): the paper's
§2 argument for Krylov–Schur is that it converges with the least I/O.
With both KS and LOBPCG behind `core.solver.solve` on the same safs-backed
TieredStore, that claim is now a measurement: bytes streamed from the file
backend per converged eigenpair, per method, with streamed-pass accounting
(`IOStats.passes` / `pass_bytes_read`) and physical backend bytes side by
side. `validate()` gates spectrum parity between the two methods and
between LOBPCG's safs and RAM paths.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import GraphOperator, TieredStore, eigsh, solve, svds
from repro.graphs import clustered_web_graph, normalized_adjacency, \
    pack_tiles, rmat_graph

SLOW_TIER_BW = 10.9e9


def _family_op(n: int, nnz: int, store: TieredStore) -> GraphOperator:
    r, c, v = rmat_graph(n, nnz, seed=7, symmetric=True)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64), min_block_nnz=4)
    return GraphOperator(tm, store=store, impl="ref")


def _run_method(method: str, n: int, nnz: int, nev: int, tol: float,
                store: TieredStore, **kw) -> tuple:
    op = _family_op(n, nnz, store)
    store.reset_stats()
    t0 = time.perf_counter()
    res = solve(op, nev, method=method, which="LA", tol=tol, store=store,
                impl="ref", **kw)
    us = (time.perf_counter() - t0) * 1e6
    return res, us


def _solver_family(root: str, n: int, nnz: int, nev: int, tol: float) -> dict:
    """KS vs LOBPCG on the same safs-backed graph: bytes per converged
    eigenpair (logical tier traffic / nev), streamed-pass accounting and
    spectrum parity. Plus a RAM-backend LOBPCG reference for the
    safs-vs-RAM parity gate."""
    out: dict = {"n": n, "nnz": nnz, "nev": nev, "tol": tol,
                 "backend": "safs"}
    evs = {}
    methods = (("krylov_schur", dict(block_size=4, max_iters=100)),
               ("lobpcg", dict(block_size=2 * nev, max_iters=300)))
    for method, kw in methods:
        # budget/cache sized well below the working set (KS: m·n·4 ≈
        # 4·nev·n·4; LOBPCG: 6 blocks of 2·nev cols) so blocks really
        # demote and the file backend sees physical traffic.
        store = TieredStore(
            device_budget_bytes=2 * n * 4 * 4, backend="safs",
            backend_opts={"root": os.path.join(root, method),
                          "cache_bytes": 2 * n * 4 * 4})
        res, us = _run_method(method, n, nnz, nev, tol, store, **kw)
        s = store.stats
        logical = s.host_bytes_read + s.host_bytes_written
        evs[method] = np.sort(np.asarray(res.eigenvalues, np.float64))
        out[method] = {
            "us": us,
            "converged": bool(res.converged),
            "iters": int(res.n_restarts),
            "n_ops": int(res.n_ops),
            "workset_cols": int(res.m_subspace),
            "eigenvalues": [float(x) for x in evs[method]],
            "host_bytes_read": int(s.host_bytes_read),
            "host_bytes_written": int(s.host_bytes_written),
            "passes": int(s.passes),
            "pass_bytes_read": int(s.pass_bytes_read),
            "physical_bytes_read": int(store.backend.stats.host_bytes_read),
            "bytes_per_converged_pair": float(logical / nev),
        }
        store.close()
    out["spectrum_max_rel_err"] = float(np.max(
        np.abs(evs["krylov_schur"] - evs["lobpcg"])
        / np.maximum(np.abs(evs["krylov_schur"]), 1e-12)))
    out["lobpcg_bytes_over_ks"] = (
        out["lobpcg"]["bytes_per_converged_pair"]
        / max(out["krylov_schur"]["bytes_per_converged_pair"], 1.0))

    # RAM-path LOBPCG reference: the safs run must reproduce its spectrum
    # (the acceptance gate for the out-of-core rewrite).
    st_ram = TieredStore(device_budget_bytes=4 * n * 4 * max(nev, 4))
    res_ram, _ = _run_method("lobpcg", n, nnz, nev, tol, st_ram,
                             block_size=2 * nev, max_iters=300)
    ev_ram = np.sort(np.asarray(res_ram.eigenvalues, np.float64))
    out["lobpcg_ram_converged"] = bool(res_ram.converged)
    out["lobpcg_safs_vs_ram_rel_err"] = float(np.max(
        np.abs(evs["lobpcg"] - ev_ram) / np.maximum(np.abs(ev_ram), 1e-12)))
    return out


def collect(*, smoke: bool = False) -> dict:
    n, nnz, nev = (1200, 10000, 4) if smoke else (6000, 72000, 8)
    out: dict = {"schema": "bench_solver_family/v1", "smoke": smoke}
    root = tempfile.mkdtemp(prefix="bench_family_")
    try:
        out["family"] = _solver_family(root, n, nnz, nev, tol=1e-6)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def validate(metrics: dict) -> None:
    """Tier-1 gate: raises AssertionError on a regression."""
    assert "family" in metrics, "BENCH_solver_family.json missing 'family'"
    fam = metrics["family"]
    for method in ("krylov_schur", "lobpcg"):
        m = fam.get(method)
        assert m, f"family comparison missing {method!r}"
        for k in ("converged", "passes", "pass_bytes_read",
                  "host_bytes_read", "physical_bytes_read",
                  "bytes_per_converged_pair", "eigenvalues"):
            assert k in m, f"{method} missing field {k!r}"
        assert m["converged"], f"{method} did not converge: {m}"
        # real streamed-pass accounting, not placeholders: every solve on
        # the safs backend must stream the subspace (passes) and touch the
        # file backend (physical bytes).
        assert m["passes"] > 0, (method, m["passes"])
        assert m["pass_bytes_read"] > 0, (method, m["pass_bytes_read"])
        assert m["physical_bytes_read"] > 0, (method,
                                              m["physical_bytes_read"])
        assert m["bytes_per_converged_pair"] > 0, m
    assert fam["spectrum_max_rel_err"] <= 1e-4, (
        f"KS / LOBPCG spectra diverged: {fam['spectrum_max_rel_err']:.3e}")
    assert fam["lobpcg_ram_converged"], "RAM-path LOBPCG did not converge"
    assert fam["lobpcg_safs_vs_ram_rel_err"] <= 1e-5, (
        f"LOBPCG safs vs RAM spectra diverged: "
        f"{fam['lobpcg_safs_vs_ram_rel_err']:.3e}")


def run(csv_rows: list):
    n, nnz = 20000, 240000
    r, c, v = rmat_graph(n, nnz, seed=3, symmetric=True)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64), min_block_nnz=4)

    # --- Fig 12: SEM vs IM for several ev counts
    for nev in (4, 8, 16):
        store = TieredStore()
        op = GraphOperator(tm, store=store, impl="ref")
        t0 = time.perf_counter()
        res = eigsh(op, nev, block_size=4, tol=1e-6, max_restarts=100,
                    store=store, impl="ref")
        t_compute = time.perf_counter() - t0
        s = store.stats
        io = s.host_bytes_read + s.host_bytes_written
        t_sem = t_compute + io / SLOW_TIER_BW
        ratio = t_compute / t_sem
        csv_rows.append(("fig12_eigensolver", f"nev={nev}",
                         t_sem * 1e6,
                         f"sem_over_im={ratio:.2f},converged={res.converged},"
                         f"restarts={res.n_restarts}"))

    # --- §2-related-work comparison: Krylov–Schur vs LOBPCG I/O
    #     (the paper picks KS for least I/O; LOBPCG [31] trades a tiny
    #     working set for more operator applications)
    from repro.core.lobpcg import lobpcg
    st_lo = TieredStore()
    t0 = time.perf_counter()
    res_lo = lobpcg(GraphOperator(tm, store=st_lo, impl="ref"), 4,
                    block_size=8, tol=1e-4, max_iters=150, which="LA",
                    store=st_lo)
    t_lo = time.perf_counter() - t0
    csv_rows.append(("related_lobpcg_vs_ks", "nev=4", t_lo * 1e6,
                     f"ops={res_lo.n_ops},workset_cols={res_lo.m_subspace},"
                     f"converged={res_lo.converged}"))

    # --- Table 3: scaled page-graph analogue (directed → SVD)
    np_, nnzp = 34000, 1290000          # 1e5× scaled page graph
    r, c, v = clustered_web_graph(np_, nnzp, seed=4)
    tma = pack_tiles(np_, np_, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    tmat = pack_tiles(np_, np_, c, r, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore(device_budget_bytes=64 << 20)
    t0 = time.perf_counter()
    res = svds(GraphOperator(tma, store=store, impl="ref"),
               GraphOperator(tmat, store=store, impl="ref"),
               8, block_size=2, tol=1e-6, max_restarts=60,
               store=store, impl="ref")
    wall = time.perf_counter() - t0
    s = store.stats
    csv_rows.append(("table3_page_scaled", "nev=8", wall * 1e6,
                     f"read_bytes={s.host_bytes_read},"
                     f"write_bytes={s.host_bytes_written},"
                     f"write_read_ratio={s.host_bytes_written / max(s.host_bytes_read, 1):.4f},"
                     f"device_hwm_bytes={store.device_bytes()},"
                     f"converged={res.converged}"))

    # --- solver family head-to-head (smoke sizes; full run via `main()`)
    fam = collect(smoke=True)["family"]
    csv_rows.append((
        "solver_family", f"nev={fam['nev']}", fam["lobpcg"]["us"],
        f"bytes_per_pair_ks={fam['krylov_schur']['bytes_per_converged_pair']:.0f},"
        f"bytes_per_pair_lobpcg={fam['lobpcg']['bytes_per_converged_pair']:.0f},"
        f"spectrum_rel_err={fam['spectrum_max_rel_err']:.1e}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down sizes (tier-1 trajectory tracking)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results", "BENCH_solver_family.json"))
    args = ap.parse_args()
    metrics = collect(smoke=args.smoke)
    validate(metrics)
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(metrics, f, indent=2)
    fam = metrics["family"]
    ks, lo = fam["krylov_schur"], fam["lobpcg"]
    print(f"wrote {args.out}")
    print(f"solver family (n={fam['n']}, nev={fam['nev']}, safs):")
    for tag, m in (("krylov_schur", ks), ("lobpcg", lo)):
        print(f"  {tag:13s} iters={m['iters']:4d} ops={m['n_ops']:4d} "
              f"passes={m['passes']:5d} "
              f"bytes/pair={m['bytes_per_converged_pair']/1e6:8.2f} MB "
              f"(physical read {m['physical_bytes_read']/1e6:.1f} MB)")
    print(f"  lobpcg/ks bytes-per-pair ratio: "
          f"{fam['lobpcg_bytes_over_ks']:.2f}")
    print(f"  spectrum parity ks-vs-lobpcg {fam['spectrum_max_rel_err']:.1e}"
          f", lobpcg safs-vs-ram {fam['lobpcg_safs_vs_ram_rel_err']:.1e}")


if __name__ == "__main__":
    main()
