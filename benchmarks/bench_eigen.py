"""Paper Fig. 12 + Table 3 — end-to-end eigensolver.

Fig. 12: SEM (tiered, budgeted device memory) vs IM (everything in the fast
tier) Krylov–Schur runtime ratio for several #eigenvalues — the paper's
40–60 % claim. On CPU both variants run the same FLOPs; the SEM runtime is
modeled as compute + tier traffic at the paper's measured tier bandwidth,
with the traffic taken from the byte-exact TieredStore accounting.

Table 3: resource consumption of the scaled page-graph analogue: runtime,
device-memory high-water mark, tier reads, tier writes + the write/read
ratio (paper: 145 TB read, 4 TB written, 120 GB RAM, 4.2 h).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import GraphOperator, TieredStore, eigsh, svds
from repro.graphs import clustered_web_graph, normalized_adjacency, \
    pack_tiles, rmat_graph

SLOW_TIER_BW = 10.9e9


def run(csv_rows: list):
    n, nnz = 20000, 240000
    r, c, v = rmat_graph(n, nnz, seed=3, symmetric=True)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64), min_block_nnz=4)

    # --- Fig 12: SEM vs IM for several ev counts
    for nev in (4, 8, 16):
        store = TieredStore()
        op = GraphOperator(tm, store=store, impl="ref")
        t0 = time.perf_counter()
        res = eigsh(op, nev, block_size=4, tol=1e-6, max_restarts=100,
                    store=store, impl="ref")
        t_compute = time.perf_counter() - t0
        s = store.stats
        io = s.host_bytes_read + s.host_bytes_written
        t_sem = t_compute + io / SLOW_TIER_BW
        ratio = t_compute / t_sem
        csv_rows.append(("fig12_eigensolver", f"nev={nev}",
                         t_sem * 1e6,
                         f"sem_over_im={ratio:.2f},converged={res.converged},"
                         f"restarts={res.n_restarts}"))

    # --- §2-related-work comparison: Krylov–Schur vs LOBPCG I/O
    #     (the paper picks KS for least I/O; LOBPCG [31] trades a tiny
    #     working set for more operator applications)
    from repro.core.lobpcg import lobpcg
    st_lo = TieredStore()
    t0 = time.perf_counter()
    res_lo = lobpcg(GraphOperator(tm, store=st_lo, impl="ref"), 4,
                    block_size=8, tol=1e-4, max_iters=150, which="LA",
                    store=st_lo)
    t_lo = time.perf_counter() - t0
    csv_rows.append(("related_lobpcg_vs_ks", "nev=4", t_lo * 1e6,
                     f"ops={res_lo.n_ops},workset_cols={res_lo.m_subspace},"
                     f"converged={res_lo.converged}"))

    # --- Table 3: scaled page-graph analogue (directed → SVD)
    np_, nnzp = 34000, 1290000          # 1e5× scaled page graph
    r, c, v = clustered_web_graph(np_, nnzp, seed=4)
    tma = pack_tiles(np_, np_, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    tmat = pack_tiles(np_, np_, c, r, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore(device_budget_bytes=64 << 20)
    t0 = time.perf_counter()
    res = svds(GraphOperator(tma, store=store, impl="ref"),
               GraphOperator(tmat, store=store, impl="ref"),
               8, block_size=2, tol=1e-6, max_restarts=60,
               store=store, impl="ref")
    wall = time.perf_counter() - t0
    s = store.stats
    csv_rows.append(("table3_page_scaled", "nev=8", wall * 1e6,
                     f"read_bytes={s.host_bytes_read},"
                     f"write_bytes={s.host_bytes_written},"
                     f"write_read_ratio={s.host_bytes_written / max(s.host_bytes_read, 1):.4f},"
                     f"device_hwm_bytes={store.device_bytes()},"
                     f"converged={res.converged}"))
    return csv_rows
