"""§Roofline — reads results/dryrun.jsonl (produced by launch.dryrun) and
emits one row per (arch × shape × mesh) with the three roofline terms."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun.jsonl")


def run(csv_rows: list):
    if not os.path.exists(RESULTS):
        csv_rows.append(("roofline", "missing", 0.0,
                         "run: python -m repro.launch.dryrun --all"))
        return csv_rows
    with open(RESULTS) as f:
        for line in f:
            r = json.loads(line)
            if "error" in r:
                csv_rows.append((f"roofline_{r['mesh']}",
                                 f"{r['arch']}/{r['shape']}", 0.0,
                                 f"ERROR={r['error'][:60]}"))
                continue
            csv_rows.append((
                f"roofline_{r['mesh']}", f"{r['arch']}/{r['shape']}",
                r["step_time_bound_s"] * 1e6,
                f"compute_s={r['compute_s']:.3e},"
                f"memory_s={r['memory_s']:.3e},"
                f"collective_s={r['collective_s']:.3e},"
                f"dominant={r['dominant']},"
                f"roofline_frac={r['roofline_fraction']:.4f},"
                f"useful_ratio={r['useful_ratio']:.3f}"))
    return csv_rows
