"""SAFS page store — Table 3 / §3.4.2 measurements on the file backend.

Four ladders on real page files, emitted two ways: the harness CSV
(`benchmarks/run.py safs`) and a machine-readable `BENCH_safs.json`
(`python benchmarks/bench_safs.py [--smoke] [--out PATH]`) that tracks
the I/O-path perf trajectory from PR 3 onward:

  read_throughput  pages/s at 4 KiB and 64 KiB page size, three ways:
                   the PR-2 *legacy* path (one python pread per page),
                   the *batched* vectored engine (coalesced preadv runs),
                   and the batched engine driven by the multi-worker
                   readahead pool. The acceptance bar is batched ≥ 2x
                   legacy at 4 KiB — the grain where the python syscall
                   loop was the bottleneck (ROADMAP follow-up, now fixed).
  safs_stream      MvTimesMatAddMv with the subspace on disk, prefetch
                   OFF vs ON — the §3.4.2 claim that overlapping page
                   reads with compute recovers most of the in-memory
                   rate; reports the overlap fraction (busy time hidden
                   behind compute / total busy).
  safs_endurance   physical disk writes vs logical tier writes during an
                   append+restart-compress cycle — write-back + pinning
                   keep the medium's write traffic at or below logical
                   (Table 3 endurance argument); also reports the
                   write-behind queue's high-water depth.
  safs_cache       page-cache hit rate for the reorthogonalization
                   re-read pattern (most-recent-block pinning, §3.4.4):
                   the CGS2 append→4×re-scan cycle run twice, once with
                   the pin lifecycle engaged and once with the cache
                   degraded to plain LRU (`pin_pages=False`). The pinned
                   rate must sit well above the LRU-only baseline — a
                   sequential scan larger than the cache is exactly LRU's
                   pathological flood, and the pin is what keeps the
                   newest on-disk block (the one re-read four times per
                   expansion) resident through it.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MultiVector, TieredStore
from repro.safs.pagefile import PageFile
from repro.safs.prefetch import Prefetcher


def _mk(store, n, m, b, group_size=2):
    rng = np.random.default_rng(0)
    mv = MultiVector(store, n, group_size=group_size, impl="ref")
    for _ in range(m // b):
        mv.append_block(jnp.asarray(rng.standard_normal((n, b)), jnp.float32))
    return mv


def _safs_store(root, n, b, *, enable_prefetch, page_size=4096,
                pin_pages=True):
    # cache holds ~3 blocks of a >8-block subspace: genuinely streaming.
    # 4 KiB pages are affordable now that reads go through coalesced
    # preadv runs instead of a python per-page loop (see read_throughput).
    return TieredStore(
        device_budget_bytes=2 * n * 4 * b, backend="safs",
        backend_opts={"root": root, "cache_bytes": 3 * n * 4 * b,
                      "page_size": page_size,
                      "enable_prefetch": enable_prefetch,
                      "pin_pages": pin_pages})


# ------------------------------------------------------------ throughput
def _read_throughput(root, page_size, *, nfiles, file_kb):
    """pages/s for the legacy per-page pread loop vs the batched vectored
    engine vs the readahead pool, over freshly written page files."""
    os.makedirs(root, exist_ok=True)
    paths = []
    for f in range(nfiles):
        arr = np.random.default_rng(f).standard_normal(
            file_kb * 256).astype(np.float32)          # file_kb KiB of data
        pf = PageFile(os.path.join(root, f"t{f}.pages"),
                      page_size=page_size, shape=arr.shape, dtype="float32")
        pf.write_pages(pf.split(arr))
        pf.close()
        paths.append(os.path.join(root, f"t{f}.pages"))
    pfs = [PageFile(p) for p in paths]
    n_pages = sum(pf.n_pages for pf in pfs)

    def best_of(fn, repeats=3):
        # this box's scheduling jitter swings raw rates several-fold;
        # best-of-N is the standard throughput answer
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    def legacy():                        # the PR-2 path: python pread/page
        for pf in pfs:
            for i in pf.page_indices():
                pf.read_page(i)

    def batched():                       # coalesced vectored runs
        for pf in pfs:
            pf.read_pages_batch(range(pf.n_pages))

    t_legacy = best_of(legacy)
    t_batched = best_of(batched)

    by_name = {p: pf for p, pf in zip(paths, pfs)}
    pool = Prefetcher(
        lambda p: sum(len(d) for d in
                      by_name[p].read_pages_batch(
                          range(by_name[p].n_pages)).values()),
        io_workers=4, depth=nfiles)

    def pooled():
        pool.schedule(paths)
        pool.drain()

    pooled()                             # warm the worker threads
    t_pool = best_of(pooled)
    pool.close()
    for pf in pfs:
        pf.delete()

    return {
        "page_size": page_size,
        "n_pages": n_pages,
        "legacy_pages_per_s": n_pages / max(t_legacy, 1e-9),
        "batched_pages_per_s": n_pages / max(t_batched, 1e-9),
        "readahead_pool_pages_per_s": n_pages / max(t_pool, 1e-9),
        "speedup_batched_vs_legacy": t_legacy / max(t_batched, 1e-9),
        "speedup_pool_vs_legacy": t_legacy / max(t_pool, 1e-9),
    }


def _scrub_cost(root, *, nfiles, file_kb):
    """verify-on-read overhead (batched reads, CRC on vs off) and full
    scrub-pass throughput over a freshly written store."""
    from repro.safs import Scrubber, SafsBackend
    os.makedirs(root, exist_ok=True)
    for f in range(nfiles):
        arr = np.random.default_rng(100 + f).standard_normal(
            file_kb * 256).astype(np.float32)
        pf = PageFile(os.path.join(root, f"s{f}.pages"),
                      shape=arr.shape, dtype="float32")
        pf.write_pages(pf.split(arr))
        pf.close()
    paths = [os.path.join(root, f"s{f}.pages") for f in range(nfiles)]

    def read_all(verify):
        pfs = [PageFile(p, verify=verify) for p in paths]
        t0 = time.perf_counter()
        for pf in pfs:
            pf.read_pages_batch(range(pf.n_pages))
        dt = time.perf_counter() - t0
        n = sum(pf.n_pages for pf in pfs)
        for pf in pfs:
            pf.close()
        return n, dt

    n_pages, t_raw = read_all(False)
    _, t_verified = read_all(True)

    backend = SafsBackend(root, enable_prefetch=True, write_behind=False)
    scrub = Scrubber(backend, use_pool=True)
    summary = scrub.run_once()
    backend.close()
    return {
        "n_pages": n_pages,
        "read_pages_per_s_raw": n_pages / max(t_raw, 1e-9),
        "read_pages_per_s_verified": n_pages / max(t_verified, 1e-9),
        "verify_overhead": t_verified / max(t_raw, 1e-9) - 1.0,
        "scrub_pages_per_s": summary["pages"] / max(summary["seconds"],
                                                    1e-9),
    }


# ------------------------------------------------------------- ladders
def collect(*, smoke: bool = False) -> dict:
    """Run every ladder; returns the BENCH_safs.json metrics dict."""
    n, b, m = (12000, 4, 32) if smoke else (60000, 4, 64)
    nfiles, file_kb = (4, 512) if smoke else (8, 2048)
    out: dict = {"schema": "bench_safs/v1", "smoke": smoke}
    root = tempfile.mkdtemp(prefix="bench_safs_")
    try:
        out["read_throughput"] = {
            str(ps): _read_throughput(os.path.join(root, f"rt{ps}"), ps,
                                      nfiles=nfiles, file_kb=file_kb)
            for ps in (4096, 65536)}

        stream = {}
        for tag, pref in (("prefetch_off", False), ("prefetch_on", True)):
            store = _safs_store(os.path.join(root, tag), n, b,
                                enable_prefetch=pref)
            mv = _mk(store, n, m, b)
            small = jnp.asarray(np.random.default_rng(1)
                                .standard_normal((m, b)), jnp.float32)
            store.flush()
            store.reset_stats()
            t0 = time.perf_counter()
            mv.mv_times_mat(small)
            if pref:
                store.backend.prefetcher.drain()
            stream[tag] = {"us": (time.perf_counter() - t0) * 1e6}
            pf = store.backend.stats_dict()["prefetch"]
            stream[tag].update(
                overlap_seconds=pf["overlap_seconds"],
                busy_seconds=pf["busy_seconds"],
                overlap_fraction=(pf["overlap_seconds"]
                                  / max(pf["busy_seconds"], 1e-9)))
            store.close()
        out["safs_stream"] = stream

        # endurance: logical vs physical writes over append + compress
        store = _safs_store(os.path.join(root, "endurance"), n, b,
                            enable_prefetch=True)
        mv = _mk(store, n, m, b)
        q = jnp.asarray(np.random.default_rng(2)
                        .standard_normal((m, m // 2)), jnp.float32)
        t0 = time.perf_counter()
        mv.compress(q, [b] * (m // 2 // b))
        us = (time.perf_counter() - t0) * 1e6
        store.flush()
        snap = store.backend.stats_dict()   # cache+prefetch+wb in one call
        out["safs_endurance"] = {
            "us": us,
            "logical_bytes_written": store.stats.host_bytes_written,
            "physical_bytes_written": snap["io"]["host_bytes_written"],
            "disk_over_logical_writes":
                (snap["io"]["host_bytes_written"]
                 / max(store.stats.host_bytes_written, 1)),
            "write_behind": snap["write_behind"],
        }

        # endurance store's own lookup mix (compress pass; LRU-dominated —
        # pinning cannot help a pattern that never re-reads its newest
        # block, which is why the pre-fix bench sat at 0.017 here)
        compress_rate = snap["io"]["hit_rate"]
        store.close()

        # reorth re-read pattern (§3.4.4): per expansion the newest block
        # is appended (demoting its predecessor to disk) and the whole
        # subspace is re-scanned four times by the CGS2 passes — the
        # just-demoted block is the only one LRU is guaranteed to flood
        # out right before it is needed. Measured with the pin lifecycle
        # engaged vs the cache degraded to plain LRU.
        def reorth_hit_rate(tag, pin_pages):
            store = _safs_store(os.path.join(root, tag), n, b,
                                enable_prefetch=False, pin_pages=pin_pages)
            rng = np.random.default_rng(3)
            mv = MultiVector(store, n, group_size=2, impl="ref")
            for _ in range(m // b):
                mv.append_block(jnp.asarray(
                    rng.standard_normal((n, b)), jnp.float32))
                w = jnp.asarray(rng.standard_normal((n, b)), jnp.float32)
                hc = mv.mv_trans_mv(w)
                w = w - mv.mv_times_mat(hc)
                h2 = mv.mv_trans_mv(w)
                w = w - mv.mv_times_mat(h2)
            rate = store.backend.stats_dict()["io"]["hit_rate"]
            store.close()
            return rate

        pinned = reorth_hit_rate("cache_pinned", True)
        lru_only = reorth_hit_rate("cache_lru", False)
        out["safs_cache"] = {
            "page_hit_rate": pinned,
            "lru_only_hit_rate": lru_only,
            "pinned_over_lru": pinned / max(lru_only, 1e-9),
            "compress_pass_hit_rate": compress_rate,
        }

        # integrity tax (PR 10): what verify-on-read costs the batched
        # engine, and how fast a full scrub pass covers the store — the
        # number that sets a sane Scrubber pace for a given device.
        out["safs_integrity"] = _scrub_cost(
            os.path.join(root, "integrity"), nfiles=nfiles,
            file_kb=file_kb)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def run(csv_rows: list):
    """Harness entry (`benchmarks/run.py safs`): CSV rows off collect()."""
    m = collect()
    for ps, r in m["read_throughput"].items():
        csv_rows.append((
            "safs_read", f"page={ps}",
            1e6 * r["n_pages"] / r["batched_pages_per_s"],
            f"batched_over_legacy={r['speedup_batched_vs_legacy']:.2f}"))
    for tag, r in m["safs_stream"].items():
        csv_rows.append(("safs_stream", f"m=64,{tag}", r["us"],
                         f"overlap_s={r['overlap_seconds']:.4f}"))
    e = m["safs_endurance"]
    csv_rows.append(("safs_endurance", "m=64", e["us"],
                     f"disk_over_logical_writes="
                     f"{e['disk_over_logical_writes']:.2f}"))
    csv_rows.append(("safs_cache", "m=64", 0.0,
                     f"page_hit_rate={m['safs_cache']['page_hit_rate']:.2f},"
                     f"lru_only={m['safs_cache']['lru_only_hit_rate']:.2f}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down sizes (tier-1 trajectory tracking)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results", "BENCH_safs.json"))
    args = ap.parse_args()
    metrics = collect(smoke=args.smoke)
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(metrics, f, indent=2)
    r4 = metrics["read_throughput"]["4096"]
    print(f"wrote {args.out}")
    print(f"4 KiB pages: legacy {r4['legacy_pages_per_s']:,.0f} pages/s, "
          f"batched {r4['batched_pages_per_s']:,.0f} pages/s "
          f"({r4['speedup_batched_vs_legacy']:.1f}x), "
          f"pool {r4['readahead_pool_pages_per_s']:,.0f} pages/s "
          f"({r4['speedup_pool_vs_legacy']:.1f}x)")
    on = metrics["safs_stream"]["prefetch_on"]
    print(f"prefetch overlap fraction: {on['overlap_fraction']:.2f}")
    wb = metrics["safs_endurance"]["write_behind"]
    if wb:
        print(f"write-behind peak queue depth: {wb['max_depth_pages']} pages")
    sc = metrics["safs_cache"]
    print(f"reorth page hit rate: {sc['page_hit_rate']:.3f} pinned vs "
          f"{sc['lru_only_hit_rate']:.3f} LRU-only "
          f"({sc['pinned_over_lru']:.1f}x)")
    ig = metrics["safs_integrity"]
    print(f"integrity: verify-on-read overhead "
          f"{100 * ig['verify_overhead']:.1f}%, scrub pass "
          f"{ig['scrub_pages_per_s']:,.0f} pages/s")


if __name__ == "__main__":
    main()
