"""SAFS page store — Table 3 / §3.4.2 measurements on the file backend.

Three ladders, all on a scaled-down subspace streamed from real page files:

  safs_stream      MvTimesMatAddMv with the subspace on disk, prefetch OFF
                   vs ON — the §3.4.2 claim that overlapping page reads
                   with compute recovers most of the in-memory rate; the
                   derived column reports the overlap seconds (acceptance:
                   nonzero).
  safs_endurance   physical disk writes vs logical tier writes during an
                   append+restart-compress cycle — write-back + pinning
                   keep the medium's write traffic at or below logical
                   (Table 3 endurance argument).
  safs_cache       page-cache hit rate for the reorthogonalization re-read
                   pattern (most-recent-block pinning, §3.4.4).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MultiVector, TieredStore


def _mk(store, n, m, b, group_size=2):
    rng = np.random.default_rng(0)
    mv = MultiVector(store, n, group_size=group_size, impl="ref")
    for _ in range(m // b):
        mv.append_block(jnp.asarray(rng.standard_normal((n, b)), jnp.float32))
    return mv


def _safs_store(root, n, b, *, enable_prefetch):
    # cache holds ~3 blocks of a >8-block subspace: genuinely streaming
    # 64 KiB pages: SAFS's 4 KiB default is faithful but the python page
    # loop dominates at that grain; the I/O ratios are page-size invariant
    return TieredStore(
        device_budget_bytes=2 * n * 4 * b, backend="safs",
        backend_opts={"root": root, "cache_bytes": 3 * n * 4 * b,
                      "page_size": 65536,
                      "enable_prefetch": enable_prefetch})


def run(csv_rows: list):
    n, b, m = 60000, 4, 64          # subspace 16 blocks, ~15 MB on disk
    small = jnp.asarray(
        np.random.default_rng(1).standard_normal((m, b)), jnp.float32)
    root = tempfile.mkdtemp(prefix="bench_safs_")
    try:
        for tag, pref in (("prefetch_off", False), ("prefetch_on", True)):
            store = _safs_store(os.path.join(root, tag), n, b,
                                enable_prefetch=pref)
            mv = _mk(store, n, m, b)
            store.flush()
            store.reset_stats()
            t0 = time.perf_counter()
            mv.mv_times_mat(small)
            if pref:
                store.backend.prefetcher.drain()
            us = (time.perf_counter() - t0) * 1e6
            ov = store.backend.prefetcher.stats()["overlap_seconds"]
            csv_rows.append(("safs_stream", f"m={m},{tag}", us,
                             f"overlap_s={ov:.4f}"))
            store.close()

        # endurance: logical vs physical writes over append + compress
        store = _safs_store(os.path.join(root, "endurance"), n, b,
                            enable_prefetch=True)
        mv = _mk(store, n, m, b)
        q = jnp.asarray(np.random.default_rng(2)
                        .standard_normal((m, m // 2)), jnp.float32)
        t0 = time.perf_counter()
        mv.compress(q, [b] * (m // 2 // b))
        us = (time.perf_counter() - t0) * 1e6
        store.flush()
        logical_w = store.stats.host_bytes_written
        physical_w = store.backend.stats.host_bytes_written
        csv_rows.append(("safs_endurance", f"m={m}", us,
                         f"disk_over_logical_writes="
                         f"{physical_w / max(logical_w, 1):.2f}"))

        # reorth re-read pattern: newest block re-read right after demote
        d = store.backend.stats
        hit_rate = d.cache_hits / max(d.cache_hits + d.cache_misses, 1)
        csv_rows.append(("safs_cache", f"m={m}", 0.0,
                         f"page_hit_rate={hit_rate:.2f}"))
        store.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return csv_rows
