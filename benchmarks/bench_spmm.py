"""Paper Fig. 6/7/8 — SpMM optimization ladder + SEM-vs-IM ratio.

Fig. 6 ablation (adapted to TPU-idiom): start from plain COO segment-sum
SpMM and add the paper's optimizations one by one:
    coo            — unstructured gather/segment-sum (no blocking)
    +blocking      — 2-D tile blocking (dense MXU blocks, block-CSR)
    +hybrid        — blocks for dense tiles + COO remainder (SCSR+COO)
    +balance       — LPT nnz balancing of tile rows (work-stealing analogue)

Fig. 7/8 SEM ratio: semi-external-memory SpMM streams the matrix image from
the slow tier; we model the tier at the paper's measured bandwidth ratio
(SSD array ≈ 10.9 GB/s vs DRAM; on TPU: PCIe host-offload vs HBM) and
report the SEM/IM runtime ratio per #columns, the paper's 40–60 % claim.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import pack_tiles, rmat_graph
from repro.graphs.partition import balance_tile_rows, imbalance, \
    tile_row_costs
from repro.kernels import ops
from repro.kernels.spmm_ref import coo_spmm_ref

# modeled tier bandwidths. SLOW = the paper's measured SSD-array stream
# rate (§4.2.2: 10.87 GB/s). FAST = *effective* in-memory SpMM bandwidth —
# power-law SpMM is DRAM-random-access-bound, not peak-DRAM-bound; the
# paper's own Fig. 7 (IM ≈ 2× SEM at k=1) implies ~22–25 GB/s effective.
SLOW_TIER_BW = 10.9e9
FAST_TIER_BW = 25e9


def _time(f, *args, reps=3):
    f(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(csv_rows: list):
    n, nnz = 20000, 300000
    r, c, v = rmat_graph(n, nnz, seed=0, symmetric=True)
    for k in (1, 4):
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((n, k)), jnp.float32)

        # --- ladder step 1: pure COO segment-sum
        coo_fn = jax.jit(lambda rr, cc, vv, xx: coo_spmm_ref(rr, cc, vv, xx, n))
        t_coo = _time(coo_fn, jnp.asarray(r), jnp.asarray(c), jnp.asarray(v),
                      x)
        csv_rows.append(("fig6_spmm_coo", f"k={k}", t_coo, ""))

        # --- step 2: dense 2-D blocking (all blocks dense)
        tm_all = pack_tiles(n, n, r, c, v, block_shape=(64, 64),
                            min_block_nnz=1)
        xp = jnp.pad(x, ((0, tm_all.shape[1] - n), (0, 0)))
        t_blk = _time(lambda xx: ops.spmm(tm_all, xx, impl="ref"), xp)
        csv_rows.append(("fig6_spmm_blocked", f"k={k}", t_blk,
                         f"nblocks={tm_all.nblocks}"))

        # --- step 3: hybrid SCSR+COO (dense blocks + COO remainder)
        tm_hyb = pack_tiles(n, n, r, c, v, block_shape=(64, 64),
                            min_block_nnz=8)
        t_hyb = _time(lambda xx: ops.spmm(tm_hyb, xx, impl="ref"), xp)
        csv_rows.append(("fig6_spmm_hybrid", f"k={k}", t_hyb,
                         f"nblocks={tm_hyb.nblocks},"
                         f"coo={tm_hyb.coo_vals.size},"
                         f"bytes={tm_hyb.nbytes_image()}"))

        # --- step 4: load balance quality (pack-time LPT vs naive)
        costs = tile_row_costs(np.asarray(tm_hyb.row_ptr))
        naive = np.arange(len(costs)) % 48
        lpt = balance_tile_rows(costs, 48, contiguous=False)
        csv_rows.append(("fig6_spmm_balance", f"k={k}", 0.0,
                         f"imb_naive={imbalance(costs, naive, 48):.3f},"
                         f"imb_lpt={imbalance(costs, lpt, 48):.3f}"))

        # --- Fig 7/8: SEM/IM modeled ratio.
        # IM  ≙ matrix resident in fast memory at the *effective* in-memory
        #       SpMM bandwidth (random-access bound — see constants above);
        # SEM ≙ matrix streamed sequentially from the slow tier, overlapped
        #       with the same compute. More dense-matrix columns raise
        #       arithmetic intensity and close the gap — the paper's k trend.
        image_bytes = tm_hyb.nbytes_image()
        flops = 2.0 * nnz * k
        t_comp = flops / (0.05 * 197e12) + k * image_bytes / 300e9
        t_im = max(t_comp, image_bytes / FAST_TIER_BW)
        t_sem = max(t_comp, image_bytes / SLOW_TIER_BW)
        ratio = t_im / t_sem
        csv_rows.append(("fig7_sem_over_im", f"k={k}", t_sem * 1e6,
                         f"ratio={ratio:.2f},paper=0.4-0.6"))
    return csv_rows
