"""Subspace pass fusion — §3.4.3 reads-per-iteration, byte-exact.

The paper's cost claim: reorthogonalization (MvTransMv + MvTimesMatAddMv
over the on-SSD subspace) dominates SEM runtime, so the wins come from
minimizing *passes* over the vector subspace. This bench archives the
before/after of the fused streamed-pass engine (`core.stream.SubspacePass`)
into `results/BENCH_subspace_io.json`:

  expansion   host-tier bytes read by one CGS2 block expansion over an
              NB-block subspace (every block demoted to the slow tier —
              the controlled measurement): unfused = 2×(MvTransMv +
              MvTimesMatAddMv) = 4 streamed reads; fused = 2 `project_out`
              reads. The acceptance bar is fused/unfused ≤ 0.6 at NB ≥ 8
              (exact value 0.5: same bytes per pass, half the passes).
  compress    host-tier bytes read by restart compression onto k_keep
              columns: unfused = one full pass per output block (k_keep/b
              reads of the subspace); fused = exactly ONE streamed read
              regardless of k_keep (multi-accumulator TSGEMM).
  eigsh_e2e   whole-solve ladder on the ram backend: total logical reads,
              streamed passes, and fused-vs-unfused eigenvalue parity.
  safs        the same expansion on the file backend: wall-clock (the
              secondary, jitter-prone column — IOStats bytes are the
              primary metric; this container's scheduler noise swamps
              small timing deltas) plus physical disk bytes, and
              fused-vs-unfused eigsh spectrum parity with the subspace
              genuinely in page files.

`validate()` fails (non-zero exit) on missing fields, a fused/unfused
expansion read ratio above 0.6, a compress that re-reads the subspace, or
parity worse than rtol 1e-5 — wired into `scripts/run_tier1.sh --smoke`.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MultiVector, TieredStore, bcgs2, eigsh, GraphOperator
from repro.graphs import rmat_graph, normalized_adjacency, pack_tiles


def _demoted_mv(store: TieredStore, n: int = 512, b: int = 4, nb: int = 8,
                seed: int = 0) -> MultiVector:
    """An nb-block subspace with EVERY block on the slow tier (pins
    released) — host_bytes_read then counts each streamed pass exactly.
    Shared with tests/test_stream.py so the bench and the byte-exact
    tests measure the identical I/O state."""
    rng = np.random.default_rng(seed)
    mv = MultiVector(store, n, group_size=2, impl="ref")
    for _ in range(nb):
        mv.append_block(jnp.asarray(rng.standard_normal((n, b)), jnp.float32))
    for i in range(nb):
        store.unpin(mv._block_name(i))
        store.demote(mv._block_name(i))
    return mv


def _expansion_ladder(n: int, b: int, nb: int) -> dict:
    sub_bytes = n * b * 4 * nb
    w = jnp.asarray(np.random.default_rng(9).standard_normal((n, b)),
                    jnp.float32)
    out = {"nblocks": nb, "block_size": b, "n": n,
           "subspace_bytes": sub_bytes}
    for tag, fused in (("fused", True), ("unfused", False)):
        store = TieredStore()
        mv = _demoted_mv(store, n, b, nb)
        store.reset_stats()
        bcgs2(mv, w, impl="ref", fused=fused)
        s = store.stats
        out[tag] = {"host_bytes_read": s.host_bytes_read,
                    "passes": s.passes,
                    "reads_over_subspace": s.host_bytes_read / sub_bytes}
    out["fused_over_unfused"] = (out["fused"]["host_bytes_read"]
                                 / max(out["unfused"]["host_bytes_read"], 1))
    return out


def _compress_ladder(n: int, b: int, nb: int) -> dict:
    sub_bytes = n * b * 4 * nb
    m = nb * b
    k_keep = m // 2
    q = jnp.asarray(np.random.default_rng(10).standard_normal((m, k_keep)),
                    jnp.float32)
    out = {"nblocks": nb, "k_keep": k_keep, "subspace_bytes": sub_bytes}
    for tag, fused in (("fused", True), ("unfused", False)):
        store = TieredStore()
        mv = _demoted_mv(store, n, b, nb)
        store.reset_stats()
        mv.compress(q, [b] * (k_keep // b), fused=fused)
        s = store.stats
        out[tag] = {"host_bytes_read": s.host_bytes_read,
                    "passes": s.passes,
                    "reads_over_subspace": s.host_bytes_read / sub_bytes}
    out["fused_over_unfused"] = (out["fused"]["host_bytes_read"]
                                 / max(out["unfused"]["host_bytes_read"], 1))
    return out


def _graph_op(n: int, nnz: int, store: TieredStore) -> GraphOperator:
    r, c, v = rmat_graph(n, nnz, seed=5, symmetric=True)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64), min_block_nnz=4)
    return GraphOperator(tm, store=store, impl="ref")


def _eigsh_e2e(n: int, nnz: int, nev: int) -> dict:
    out: dict = {"n": n, "nev": nev}
    evs = {}
    for tag, fused in (("fused", True), ("unfused", False)):
        store = TieredStore()
        op = _graph_op(n, nnz, store)
        res = eigsh(op, nev, block_size=4, tol=1e-7, max_restarts=200,
                    store=store, impl="ref", fused_passes=fused)
        s = store.stats
        evs[tag] = np.sort(res.eigenvalues)
        out[tag] = {"host_bytes_read": s.host_bytes_read,
                    "host_bytes_written": s.host_bytes_written,
                    "passes": s.passes,
                    "pass_bytes_read": s.pass_bytes_read,
                    "bytes_per_pass": s.bytes_per_pass(),
                    "converged": bool(res.converged),
                    "n_restarts": int(res.n_restarts)}
    out["max_rel_err"] = float(np.max(
        np.abs(evs["fused"] - evs["unfused"]) / np.abs(evs["unfused"])))
    out["passes_fused_over_unfused"] = (out["fused"]["passes"]
                                        / max(out["unfused"]["passes"], 1))
    # subspace bytes actually streamed over the whole solve (attributed to
    # passes — operator tile reads sharing the store are excluded)
    out["pass_bytes_fused_over_unfused"] = (
        out["fused"]["pass_bytes_read"]
        / max(out["unfused"]["pass_bytes_read"], 1))
    return out


def _safs_ladder(root: str, n: int, b: int, nb: int, eig_n: int, nev: int
                 ) -> dict:
    """File-backend column: wall-clock per expansion (secondary metric)
    plus fused-vs-unfused spectrum parity with the subspace in pages."""
    out: dict = {"n": n, "nblocks": nb}
    w = jnp.asarray(np.random.default_rng(11).standard_normal((n, b)),
                    jnp.float32)
    for tag, fused in (("fused", True), ("unfused", False)):
        store = TieredStore(
            device_budget_bytes=2 * n * 4 * b, backend="safs",
            backend_opts={"root": os.path.join(root, f"exp_{tag}"),
                          "cache_bytes": 3 * n * 4 * b})
        mv = _demoted_mv(store, n, b, nb, seed=12)
        store.flush()
        store.reset_stats()
        t0 = time.perf_counter()
        bcgs2(mv, w, impl="ref", fused=fused)
        us = (time.perf_counter() - t0) * 1e6
        out[tag] = {"us": us,
                    "logical_bytes_read": store.stats.host_bytes_read,
                    "physical_bytes_read": store.backend.stats.host_bytes_read,
                    "passes": store.stats.passes}
        store.close()
    out["wallclock_fused_over_unfused"] = (out["fused"]["us"]
                                           / max(out["unfused"]["us"], 1e-9))

    evs = {}
    for tag, fused in (("fused", True), ("unfused", False)):
        store = TieredStore(
            device_budget_bytes=2 * eig_n * 4 * 4, backend="safs",
            backend_opts={"root": os.path.join(root, f"eig_{tag}"),
                          "cache_bytes": 3 * eig_n * 4 * 4})
        op = _graph_op(eig_n, 12 * eig_n, store)
        res = eigsh(op, nev, block_size=4, tol=1e-6, max_restarts=100,
                    store=store, impl="ref", fused_passes=fused)
        evs[tag] = np.sort(res.eigenvalues)
        store.close()
    out["eigsh_max_rel_err"] = float(np.max(
        np.abs(evs["fused"] - evs["unfused"]) / np.abs(evs["unfused"])))
    return out


def collect(*, smoke: bool = False) -> dict:
    n, b, nb = (4000, 4, 8) if smoke else (20000, 4, 16)
    e2e_n, e2e_nnz, nev = (1200, 10000, 8) if smoke else (3000, 30000, 8)
    eig_n = 4000 if smoke else 6000   # safs parity solve (disk-bound)
    out: dict = {"schema": "bench_subspace_io/v1", "smoke": smoke}
    out["expansion"] = _expansion_ladder(n, b, nb)
    out["compress"] = _compress_ladder(n, b, nb)
    out["eigsh_e2e"] = _eigsh_e2e(e2e_n, e2e_nnz, nev)
    root = tempfile.mkdtemp(prefix="bench_subio_")
    try:
        out["safs"] = _safs_ladder(root, n, b, nb, eig_n, nev)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


def validate(metrics: dict) -> None:
    """Tier-1 gate: raises AssertionError on a perf/parity regression."""
    for k in ("expansion", "compress", "eigsh_e2e", "safs"):
        assert k in metrics, f"BENCH_subspace_io.json missing {k!r}"
    exp = metrics["expansion"]
    assert exp["nblocks"] >= 8, exp["nblocks"]
    for k in ("fused", "unfused"):
        assert exp[k]["host_bytes_read"] > 0, (k, exp)
    assert exp["fused_over_unfused"] <= 0.6, (
        f"fused expansion reads {exp['fused_over_unfused']:.3f}x unfused "
        f"(bar: 0.6) — pass fusion regressed")
    comp = metrics["compress"]
    assert comp["fused"]["passes"] == 1, comp["fused"]
    assert comp["fused"]["reads_over_subspace"] <= 1.0 + 1e-9, (
        "fused compress must read the subspace exactly once")
    e2e = metrics["eigsh_e2e"]
    assert e2e["fused"]["converged"] and e2e["unfused"]["converged"], e2e
    assert e2e["max_rel_err"] <= 1e-5, (
        f"fused/unfused spectrum diverged: {e2e['max_rel_err']:.3e}")
    assert metrics["safs"]["eigsh_max_rel_err"] <= 1e-5, (
        f"safs fused/unfused spectrum diverged: "
        f"{metrics['safs']['eigsh_max_rel_err']:.3e}")


def run(csv_rows: list):
    """Harness entry (`benchmarks/run.py subspace_io`)."""
    m = collect(smoke=True)
    exp, comp, e2e = m["expansion"], m["compress"], m["eigsh_e2e"]
    csv_rows.append((
        "subspace_io_expand", f"nb={exp['nblocks']}", m["safs"]["fused"]["us"],
        f"fused_over_unfused={exp['fused_over_unfused']:.3f}"))
    csv_rows.append((
        "subspace_io_compress", f"k={comp['k_keep']}", 0.0,
        f"fused_passes={comp['fused']['passes']},"
        f"unfused_passes={comp['unfused']['passes']}"))
    csv_rows.append((
        "subspace_io_e2e", f"n={e2e['n']}", 0.0,
        f"passes_ratio={e2e['passes_fused_over_unfused']:.3f},"
        f"max_rel_err={e2e['max_rel_err']:.1e}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down sizes (tier-1 trajectory tracking)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results", "BENCH_subspace_io.json"))
    args = ap.parse_args()
    metrics = collect(smoke=args.smoke)
    validate(metrics)
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(metrics, f, indent=2)
    exp, comp, e2e = (metrics["expansion"], metrics["compress"],
                      metrics["eigsh_e2e"])
    print(f"wrote {args.out}")
    print(f"expansion (NB={exp['nblocks']}): "
          f"{exp['unfused']['reads_over_subspace']:.2f}x subspace unfused → "
          f"{exp['fused']['reads_over_subspace']:.2f}x fused "
          f"(ratio {exp['fused_over_unfused']:.3f})")
    print(f"compress (k_keep={comp['k_keep']}): "
          f"{comp['unfused']['passes']} passes unfused → "
          f"{comp['fused']['passes']} fused "
          f"({comp['fused']['reads_over_subspace']:.2f}x subspace)")
    print(f"eigsh e2e: {e2e['unfused']['passes']} → {e2e['fused']['passes']} "
          f"passes, subspace bytes {e2e['unfused']['pass_bytes_read']/1e6:.1f}"
          f" → {e2e['fused']['pass_bytes_read']/1e6:.1f} MB "
          f"(ratio {e2e['pass_bytes_fused_over_unfused']:.3f}), "
          f"parity {e2e['max_rel_err']:.1e}")
    print(f"safs: expansion wall-clock ratio "
          f"{metrics['safs']['wallclock_fused_over_unfused']:.2f} "
          f"(secondary; jitter), eigsh parity "
          f"{metrics['safs']['eigsh_max_rel_err']:.1e}")


if __name__ == "__main__":
    main()
