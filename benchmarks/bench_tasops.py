"""Paper Fig. 9/10/11 — out-of-core dense-matrix (TAS) operations.

Fig. 9 I/O ladder (TPU-idiom adaptation):
    naive          — every block demoted+promoted per op (no cache, no pool)
    +recent-cache  — newest block pinned in the device tier (§3.4.4)
    +lazy-scale    — MvScale folded into consumers (zero-I/O scaling)
    +grouping      — Fig. 5 group decomposition (bounded fast-tier memory)

Fig. 10/11: op1 (MvTimesMatAddMv) runtime vs m, plus modeled tier
bandwidth saturation (the paper reaches 10.87 GB/s of 12 GB/s max).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MultiVector, TieredStore

SLOW_TIER_BW = 10.9e9


def _mk(store, n, m, b, group_size=8):
    rng = np.random.default_rng(0)
    mv = MultiVector(store, n, group_size=group_size, impl="ref")
    for _ in range(m // b):
        mv.append_block(jnp.asarray(rng.standard_normal((n, b)), jnp.float32))
    return mv


def run(csv_rows: list):
    n, b = 60000, 4          # paper §4.2: n = 60M scaled 1000×, b = 4
    for m in (16, 64, 256):
        small = jnp.asarray(
            np.random.default_rng(1).standard_normal((m, b)), jnp.float32)

        # naive: no pinned cache — demote every block after each touch
        store = TieredStore(device_budget_bytes=n * 4 * b)  # 1 block fits
        mv = _mk(store, n, m, b)
        for i in range(mv.nblocks):
            store.unpin(mv._block_name(i))
            store.demote(mv._block_name(i))
        store.reset_stats()
        t0 = time.perf_counter()
        mv.mv_times_mat(small)
        t_naive = (time.perf_counter() - t0) * 1e6
        io_naive = store.stats.host_bytes_read + store.stats.host_bytes_written
        csv_rows.append(("fig9_tas_naive", f"m={m}", t_naive,
                         f"io_bytes={io_naive}"))

        # +recent-cache (default policy) — newest block stays on device
        store2 = TieredStore(device_budget_bytes=2 * n * 4 * b)
        mv2 = _mk(store2, n, m, b)
        store2.reset_stats()
        t0 = time.perf_counter()
        mv2.mv_times_mat(small)
        t_cache = (time.perf_counter() - t0) * 1e6
        io_cache = (store2.stats.host_bytes_read
                    + store2.stats.host_bytes_written)
        csv_rows.append(("fig9_tas_cache", f"m={m}", t_cache,
                         f"io_bytes={io_cache}"))

        # +lazy scale: MvScale costs zero I/O
        store2.reset_stats()
        mv2.mv_scale(0.5)
        io_scale = (store2.stats.host_bytes_read
                    + store2.stats.host_bytes_written)
        csv_rows.append(("fig9_tas_lazy_scale", f"m={m}", 0.0,
                         f"io_bytes={io_scale}"))

        # +grouping: fast-tier peak during MvTransMv bounded by group size
        for gs in (2, 8):
            store3 = TieredStore()
            mv3 = _mk(store3, n, m, b, group_size=gs)
            other = jnp.asarray(np.random.default_rng(2)
                                .standard_normal((n, b)), jnp.float32)
            t0 = time.perf_counter()
            mv3.mv_trans_mv(other)
            t_g = (time.perf_counter() - t0) * 1e6
            csv_rows.append(("fig10_mv_trans_mv", f"m={m},g={gs}", t_g, ""))

        # Fig 11: modeled tier throughput for op1 streaming the subspace
        bytes_streamed = n * m * 4
        t_io_bound = bytes_streamed / SLOW_TIER_BW * 1e6
        eff = min(1.0, t_io_bound / max(t_cache, 1e-9))
        csv_rows.append(("fig11_tier_saturation", f"m={m}", t_io_bound,
                         f"io_over_compute={eff:.2f}"))
    return csv_rows
