"""Benchmark harness — one module per paper table/figure.

Prints ``name,case,us_per_call,derived`` CSV. Fast by construction (scaled-
down problem sizes; the full-scale numbers live in the dry-run/roofline
path).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_spmm, bench_tasops, bench_eigen, \
        bench_roofline, bench_safs, bench_dist_e2e, bench_subspace_io
    rows: list = []
    mods = {"spmm": bench_spmm, "tasops": bench_tasops,
            "eigen": bench_eigen, "roofline": bench_roofline,
            "safs": bench_safs, "dist_e2e": bench_dist_e2e,
            "subspace_io": bench_subspace_io}
    selected = sys.argv[1:] or list(mods)
    for name in selected:
        mods[name].run(rows)
    print("name,case,us_per_call,derived")
    for name, case, us, derived in rows:
        print(f"{name},{case},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
