"""Beyond-paper integration: point the FlashEigen solver at an LM's loss
curvature (Hessian spectrum via matrix-free HVPs).

    PYTHONPATH=src python examples/curvature_spectrum.py

The same Block Krylov-Schur machinery that eigendecomposes billion-node
graphs here estimates the top loss-curvature eigenvalues of a (reduced)
assigned architecture — the LinearOperator abstraction is what makes the
paper's technique a first-class framework feature (DESIGN.md §4).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import HvpOperator, eigsh
from repro.models import transformer as tf


def main():
    cfg = configs.reduced("qwen2-1.5b")
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                               jnp.int32),
    }

    def loss(p):
        return tf.loss_fn(p, cfg, batch)

    op = HvpOperator(loss, params, pad_to=8)
    print(f"parameter space dimension: {op.n_logical:,}")
    res = eigsh(op, 4, block_size=2, tol=1e-3, max_restarts=40,
                which="LA", impl="ref")
    print("top Hessian eigenvalues:", np.round(res.eigenvalues, 4))
    print(f"restarts={res.n_restarts} HVP-block-calls={res.n_ops}")
    assert np.isfinite(res.eigenvalues).all()


if __name__ == "__main__":
    main()
