"""End-to-end sharded eigensolve: core restart loop driving the dist layer.

    PYTHONPATH=src python examples/dist_eigen_e2e.py [--n 4000] [--nev 8]
        [--devices 8] [--root DIR] [--pod-compressed]

This is the integration the paper's headline result is about (§3 + §4 in
one pipeline): `core.eigsh` owns the Krylov–Schur restarts and the
out-of-core subspace, while every expansion runs as ONE fused shard_mapped
SpMM + CGS2 + CholQR2 program (`dist.build_eigen_step`) over edge panels
sharded across a (pod, data, model) CPU device mesh. Residencies follow
the paper's split:

  * edge panels: packed once, device-sharded (the SSD-streamed operand);
  * subspace history: device-sharded (nb_v, n_pad, b) stack consumed in
    place by the fused step — the "recent matrix cached in fast memory";
  * the MultiVector system-of-record spills to SAFS page files
    (`TieredStore(backend="safs")`): restart compression and eigenvector
    materialization stream it back — the "subspace on SSD" half.

The driver factorizes the same RMAT graph through the local GraphOperator
path and asserts spectrum parity to rtol 1e-5, then (optionally) runs the
int8 cross-pod reduction variant (`pod_compressed=True`) and reports its
per-restart eigenvalue deviation — the error-accumulation number the
ROADMAP asks for before it can become a multi-pod default.
"""
import argparse
import os
import shutil
import tempfile
import time

from repro.hostdev import force_host_devices


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--nnz", type=int, default=48000)
    ap.add_argument("--nev", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (pod×data×model mesh)")
    ap.add_argument("--root", default=None,
                    help="directory for the SAFS page files (default: tmp)")
    ap.add_argument("--pod-compressed", action="store_true",
                    help="also run the int8 cross-pod reduction variant")
    args = ap.parse_args()
    force_host_devices(args.devices)

    import jax
    import numpy as np
    from repro.graphs import rmat_spectral, pack_tiles
    from repro.core import GraphOperator, TieredStore, eigsh
    from repro.dist import DistOperator

    print(f"building RMAT graph: {args.n} vertices, ~{args.nnz} edges")
    r, c, v = rmat_spectral(args.n, args.nnz, seed=1)

    # ---- local reference: GraphOperator through the same restart loop
    tm = pack_tiles(args.n, args.n, r, c, v, block_shape=(64, 64),
                    min_block_nnz=4)
    t0 = time.perf_counter()
    local = eigsh(GraphOperator(tm, impl="ref"), args.nev,
                  block_size=args.block_size, tol=1e-7, max_restarts=100,
                  impl="ref")
    t_local = time.perf_counter() - t0
    w_local = np.sort(local.eigenvalues)

    # ---- sharded path: fused expansion on the device mesh, subspace
    #      system-of-record spilled to SAFS page files
    from repro.dist import e2e_mesh
    dop = DistOperator(args.n, r, c, v, mesh=e2e_mesh())
    print(f"mesh: {dop.mesh.shape} over {len(jax.devices())} devices, "
          f"n_pad={dop.n}, e_loc={dop.e_loc}")

    root = args.root or tempfile.mkdtemp(prefix="dist_e2e_")
    own_tmp = args.root is None
    bs = args.block_size
    store = TieredStore(
        device_budget_bytes=2 * dop.n * 4 * bs, backend="safs",
        backend_opts={"root": os.path.join(root, "pages"),
                      "cache_bytes": 3 * dop.n * 4 * bs})
    try:
        _drive(args, dop, store, r, c, v, w_local, t_local)
    finally:
        # a failed parity assert must not leak the write-behind thread,
        # open page files, or the spilled-subspace tmpdir
        store.close()
        if own_tmp:
            shutil.rmtree(root, ignore_errors=True)


def _drive(args, dop, store, r, c, v, w_local, t_local):
    import numpy as np
    from repro.core import eigsh
    bs = args.block_size
    t0 = time.perf_counter()
    dist = eigsh(dop, args.nev, block_size=bs, tol=1e-7, max_restarts=100,
                 store=store, impl="ref")
    t_dist = time.perf_counter() - t0
    w_dist = np.sort(dist.eigenvalues)

    print(f"eigenvalues (dist):  {np.round(w_dist, 6)}")
    print(f"eigenvalues (local): {np.round(w_local, 6)}")
    np.testing.assert_allclose(w_dist, w_local, rtol=1e-5)
    print(f"sharded path matches local path to rtol 1e-5 "
          f"({dop.n_fused_steps} fused expansions, "
          f"local {t_local:.1f}s vs dist {t_dist:.1f}s)")

    s, d = store.stats, store.backend.stats
    print(f"subspace spill (SAFS): logical wrote {s.host_bytes_written/1e6:.1f} MB "
          f"/ read {s.host_bytes_read/1e6:.1f} MB; physical disk "
          f"wrote {d.host_bytes_written/1e6:.1f} MB / read "
          f"{d.host_bytes_read/1e6:.1f} MB "
          f"(page hits {d.cache_hits}, misses {d.cache_misses})")
    print("fused path note: expansions stream ZERO subspace bytes from the "
          "store — only restart compression and the final Ritz GEMM do "
          "(the paper's subspace-on-SSD / recent-matrix-in-fast-memory "
          "split)")

    if args.pod_compressed:
        # int8 cross-pod reductions: per-restart |λ| deviation (shared
        # methodology — see dist.pod_compressed_deviation)
        from repro.dist import pod_compressed_deviation
        devs = pod_compressed_deviation(args.n, r, c, v, w_local,
                                        mesh=dop.mesh, nev=args.nev,
                                        block_size=bs, max_restarts=8)
        print(f"pod_compressed deviation per restart: "
              f"{[f'{x:.2e}' for x in devs]} (no runaway accumulation)")


if __name__ == "__main__":
    main()
