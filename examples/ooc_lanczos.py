"""End-to-end out-of-core eigensolve with the subspace on disk (SAFS).

    PYTHONPATH=src python examples/ooc_lanczos.py [--n 4000] [--nev 8]
        [--solver ks|lanczos] [--root DIR] [--trace OUT.jsonl]
        [--checkpoint DIR [--every N]] [--resume DIR]

This is the full paper pipeline at laptop scale: an RMAT graph, the
semi-external SpMM operator, and the Krylov–Schur (or block-Lanczos
baseline) loop with the *entire vector subspace AND the matrix image
living in SAFS page files* (`TieredStore(backend="safs")`, §3.4.1 +
`GraphOperator(stream_image=True)`, §3.3.3) — every host-tier byte
physically traverses the filesystem through the LRU page cache via the
batched vectored I/O engine, demotions retire through the async
write-behind queue, and the multi-worker readahead pool keeps the next
subspace group / matrix chunk in flight under the current contraction.

The driver runs the identical solve on the ram backend and asserts the two
spectra agree to rtol 1e-5 (the out-of-core machinery is bit-honest, not
approximate), then reports:

  * logical tier traffic (reads ≫ writes — the paper's write-avoidance,
    Table 3: 145 TB read vs 4 TB written, ratio 0.028);
  * physical disk traffic (≤ logical: the page cache absorbs re-reads);
  * prefetch overlap seconds (reads hidden behind compute, §3.4.2);
  * a direct-from-pages checkpoint snapshot (no RAM round-trip).

All counters come from one `backend.stats_dict()` snapshot (cache +
prefetcher + write-behind merged). With `--trace OUT.jsonl` the SAFS solve
records a full span timeline (`repro.obs`) — inspect it with
`python -m repro.obs.report OUT.jsonl` or convert to Perfetto JSON.

Fault tolerance (`--solver ks` only): `--checkpoint DIR` snapshots the
SAFS solve at restart boundaries (every `--every` restarts) under
`ft.PreemptionGuard` — a SIGTERM mid-solve finishes the in-flight
restart, commits a checkpoint and exits 0 with a resume hint; rerun with
`--resume DIR` to continue from the newest committed snapshot (the final
ram-parity assert then proves the interrupted solve converged to the
same spectrum).
"""
import argparse
import os
import shutil
import signal
import sys
import tempfile

import numpy as np
import jax.numpy as jnp

from repro.graphs import rmat_graph, normalized_adjacency, pack_tiles
from repro.core import GraphOperator, TieredStore, solve
from repro.ckpt import checkpoint as ck
from repro.ckpt.solver import CheckpointPolicy, SolveSuspended
from repro.ft import PreemptionGuard

_METHODS = {"ks": "krylov_schur", "lanczos": "lanczos"}


def run_solve(image, n, nev, *, solver, store, stream_image=False,
              trace=None, checkpoint=None, resume=None, callback=None):
    # stream_image=True spills the edge tiles into the same page store as
    # the subspace: matmat then really is semi-external (§3.3.3)
    op = GraphOperator(image, store=store, impl="ref",
                       stream_image=stream_image, image_chunk_bytes=1 << 20)
    kw = ({"tol": 1e-7, "max_iters": 100} if solver == "ks" else {})
    return solve(op, nev, method=_METHODS[solver], block_size=4,
                 store=store, impl="ref", group_size=2, trace=trace,
                 checkpoint=checkpoint, resume=resume, callback=callback,
                 **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--nnz", type=int, default=48000)
    ap.add_argument("--nev", type=int, default=8)
    ap.add_argument("--solver", choices=("ks", "lanczos"), default="ks")
    ap.add_argument("--root", default=None,
                    help="directory for the SAFS page files (default: tmp)")
    ap.add_argument("--trace", default=None, metavar="OUT.jsonl",
                    help="record the SAFS solve timeline to this JSONL file")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="snapshot the SAFS solve at restart boundaries "
                         "into DIR; SIGTERM suspends resumably (ks only)")
    ap.add_argument("--every", type=int, default=1,
                    help="checkpoint cadence in restarts (default 1)")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="continue the SAFS solve from the newest "
                         "committed checkpoint under DIR")
    ap.add_argument("--preempt-after", type=int, default=None,
                    help=argparse.SUPPRESS)  # test hook: SIGTERM ourselves
                    # after N restarts to exercise the real signal path
    args = ap.parse_args()
    if (args.checkpoint or args.resume) and args.solver != "ks":
        ap.error("--checkpoint/--resume need --solver ks")

    print(f"building RMAT graph: {args.n} vertices, ~{args.nnz} edges")
    r, c, v = rmat_graph(args.n, args.nnz, seed=1, symmetric=True)
    r, c, v = normalized_adjacency(args.n, r, c, v)
    image = pack_tiles(args.n, args.n, r, c, v, block_shape=(64, 64),
                       min_block_nnz=4)

    # in-memory reference: identical solve, ram backend
    ram_store = TieredStore(device_budget_bytes=2 * args.n * 4 * 4)
    ram = run_solve(image, args.n, args.nev, solver=args.solver,
                    store=ram_store)

    root = args.root or tempfile.mkdtemp(prefix="ooc_lanczos_")
    own_tmp = args.root is None
    # small page cache (subspace ≫ cache) → bytes genuinely stream from disk
    # cache: ~3 subspace blocks + 2 matrix-image chunks — far below the
    # total footprint (subspace + image), so both genuinely stream
    safs_store = TieredStore(
        device_budget_bytes=2 * args.n * 4 * 4, backend="safs",
        backend_opts={"root": os.path.join(root, "pages"),
                      "cache_bytes": args.n * 4 * 4 * 3 + (2 << 20)})

    callback = None
    if args.preempt_after is not None:
        def callback(step, _theta, _res, _n=[0]):
            _n[0] += 1
            if _n[0] == args.preempt_after:
                os.kill(os.getpid(), signal.SIGTERM)

    with PreemptionGuard() as guard:
        policy = None
        if args.checkpoint:
            policy = CheckpointPolicy(root=args.checkpoint,
                                      every_restarts=args.every,
                                      guard=guard)
        try:
            disk = run_solve(image, args.n, args.nev, solver=args.solver,
                             store=safs_store, stream_image=True,
                             trace=args.trace, checkpoint=policy,
                             resume=args.resume, callback=callback)
        except SolveSuspended as e:
            # preempted: the in-flight restart finished and committed —
            # exit clean, the next run continues where this one stopped
            print(f"solve suspended at restart {e.step}; resume with "
                  f"--resume {e.root}")
            safs_store.close()
            if own_tmp:
                shutil.rmtree(root, ignore_errors=True)
            sys.exit(0)

    w_ram = np.sort(ram.eigenvalues)
    w_disk = np.sort(disk.eigenvalues)
    print(f"eigenvalues (safs): {np.round(w_disk, 6)}")
    np.testing.assert_allclose(w_disk, w_ram, rtol=1e-5)
    print("safs backend matches ram backend to rtol 1e-5")

    s = safs_store.stats
    snap = safs_store.backend.stats_dict()   # cache+prefetch+wb, one call
    d, pf, w = snap["io"], snap["prefetch"], snap["write_behind"]
    ratio = s.host_bytes_written / max(s.host_bytes_read, 1)
    print(f"logical tier I/O:  read {s.host_bytes_read/1e6:8.1f} MB, "
          f"wrote {s.host_bytes_written/1e6:6.1f} MB "
          f"(write/read = {ratio:.4f}; paper Table 3: 0.028)")
    print(f"streamed subspace passes: {s.passes} "
          f"({s.bytes_per_pass()/1e6:.2f} MB/pass — fused CGS2 reads the "
          f"subspace 2x per expansion, restart compression 1x, §3.4.3)")
    print(f"physical disk I/O: read {d['host_bytes_read']/1e6:8.1f} MB, "
          f"wrote {d['host_bytes_written']/1e6:6.1f} MB "
          f"(page-cache hits {d['cache_hits']}, misses {d['cache_misses']})")
    print(f"readahead: {pf['bytes_prefetched']/1e6:.1f} MB staged by "
          f"{pf['io_workers']} workers (depth {pf['depth']}), "
          f"{pf['overlap_seconds']*1e3:.1f} ms of reads overlapped compute")
    if w is not None:
        print(f"write-behind: {w['pages_retired']} pages retired in "
              f"{w['batches_retired']} journaled batches "
              f"(peak queue depth {w['max_depth_pages']} pages)")
    assert s.host_bytes_read > 10 * s.host_bytes_written, \
        "tier must be read-dominated (write-avoidance)"
    if args.trace:
        print(f"trace: {args.trace} "
              f"(inspect: python -m repro.obs.report {args.trace})")

    # checkpoint straight from the page files (no RAM round-trip)
    ckroot = os.path.join(root, "ckpt")
    path = ck.save_safs(ckroot, 1, safs_store,
                        extra={"eigenvalues": list(map(float, w_disk))})
    print(f"page snapshot: {path} "
          f"({sum(e.stat().st_size for e in os.scandir(path))/1e6:.1f} MB)")

    safs_store.close()
    if own_tmp:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
