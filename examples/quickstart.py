"""Quickstart: compute 8 eigenvalues of a power-law graph out-of-core.

    PYTHONPATH=src python examples/quickstart.py

Builds an RMAT graph, packs the block-sparse matrix image, runs the
tiered (out-of-core) Block Krylov-Schur eigensolver, and checks the
spectrum against scipy. Prints the byte-exact tier I/O accounting —
the paper's Table-3 read/write shape at laptop scale.
"""
import numpy as np
import jax.numpy as jnp
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.graphs import rmat_graph, normalized_adjacency, pack_tiles
from repro.core import GraphOperator, TieredStore, eigsh, true_residuals


def main():
    n, nnz, nev = 5000, 60000, 8
    print(f"building RMAT graph: {n} vertices, ~{nnz} edges")
    r, c, v = rmat_graph(n, nnz, seed=1, symmetric=True)
    r, c, v = normalized_adjacency(n, r, c, v)
    image = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    print(f"matrix image: {image.nblocks} dense blocks + "
          f"{image.coo_vals.size} COO entries, "
          f"{image.nbytes_image()/1e6:.1f} MB")

    # device tier budgeted below the subspace size → genuinely out-of-core
    store = TieredStore(device_budget_bytes=2 * n * 4 * 4)
    op = GraphOperator(image, store=store, impl="ref")
    res = eigsh(op, nev, block_size=4, tol=1e-6, max_restarts=100,
                which="LM", store=store, impl="ref")
    print(f"eigenvalues: {np.round(np.sort(res.eigenvalues), 5)}")
    print(f"converged={res.converged} restarts={res.n_restarts} "
          f"SpMM-calls={res.n_ops}")

    a = sp.coo_matrix((v, (r, c)), shape=(n, n)).tocsr()
    w = np.sort(spla.eigsh(a, k=nev, which="LM", return_eigenvectors=False))
    err = np.abs(np.sort(res.eigenvalues) - w).max()
    print(f"max |err| vs scipy: {err:.2e}")
    tr = true_residuals(op, jnp.asarray(res.eigenvectors), res.eigenvalues)
    print(f"max true residual:  {tr.max():.2e}")

    s = store.stats
    print(f"tier I/O: read {s.host_bytes_read/1e6:.1f} MB, "
          f"wrote {s.host_bytes_written/1e6:.1f} MB "
          f"(write/read = {s.host_bytes_written/max(s.host_bytes_read,1):.4f};"
          f" paper Table 3: 0.028)")
    assert err < 1e-4


if __name__ == "__main__":
    main()
