"""Spectral clustering on a planted-partition graph — the paper's target
application [17, 22].

    PYTHONPATH=src python examples/spectral_cluster.py
    PYTHONPATH=src python examples/spectral_cluster.py --method lobpcg
    PYTHONPATH=src python examples/spectral_cluster.py --laplacian

Embeds vertices with the top-k eigenvectors of the normalized adjacency
(equivalently, with `--laplacian`, the smallest-eigenvalue eigenvectors of
the normalized Laplacian L = I − Â) and recovers the planted communities
with spherical k-means. Any registered member of the solver family
(`repro.core.solve`) computes the embedding — the two spectral views and
all methods must land on the same partition.
"""
import argparse

import numpy as np

from repro.graphs import normalized_adjacency, pack_tiles
from repro.core import GraphOperator, TieredStore, solve


class LaplacianOperator:
    """Normalized Laplacian L = I − Â as a streamed operator: one Â tile
    pass per apply, identity added on the fly. Its smallest eigenpairs are
    Â's largest, so the two CLI modes must agree."""

    def __init__(self, adj_op):
        self.adj = adj_op
        self.n = adj_op.n

    def matmat(self, x):
        return x - self.adj.matmat(x)


def planted_partition(n=3000, k=4, d_avg=12, p_in=0.85, seed=0):
    rng = np.random.default_rng(seed)
    labels = np.repeat(np.arange(k), n // k)
    rows, cols = [], []
    for i in range(n):
        for _ in range(d_avg):
            j = int(rng.integers(0, n))
            p = p_in if labels[i] == labels[j] else (1 - p_in) / (k - 1)
            if rng.random() < p and i != j:
                rows.append(i); cols.append(j)
    r = np.array(rows + cols, np.int32)
    c = np.array(cols + rows, np.int32)
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    return labels, r[idx], c[idx], np.ones(idx.size, np.float32)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--method", default="krylov_schur",
                    choices=("krylov_schur", "lobpcg"),
                    help="solver-family member computing the embedding")
    ap.add_argument("--laplacian", action="store_true",
                    help="embed with the SMALLEST eigenpairs of L = I − Â "
                         "instead of the largest of Â")
    args = ap.parse_args(argv)

    n, k = 3000, 4
    labels, r, c, v = planted_partition(n, k)
    print(f"planted partition: {n} vertices, {r.size} edges, {k} blocks")
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    image = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64),
                       min_block_nnz=4)
    store = TieredStore()
    adj = GraphOperator(image, store=store, impl="ref")
    if args.laplacian:
        op, which = LaplacianOperator(adj), "SA"
    else:
        op, which = adj, "LA"
    res = solve(op, k, method=args.method, which=which, tol=1e-6,
                max_iters=200, block_size=k if args.method == "krylov_schur"
                else 2 * k, store=store, impl="ref")
    emb = res.eigenvectors[:n]
    emb = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-12)

    cents = emb[np.linspace(0, n - 1, k).astype(int)]
    for _ in range(30):
        assign = np.argmax(emb @ cents.T, axis=1)
        cents = np.stack([emb[assign == i].mean(0) if (assign == i).any()
                          else cents[i] for i in range(k)])
        cents /= np.linalg.norm(cents, axis=1, keepdims=True) + 1e-12
    purity = sum(np.bincount(labels[assign == i]).max()
                 for i in range(k) if (assign == i).any()) / n
    spec = "L = I - A_hat (smallest)" if args.laplacian \
        else "A_hat (largest)"
    print(f"method={args.method}  spectrum={spec}")
    print(f"eigenvalues: {np.round(np.sort(res.eigenvalues), 4)}")
    print(f"cluster purity: {purity:.3f}")
    assert purity > 0.9
    return purity


if __name__ == "__main__":
    main()
