"""Spectral clustering on a planted-partition graph — the paper's target
application [17, 22].

    PYTHONPATH=src python examples/spectral_cluster.py

Embeds vertices with the top-k eigenvectors of the normalized adjacency
(computed by the out-of-core solver) and recovers the planted communities
with spherical k-means.
"""
import numpy as np

from repro.graphs import normalized_adjacency, pack_tiles
from repro.core import GraphOperator, TieredStore, eigsh


def planted_partition(n=3000, k=4, d_avg=12, p_in=0.85, seed=0):
    rng = np.random.default_rng(seed)
    labels = np.repeat(np.arange(k), n // k)
    rows, cols = [], []
    for i in range(n):
        for _ in range(d_avg):
            j = int(rng.integers(0, n))
            p = p_in if labels[i] == labels[j] else (1 - p_in) / (k - 1)
            if rng.random() < p and i != j:
                rows.append(i); cols.append(j)
    r = np.array(rows + cols, np.int32)
    c = np.array(cols + rows, np.int32)
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    return labels, r[idx], c[idx], np.ones(idx.size, np.float32)


def main():
    n, k = 3000, 4
    labels, r, c, v = planted_partition(n, k)
    print(f"planted partition: {n} vertices, {r.size} edges, {k} blocks")
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    image = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64),
                       min_block_nnz=4)
    store = TieredStore()
    res = eigsh(GraphOperator(image, store=store, impl="ref"), k,
                block_size=k, tol=1e-6, max_restarts=200, which="LA",
                store=store, impl="ref")
    emb = res.eigenvectors[:n]
    emb = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-12)

    cents = emb[np.linspace(0, n - 1, k).astype(int)]
    for _ in range(30):
        assign = np.argmax(emb @ cents.T, axis=1)
        cents = np.stack([emb[assign == i].mean(0) if (assign == i).any()
                          else cents[i] for i in range(k)])
        cents /= np.linalg.norm(cents, axis=1, keepdims=True) + 1e-12
    purity = sum(np.bincount(labels[assign == i]).max()
                 for i in range(k) if (assign == i).any()) / n
    print(f"eigenvalues: {np.round(np.sort(res.eigenvalues), 4)}")
    print(f"cluster purity: {purity:.3f}")
    assert purity > 0.9


if __name__ == "__main__":
    main()
