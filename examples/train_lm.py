"""End-to-end training driver: train a small LM for a few hundred steps on
the deterministic synthetic corpus, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py               # ~20M params
    PYTHONPATH=src python examples/train_lm.py --preset 100m # the full run

The 100m preset matches the "train a ~100M model" deliverable shape; the
default is sized to finish on this CPU container in minutes. Interrupt it
(Ctrl-C → SIGTERM) and rerun: it resumes from the newest checkpoint.
"""
import argparse
import dataclasses

from repro import configs
from repro.data.pipeline import DataConfig
from repro.train.trainer import TrainConfig, train


PRESETS = {
    # ~21M params: qwen2-family (GQA + GLU), scaled
    "20m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                head_dim=64, d_ff=1024, vocab_size=8192,
                seq_len=128, global_batch=8, steps=300),
    # ~113M params
    "100m": dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
                 head_dim=64, d_ff=2048, vocab_size=32000,
                 seq_len=512, global_batch=32, steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    base = configs.get("qwen2-1.5b")
    cfg = dataclasses.replace(
        base, name=f"lm-{args.preset}",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], param_dtype="float32", remat=False)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    steps = args.steps or p["steps"]
    tcfg = TrainConfig(steps=steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
                       peak_lr=1e-3, warmup=30, log_every=10)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=p["seq_len"],
                      global_batch=p["global_batch"])
    summary = train(cfg, tcfg, dcfg)
    print("summary:", summary)
    assert summary["final_loss"] < summary["first_loss"]


if __name__ == "__main__":
    main()
