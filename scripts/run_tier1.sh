#!/usr/bin/env bash
# Tier-1 verification, reproducible on CPU-only boxes.
#
# The multi-device tests (tests/test_distributed.py, the compressed
# eigen-step check in tests/test_perf_variants.py) run their mesh code in
# subprocesses; DIST_SUBPROCESS_XLA_FLAGS pins those subprocesses to 8
# forced host devices. The pin must NOT be exported as XLA_FLAGS to the
# main pytest process: the dry-run contract requires the main process to
# keep seeing exactly 1 device
# (tests/test_distributed.py::test_main_process_sees_one_device), and
# repro.launch.dryrun forces its own 512-device flag in-process.
set -euo pipefail

cd "$(dirname "$0")/.."

export DIST_SUBPROCESS_XLA_FLAGS="--xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"
