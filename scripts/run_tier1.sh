#!/usr/bin/env bash
# Tier-1 verification, reproducible on CPU-only boxes.
#
# The multi-device tests (tests/test_distributed.py, the compressed
# eigen-step check in tests/test_perf_variants.py) run their mesh code in
# subprocesses; DIST_SUBPROCESS_XLA_FLAGS pins those subprocesses to 8
# forced host devices. The pin must NOT be exported as XLA_FLAGS to the
# main pytest process: the dry-run contract requires the main process to
# keep seeing exactly 1 device
# (tests/test_distributed.py::test_main_process_sees_one_device), and
# repro.launch.dryrun forces its own 512-device flag in-process.
#
# Pass 2 re-runs the `disk`-marked subset (SAFS page-file tests) inside a
# freshly-created bounded TMPDIR so page files land on a throwaway mount
# point and their total footprint is reported + reclaimed even if a test
# aborts mid-write (the per-test guard is conftest.disk_tmp).
set -euo pipefail

cd "$(dirname "$0")/.."

export DIST_SUBPROCESS_XLA_FLAGS="--xla_force_host_platform_device_count=8"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# pass 1 deselects the disk subset — it runs once, in pass 2's bounded
# TMPDIR (the plain ROADMAP command `python -m pytest -x -q` still runs
# everything, so the disk tests stay part of the tier-1 contract)
python -m pytest -x -q -m "not disk" "$@"

DISK_TMP="$(mktemp -d -t tier1_disk.XXXXXX)"
trap 'rm -rf "$DISK_TMP"' EXIT
echo "== disk-marked subset (TMPDIR=$DISK_TMP) =="
TMPDIR="$DISK_TMP" python -m pytest -x -q -m disk
echo "disk subset TMPDIR footprint: $(du -sh "$DISK_TMP" | cut -f1)"

# Smoke-sized SAFS I/O-path benchmark: refreshes results/BENCH_safs.json
# (pages/s at 4 KiB / 64 KiB, prefetch overlap fraction, write-behind
# queue depth, reorth page-cache hit rate vs LRU-only) so the perf
# trajectory is tracked from PR 3 onward.
echo "== bench_safs smoke (results/BENCH_safs.json) =="
TMPDIR="$DISK_TMP" python benchmarks/bench_safs.py --smoke

# Smoke-sized subspace-pass-fusion I/O bench (PR 5): byte-exact
# reads-per-expansion and reads-per-restart, fused vs unfused, archived in
# results/BENCH_subspace_io.json. The bench self-validates (validate():
# non-zero exit on missing fields, a fused/unfused expansion read ratio
# above 0.6, a restart compression that re-reads the subspace, or
# fused-vs-unfused spectrum parity worse than rtol 1e-5).
echo "== bench_subspace_io smoke (results/BENCH_subspace_io.json) =="
TMPDIR="$DISK_TMP" python benchmarks/bench_subspace_io.py --smoke

# Smoke-sized end-to-end sharded eigensolve (PR 4): core restart loop
# driving the fused dist step on a forced 8-device mesh. The bench
# self-validates (non-zero exit when parity fails); the explicit check
# below additionally fails the tier if the archived JSON is missing the
# parity / eigenvalue / pod-compressed fields.
echo "== bench_dist_e2e smoke (results/BENCH_dist_e2e.json) =="
python benchmarks/bench_dist_e2e.py --smoke
python - <<'EOF'
import json
from benchmarks.bench_dist_e2e import validate
with open("results/BENCH_dist_e2e.json") as f:
    metrics = json.load(f)
validate(metrics)
print("BENCH_dist_e2e.json: parity/eigenvalue fields present, "
      f"max_rel_err={metrics['parity']['max_rel_err']:.3e}")
EOF

# Smoke-sized solver-family comparison (PR 6): Krylov–Schur vs LOBPCG
# behind `core.solver.solve` on the same safs-backed store —
# bytes-per-converged-pair, streamed-pass accounting, spectrum parity
# (KS vs LOBPCG, and LOBPCG safs vs RAM), archived in
# results/BENCH_solver_family.json. The bench self-validates; the explicit
# check below re-gates the archived JSON (required fields + parity rtol).
echo "== bench_eigen solver-family smoke (results/BENCH_solver_family.json) =="
TMPDIR="$DISK_TMP" python benchmarks/bench_eigen.py --smoke
python - <<'EOF'
import json
from benchmarks.bench_eigen import validate
with open("results/BENCH_solver_family.json") as f:
    metrics = json.load(f)
validate(metrics)
fam = metrics["family"]
print("BENCH_solver_family.json: both methods converged, "
      f"ks-vs-lobpcg rel_err={fam['spectrum_max_rel_err']:.3e}, "
      f"lobpcg safs-vs-ram rel_err={fam['lobpcg_safs_vs_ram_rel_err']:.3e}")
EOF

# Observability smoke (PR 7): the full out-of-core example with span
# tracing on, gated on the machine-readable report validator (schema,
# non-zero span count, non-negative durations, overlap fractions in
# [0,1], and — on a lossless trace — byte-exact reconciliation of the
# pass.subspace span bytes against the store's IOStats pass counters).
echo "== obs trace smoke (ooc_lanczos --trace + repro.obs.report --validate) =="
TMPDIR="$DISK_TMP" python examples/ooc_lanczos.py --n 2000 --nnz 24000 \
    --trace "$DISK_TMP/ooc_trace.jsonl"
python -m repro.obs.report "$DISK_TMP/ooc_trace.jsonl" --validate

# Fault-tolerance smoke (PR 8): kill the out-of-core solve mid-flight
# through the real SIGTERM path (PreemptionGuard → boundary checkpoint →
# SolveSuspended → exit 0 with a resume hint), then resume from the
# committed checkpoint into a fresh SAFS root. The resume run's built-in
# ram-parity assert (rtol 1e-5) is the gate that the interrupted solve
# converged to the same spectrum.
echo "== fault-tolerance smoke (suspend via SIGTERM → resume, parity) =="
FT_CK="$DISK_TMP/ft_smoke_ck"
FT_OUT="$(TMPDIR="$DISK_TMP" python examples/ooc_lanczos.py --n 2000 \
    --nnz 24000 --checkpoint "$FT_CK" --preempt-after 2)"
echo "$FT_OUT"
grep -q "solve suspended at restart" <<<"$FT_OUT"
TMPDIR="$DISK_TMP" python examples/ooc_lanczos.py --n 2000 --nnz 24000 \
    --resume "$FT_CK"

# Serving smoke (PR 9): a 3-job mixed-priority queue (eigsh + lobpcg +
# spectral-cluster) through the real CLI against ONE shared SafsBackend
# under one arbiter-split device budget, on the bounded TMPDIR. The CLI
# exits nonzero unless `serve.validate_report` passes: queue drained,
# zero lost jobs, per-namespace physical byte sums reconciling EXACTLY
# against the backend's global IOStats.
echo "== serve smoke (repro.launch.serve --jobs, report validation) =="
cat > "$DISK_TMP/serve_jobs.json" <<'JOBS'
[{"job_id": "embed",   "kind": "eigsh",   "n": 600, "nnz": 6000, "nev": 4,
  "tol": 1e-6, "max_iters": 80},
 {"job_id": "pcg",     "kind": "lobpcg",  "n": 400, "nnz": 4000, "nev": 3,
  "tol": 1e-5, "max_iters": 60, "priority": 1},
 {"job_id": "cluster", "kind": "cluster", "n": 600, "k_classes": 3,
  "nev": 3, "tol": 1e-6, "priority": 2}]
JOBS
TMPDIR="$DISK_TMP" python -m repro.launch.serve \
    --jobs "$DISK_TMP/serve_jobs.json" --out "$DISK_TMP/serve_report.json" \
    --backend safs --root "$DISK_TMP/serve_pages" \
    --ckpt-root "$DISK_TMP/serve_ckpt" \
    --device-budget $((8<<20)) --cache-bytes $((4<<20)) --max-concurrent 2

# Integrity smoke (PR 10): flip real bits and prove the stack heals.
# 1. suspend a checkpointed safs solve mid-flight (store now at rest,
#    its state == the newest committed snapshot — the regime where
#    page-level repair is sound);
# 2. corrupt one page of the live store → the scrub CLI detects it and
#    repairs it from the newest *verified* snapshot (exit 0), and a
#    second scrub pass proves the store verifies clean;
# 3. corrupt the newest checkpoint snapshot itself → the resume falls
#    back to the next older verified step, and the example's built-in
#    ram-parity assert (rtol 1e-5) gates the resumed spectrum;
# 4. the resume trace must pass `repro.obs.report --validate`, which now
#    also reconciles the integrity counters against safs.corrupt /
#    safs.scrub / safs.repair trace events.
echo "== integrity smoke (bitflip -> scrub/repair -> fallback resume) =="
IG_ROOT="$DISK_TMP/integ_root"
IG_CK="$DISK_TMP/integ_ck"
IG_OUT="$(TMPDIR="$DISK_TMP" python examples/ooc_lanczos.py --n 2000 \
    --nnz 24000 --root "$IG_ROOT" --checkpoint "$IG_CK" --preempt-after 2)"
grep -q "solve suspended at restart" <<<"$IG_OUT"
python - "$IG_ROOT/pages" "$IG_CK/pages" <<'EOF'
import glob, os, sys
from repro.safs import flip_bit
# the victim must be a file the checkpoint snapshot covers (the live
# root also holds matrix-image chunks no snapshot carries)
newest = sorted(glob.glob(sys.argv[2] + "/step_*"))[-1]
covered = {os.path.basename(p)
           for p in glob.glob(os.path.join(newest, "*.pages"))}
victim = sorted(p for p in glob.glob(sys.argv[1] + "/*.pages")
                if os.path.basename(p) in covered)[0]
flip_bit(victim, 0)
print(f"flipped one bit in live store page: {victim}")
EOF
TMPDIR="$DISK_TMP" python -m repro.safs.scrub "$IG_ROOT/pages" \
    --repair-from "$IG_CK/pages"
TMPDIR="$DISK_TMP" python -m repro.safs.scrub "$IG_ROOT/pages"
python - "$IG_CK/pages" <<'EOF'
import glob, os, sys
from repro.safs import flip_bit
snaps = sorted(glob.glob(sys.argv[1] + "/step_*"))
victim = sorted(glob.glob(os.path.join(snaps[-1], "*.pages")))[0]
flip_bit(victim, 0)
print(f"corrupted newest snapshot: {victim}")
EOF
TMPDIR="$DISK_TMP" python examples/ooc_lanczos.py --n 2000 --nnz 24000 \
    --resume "$IG_CK" --trace "$DISK_TMP/integ_trace.jsonl"
# the corrupt newest snapshot must have been *skipped*, not restored
grep -q "ckpt.corrupt_snapshot" "$DISK_TMP/integ_trace.jsonl"
python -m repro.obs.report "$DISK_TMP/integ_trace.jsonl" --validate
