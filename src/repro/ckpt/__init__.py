"""repro.ckpt — atomic checkpoints + eigensolve suspend/resume.

`checkpoint` holds the storage primitives (atomic tree manifests, SAFS
page snapshots, stale-tmp GC); `solver` the eigensolve-facing layer
(restart-boundary snapshots, preemption suspend, bit-identical resume).
"""
from repro.ckpt.checkpoint import (AsyncWriter, gc_old, latest_step,
                                   restore, restore_safs, save, save_safs,
                                   valid_steps)
from repro.ckpt.solver import (CheckpointPolicy, ResumeState,
                               SolveCheckpointer, SolveSuspended)

__all__ = [
    "AsyncWriter", "gc_old", "latest_step", "restore", "restore_safs",
    "save", "save_safs", "valid_steps",
    "CheckpointPolicy", "ResumeState", "SolveCheckpointer",
    "SolveSuspended",
]
