"""repro.ckpt"""
