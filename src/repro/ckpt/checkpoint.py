"""Checkpoint/restart: atomic manifest + per-array storage + elastic reshard.

Fault-tolerance contract (DESIGN.md §6):
  * a checkpoint is VALID iff its manifest exists — arrays are written to a
    tmp dir first, manifest last, then an atomic rename; a crash mid-write
    leaves the previous checkpoint untouched;
  * `latest_step` scans for the newest valid checkpoint (restart after
    preemption / node failure);
  * arrays are stored logically (full, unsharded view in this emulation;
    on a real pod each host writes its shard files and the manifest stores
    the global shape + sharding) — restore() re-shards onto whatever mesh
    the restarted job has (`elastic` = device count may change);
  * eigensolver restart state (locked Ritz pairs + H + current block) is a
    few MB even for billion-vertex problems — the Krylov-restart
    compression IS the checkpoint compression (paper §3.4 observation).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
import urllib.parse
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


class CorruptSnapshotError(RuntimeError):
    """A committed page snapshot failed content verification (bit-rot or
    a torn copy in the checkpoint itself). The resume path treats it like
    an orphan: fall back to the next-older valid step."""


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(root: str, step: int, tree: Any, *, extra: dict | None = None) -> str:
    """Write checkpoint atomically; returns final path."""
    final = os.path.join(root, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _flatten_with_paths(tree)

    def encode(a):
        a = np.asarray(a)
        # npz can't store ml_dtypes (bf16, fp8); store the raw bits
        if a.dtype.name == "bfloat16":
            return a.view(np.uint16)
        if a.dtype.itemsize == 1 and a.dtype.kind == "V":
            return a.view(np.uint8)
        return a

    arrays = {f"a{i}": encode(leaf) for i, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def valid_steps(root: str) -> list[int]:
    """All committed checkpoint steps under root, ascending. A step is
    committed iff its final dir exists with a manifest; `.tmp` dirs (a
    crash mid-save) are never valid."""
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, MANIFEST)):
                steps.append(int(d.split("_")[1]))
    return sorted(steps)


def latest_step(root: str, *, gc_stale_tmp: bool = True,
                tmp_grace_seconds: float = 3600.0) -> int | None:
    """Newest committed checkpoint step (None if no valid checkpoint).

    `step_*.tmp` dirs are a crash mid-`save` — never valid, and left
    behind forever by a killed writer. The restart path is the natural
    place to reclaim them: any tmp older than `tmp_grace_seconds` is
    removed (the grace keeps a *live* writer's in-flight tmp safe — e.g.
    an AsyncWriter in another process of an elastic restart)."""
    if not os.path.isdir(root):
        return None
    if gc_stale_tmp:
        now = time.time()
        for d in os.listdir(root):
            if not (d.startswith("step_") and d.endswith(".tmp")):
                continue
            p = os.path.join(root, d)
            try:
                age = now - os.path.getmtime(p)
            except OSError:
                continue        # raced with its writer's rename/cleanup
            if age >= tmp_grace_seconds:
                shutil.rmtree(p, ignore_errors=True)
    steps = valid_steps(root)
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any, *, shardings: Any = None
            ) -> tuple[Any, dict]:
    """Restore into the structure of `like`; optionally re-shard (elastic).

    `shardings` mirrors `like` (or a single sharding applied to all leaves).
    """
    path = os.path.join(root, f"step_{step:010d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    names, leaves, treedef = _flatten_with_paths(like)
    if names != manifest["names"]:
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(names) ^ set(manifest['names'])}")
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None and not hasattr(shardings, "spec")
                    else [shardings] * len(leaves))
    for i, leaf in enumerate(leaves):
        arr = z[f"a{i}"]
        want = manifest["dtypes"][i]
        if want == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if shard_leaves[i] is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        else:
            arr = jnp.asarray(arr)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]


def gc_old(root: str, keep: int = 3) -> None:
    """Keep the newest `keep` valid checkpoints."""
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(root, d, MANIFEST)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:010d}"), ignore_errors=True)


# -------------------------------------------------------- SAFS page snapshots
def save_safs(root: str, step: int, store, *, extra: dict | None = None
              ) -> str:
    """Snapshot a safs-backed TieredStore's page files — no RAM round-trip.

    The subspace already lives on disk as SAFS page files (§3.4.1), so the
    checkpoint is a flush (journaled write-back of dirty pages) plus a
    kernel-side file copy (`shutil.copyfile` → copy_file_range/sendfile on
    Linux) of each page file and its sidecars (shape metadata AND the
    checksum block — the snapshot stays self-verifying) into the
    checkpoint dir. The manifest additionally records a sha256 content
    hash per page file, so `verify_safs_snapshot` can prove a snapshot
    clean before it is trusted as a resume/repair source. The arrays are
    never assembled in host memory. Same atomic-manifest contract as
    `save` (tmp dir, manifest last, atomic rename); use a separate
    checkpoint root from tree checkpoints — `restore` and `restore_safs`
    are not interchangeable.
    """
    from repro.core.tiered import DEVICE
    from repro.safs.backend import SafsBackend
    backend = getattr(store, "backend", store)
    if not isinstance(backend, SafsBackend):
        raise TypeError("save_safs needs a safs-backed store; got "
                        f"{type(backend).__name__}")
    # Device-tier entries with no current host copy (the newest subspace
    # block is pinned on device per §3.4.4) must be written through first,
    # or the snapshot would silently miss them. Residency is unchanged;
    # the entry just becomes clean-with-host-copy, like after a promote.
    sync = getattr(store, "sync_device_entries", None)
    if sync is not None:
        sync()
    else:       # a bare backend passed as `store` has no device tier
        for e in getattr(store, "_entries", {}).values():
            if e.tier == DEVICE and (e.dirty or not e.has_host):
                backend.store(e.data_id, np.asarray(e.device_val))
                e.has_host, e.dirty = True, False
    backend.flush()
    final = os.path.join(root, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    # the store's OWN ids, not backend.data_ids(): on a shared multi-
    # tenant backend a session's checkpoint must not capture (or later
    # restore over) other sessions' page files
    own_ids = getattr(store, "data_ids", None)
    data_ids = own_ids() if own_ids is not None else backend.data_ids()
    hashes = {}
    for data_id in data_ids:
        pf = backend.pagefile(data_id)
        for src in (pf.path, pf.path + ".meta", pf.path + ".sums"):
            if os.path.exists(src):
                shutil.copyfile(src,
                                os.path.join(tmp, os.path.basename(src)))
        # content hash of the COPY — what a later resume must verify
        # before trusting this snapshot as a repair source
        hashes[data_id] = _sha256_file(
            os.path.join(tmp, os.path.basename(pf.path)))
    manifest = {"step": step, "kind": "safs_pages", "data_ids": data_ids,
                "page_size": backend.page_size, "hashes": hashes,
                "extra": extra or {}}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def verify_safs_snapshot(path: str) -> list[str]:
    """Content-verify a committed page snapshot against its manifest:
    every data_id's page file present (with metadata) and matching its
    recorded sha256. Returns the list of problems (empty == verified).
    Legacy manifests without hashes verify on presence alone."""
    problems: list[str] = []
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        return [f"unreadable manifest: {e}"]
    if manifest.get("kind") != "safs_pages":
        return [f"not a safs page snapshot: {path}"]
    hashes = manifest.get("hashes") or {}
    for data_id in manifest.get("data_ids", []):
        fp = os.path.join(path,
                          urllib.parse.quote(data_id, safe="") + ".pages")
        if not (os.path.exists(fp) and os.path.exists(fp + ".meta")):
            problems.append(f"missing page file for {data_id!r}")
            continue
        want = hashes.get(data_id)
        if want is not None and _sha256_file(fp) != want:
            problems.append(f"content hash mismatch for {data_id!r}")
    return problems


def restore_safs(root: str, step: int, dest_root: str, *,
                 verify: bool = True):
    """Rehydrate a page snapshot into a fresh SafsBackend at dest_root.

    Copies the page files back (kernel-side) and reopens them; returns
    (backend, extra). Pages are faulted in lazily through the page cache on
    first access — restore itself still does no RAM round-trip. With
    `verify` (default) the snapshot's content hashes are checked first and
    a mismatch raises `CorruptSnapshotError` instead of rehydrating rot.
    """
    from repro.safs.backend import SafsBackend
    path = os.path.join(root, f"step_{step:010d}")
    if verify:
        problems = verify_safs_snapshot(path)
        if problems:
            raise CorruptSnapshotError("; ".join(problems))
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    if manifest.get("kind") != "safs_pages":
        raise ValueError(f"not a safs page snapshot: {path}")
    os.makedirs(dest_root, exist_ok=True)
    for fname in os.listdir(path):
        if (fname.endswith(".pages") or fname.endswith(".pages.meta")
                or fname.endswith(".pages.sums")):
            shutil.copyfile(os.path.join(path, fname),
                            os.path.join(dest_root, fname))
    backend = SafsBackend(dest_root, page_size=manifest["page_size"])
    return backend, manifest["extra"]


class AsyncWriter:
    """Overlap checkpoint writes with compute (one in flight at a time)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def submit(self, root: str, step: int, tree: Any,
               extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def _run():
            self.last_path = save(root, step, host_tree, extra=extra)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
