"""Checkpoint-suspend/resume of long eigensolves (robustness layer).

A billion-node spectral solve is hours of wall clock (paper §4) — it WILL
be preempted, and an SSD box mid-solve WILL occasionally lose power. The
paper's own observation (§3.4) makes checkpointing cheap: the thick-restart
compression already shrinks the live state to k·n vectors plus a few-MB
projected problem, so the restart boundary is the natural (and only)
snapshot point — nothing in flight, subspace freshly compressed.

One checkpoint = one composite directory under `CheckpointPolicy.root`:

    root/pages/step_XXXXXXXXXX/   SAFS page snapshot of the subspace
                                  (`ckpt.save_safs`: flush + kernel-side
                                  file copy, no RAM round-trip) — written
                                  FIRST; absent for the ram backend, whose
                                  blocks embed in the state arrays;
    root/state/step_XXXXXXXXXX/   the solver's small dense state (H, Ritz
                                  values/residuals, coupling block, RNG-
                                  free counters) via `ckpt.save`'s atomic
                                  manifest — written LAST, so the state
                                  manifest IS the commit point.

A crash between the two leaves an orphaned page snapshot; `load` skips any
state-less step and falls back to the previous committed one — the
kill-matrix test in tests/test_faults.py drives a `CrashPoint` into every
window (`ckpt.save` site) to prove it.

Resume is a *bit-identical continuation*: the subspace blocks, H, the
in-flight block q and every counter are restored exactly, so a resumed
solve walks the same restart trajectory as an uninterrupted one (spectrum
parity at rtol 1e-5 is then a regression test, not a hope) and costs at
most the one restart that was in flight when the plug was pulled
(`every_restarts=1`).

`ft.PreemptionGuard` integration: pass the guard in the policy; at each
restart boundary the checkpointer finishes the snapshot and raises
`SolveSuspended` when a SIGTERM arrived mid-restart — callers exit 0 and
rerun with `solve(..., resume=root)`.

Fault-plan integration: when the store's backend carries a
`safs.faults.FaultPlan`, the checkpointer consults it at its own two
sites — `solve.restart` (the boundary itself) and `ckpt.save` (between
the page snapshot and the state commit) — so one seeded plan scripts a
whole solve's failure schedule end to end.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ck
from repro.obs import trace


@dataclasses.dataclass
class CheckpointPolicy:
    """When/where to checkpoint a solve.

    root: composite checkpoint directory (pages/ + state/ subtrees).
    every_restarts: snapshot cadence in restart boundaries (1 = every
        boundary — the ≤1-extra-restart guarantee; 0 disables periodic
        snapshots, leaving only preemption-triggered ones).
    keep: committed checkpoints retained per subtree (`ckpt.gc_old`).
    guard: an `ft.PreemptionGuard` (or anything with `requested()`);
        when it fires, the next boundary checkpoints then raises
        `SolveSuspended`.
    """
    root: str
    every_restarts: int = 1
    keep: int = 3
    guard: Optional[object] = None


class SolveSuspended(RuntimeError):
    """A solve checkpointed and stopped on preemption — not a failure.
    Carries the committed step and the checkpoint root; rerun with
    `solve(..., resume=root)` to continue."""

    def __init__(self, step: int, root: str):
        super().__init__(
            f"solve suspended at step {step}; resume from {root!r}")
        self.step = step
        self.root = root


@dataclasses.dataclass
class ResumeState:
    """What `SolveCheckpointer.load` hands back to the algorithm: the
    committed step, the rebuilt out-of-core MultiVectors (already living
    in the caller's store) and the small dense state."""
    step: int
    mvs: Dict[str, Any]
    arrays: Dict[str, np.ndarray]
    extra: Dict[str, Any]


def _state_root(root: str) -> str:
    return os.path.join(root, "state")


def _pages_root(root: str) -> str:
    return os.path.join(root, "pages")


def _load_tree(root: str, step: int) -> tuple:
    """Read one committed `ckpt.save` checkpoint back as a nested dict
    (manifest names are '/'-joined paths) — no `like` template needed,
    unlike `ckpt.restore`: the resuming solver does not have the solved
    shapes yet, the checkpoint does."""
    path = os.path.join(root, f"step_{step:010d}")
    with open(os.path.join(path, ck.MANIFEST)) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    tree: Dict[str, Any] = {}
    for i, name in enumerate(manifest["names"]):
        parts = name.split("/")
        d = tree
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = z[f"a{i}"]
    return tree, manifest["extra"]


def _snapshot_block(snap_dir: str, data_id: str,
                    integrity=None) -> np.ndarray:
    """Assemble one subspace block straight out of a page snapshot's
    PageFile (lazy page reads — the block never existed in the snapshot
    as a contiguous array). Reads verify against the snapshot's copied
    checksum block: a rotten snapshot page raises CorruptPageError here
    rather than resuming garbage (normally pre-empted by the manifest
    hash check in `load`, which falls back to an older step)."""
    import urllib.parse

    from repro.safs.pagefile import PageFile
    path = os.path.join(snap_dir,
                        urllib.parse.quote(data_id, safe="") + ".pages")
    pf = PageFile(path, integrity=integrity)
    try:
        return pf.assemble(pf.read_pages_batch(pf.page_indices()))
    finally:
        pf.close()


def _is_safs(store) -> bool:
    from repro.safs.backend import SafsBackend
    return isinstance(getattr(store, "backend", None), SafsBackend)


class SolveCheckpointer:
    """The solver-side half of checkpoint/suspend/resume.

    Algorithms call `maybe_checkpoint(store, step, state_fn)` at each
    restart boundary with a zero-argument `state_fn` returning

        {"mvs":    {slot: MultiVector, ...},     # out-of-core state
         "arrays": {name: ndarray, ...},         # small dense state
         "extra":  {name: json-scalar, ...}}     # counters/flags

    — `state_fn` only runs when a snapshot is actually due. `load(store)`
    rebuilds the newest committed checkpoint into `store` (any backend:
    safs snapshots rehydrate block-by-block from the page files, ram
    checkpoints embed the blocks in the state arrays) and refuses a
    checkpoint written by a different method or solve shape (`params`
    mismatch) instead of resuming garbage.
    """

    def __init__(self, policy: Optional[CheckpointPolicy], *, method: str,
                 resume_from: Optional[str] = None,
                 params: Optional[dict] = None):
        if policy is None and resume_from is None:
            raise ValueError("need a CheckpointPolicy and/or resume root")
        if policy is None:
            # resume-only: continue WITHOUT further checkpoints
            policy = CheckpointPolicy(root=resume_from, every_restarts=0)
        self.policy = policy
        self.method = method
        self.resume_from = resume_from
        self.params = dict(params or {})
        self.saved_steps: List[int] = []
        self.resumed_step: Optional[int] = None

    # ------------------------------------------------------------- plumbing
    @staticmethod
    def _plan(store):
        return getattr(getattr(store, "backend", None), "faults", None)

    def _preempted(self) -> bool:
        g = self.policy.guard
        return g is not None and bool(g.requested())

    # ----------------------------------------------------------------- save
    def maybe_checkpoint(self, store, step: int,
                         state_fn: Callable[[], dict]) -> bool:
        """Snapshot at a restart boundary when due (cadence) or demanded
        (preemption). Raises `SolveSuspended` after a preemption-triggered
        snapshot commits. Returns whether a snapshot was written."""
        plan = self._plan(store)
        if plan is not None:
            # the boundary itself is an injectable site: a "crash" rule
            # here simulates a kill between restarts (no snapshot written)
            plan.check("solve.restart", step=step)
        preempt = self._preempted()
        every = self.policy.every_restarts
        due = every > 0 and step % every == 0
        if not (due or preempt):
            return False
        self.save(store, step, state_fn())
        if preempt:
            raise SolveSuspended(step, self.policy.root)
        return True

    def save(self, store, step: int, state: dict) -> None:
        mvs: Dict[str, Any] = state.get("mvs", {})
        arrays: Dict[str, Any] = dict(state.get("arrays", {}))
        extra: Dict[str, Any] = dict(state.get("extra", {}))
        safs = _is_safs(store)
        mv_meta = {
            slot: {"name": mv.name, "n": int(mv.n),
                   "widths": [int(w) for w in mv.block_widths()],
                   "scales": [float(b.scale) for b in mv._blocks],
                   "group_size": int(mv.group_size), "impl": str(mv.impl)}
            for slot, mv in mvs.items()}
        with trace.span("ckpt.save", step=step, backend=(
                "safs" if safs else "ram")) as sp:
            tree: Dict[str, Any] = {"arrays": arrays}
            if safs:
                # pages FIRST: an orphaned page snapshot is harmless, a
                # state manifest pointing at missing pages would not be
                ck.save_safs(_pages_root(self.policy.root), step, store,
                             extra={"mv_meta": mv_meta})
                plan = self._plan(store)
                if plan is not None:
                    # the crash window between snapshot halves
                    plan.check("ckpt.save", step=step)
            else:
                # ram backend: blocks are host arrays — embed them (raw
                # store bytes; lazy scales live in mv_meta for both paths)
                tree["blocks"] = {
                    slot: {f"b{i}": np.asarray(store.get(name))
                           for i, name in enumerate(mv.block_names())}
                    for slot, mv in mvs.items()}
            ck.save(_state_root(self.policy.root), step, tree, extra={
                "method": self.method, "params": self.params,
                "backend": "safs" if safs else "ram",
                "mv_meta": mv_meta, "solver_extra": extra,
                "io_stats": store.stats.as_dict(),
            })
            sp.set(committed=True)
        self.saved_steps.append(step)
        if self.policy.keep:
            ck.gc_old(_state_root(self.policy.root), keep=self.policy.keep)
            if safs:
                ck.gc_old(_pages_root(self.policy.root),
                          keep=self.policy.keep)

    # ----------------------------------------------------------------- load
    def load(self, store) -> Optional[ResumeState]:
        """Rebuild the newest committed checkpoint into `store`; None when
        not resuming or the root holds no committed checkpoint yet (a
        crash before the first snapshot — the solve just starts over)."""
        if self.resume_from is None:
            return None
        root = self.resume_from
        sroot = _state_root(root)
        # latest_step (not valid_steps) on the commit subtree: the restart
        # path doubles as the stale-tmp garbage collector
        if ck.latest_step(sroot) is None:
            return None
        for step in reversed(ck.valid_steps(sroot)):
            tree, extra = _load_tree(sroot, step)
            if extra.get("method") != self.method:
                raise ValueError(
                    f"checkpoint at {root!r} was written by method "
                    f"{extra.get('method')!r}, not {self.method!r}")
            saved = extra.get("params", {})
            clash = {k: (saved.get(k), v) for k, v in self.params.items()
                     if k in saved and saved[k] != v}
            if clash:
                raise ValueError(
                    f"checkpoint params mismatch at step {step}: {clash}")
            snap = None
            if extra.get("backend") == "safs":
                snap = os.path.join(_pages_root(root), f"step_{step:010d}")
                if not os.path.exists(os.path.join(snap, ck.MANIFEST)):
                    continue    # orphan: state committed, pages gc'd/lost
                problems = ck.verify_safs_snapshot(snap)
                if problems:
                    # corrupt/torn snapshot: NEVER a resume source — fall
                    # back to the next-older verified step, same as an
                    # orphan (the solve re-pays at most those restarts)
                    trace.event("ckpt.corrupt_snapshot", step=step,
                                problems=list(problems))
                    continue
            mvs = self._rebuild_mvs(store, extra["mv_meta"], tree, snap)
            trace.event("ckpt.resume", step=step, method=self.method,
                        backend=extra.get("backend"))
            self.resumed_step = step
            return ResumeState(step=step, mvs=mvs,
                               arrays=tree.get("arrays", {}),
                               extra={**extra.get("solver_extra", {}),
                                      "io_stats": extra.get("io_stats")})
        return None

    @staticmethod
    def _rebuild_mvs(store, mv_meta: dict, tree: dict,
                     snap: Optional[str]) -> Dict[str, Any]:
        from repro.core.multivector import MultiVector
        mvs: Dict[str, Any] = {}
        for slot, meta in mv_meta.items():
            mv = MultiVector(store, meta["n"], name=meta["name"],
                             group_size=meta["group_size"],
                             impl=meta["impl"])
            resolve = getattr(store, "resolve_data_id", lambda n: n)
            for i, _w in enumerate(meta["widths"]):
                if snap is not None:
                    # the snapshot's page files are keyed by the store-
                    # qualified id (a namespaced session prefixes names)
                    arr = _snapshot_block(
                        snap, resolve(f"{meta['name']}/b{i}"),
                        integrity=getattr(getattr(store, "backend", None),
                                          "integrity", None))
                else:
                    arr = tree["blocks"][slot][f"b{i}"]
                mv.append_block(jnp.asarray(arr, jnp.float32),
                                pin_recent=False)
                # resumed blocks start on the slow tier, like the live
                # solve's history blocks; the solver re-promotes what it
                # actually touches
                store.demote(mv._block_name(i))
                mv._blocks[i].scale = float(meta["scales"][i])
            mvs[slot] = mv
        return mvs
