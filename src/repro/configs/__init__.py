"""Config registry: assigned architectures + the paper's own graph configs."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applicable
from repro.configs.grok_1_314b import CONFIG as grok_1_314b
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.hubert_xlarge import CONFIG as hubert_xlarge
from repro.configs.llama_3_2_vision_90b import CONFIG as llama_3_2_vision_90b
from repro.configs.yi_9b import CONFIG as yi_9b
from repro.configs.qwen2_1_5b import CONFIG as qwen2_1_5b
from repro.configs.h2o_danube_3_4b import CONFIG as h2o_danube_3_4b
from repro.configs.mistral_large_123b import CONFIG as mistral_large_123b
from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b
from repro.configs.mamba2_780m import CONFIG as mamba2_780m
from repro.configs import flasheigen

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        grok_1_314b, arctic_480b, hubert_xlarge, llama_3_2_vision_90b,
        yi_9b, qwen2_1_5b, h2o_danube_3_4b, mistral_large_123b,
        recurrentgemma_2b, mamba2_780m,
    ]
}

GRAPHS = flasheigen.GRAPHS


def get(name: str) -> ArchConfig:
    return ARCHS[name]


def reduced(name: str) -> ArchConfig:
    """Smoke-test-scale config of the same family (CPU, one step)."""
    c = ARCHS[name]
    pat = len(c.pattern)
    kv = max(1, min(c.n_kv_heads, 2))
    heads = max(kv, 4 - (4 % kv))
    return dataclasses.replace(
        c,
        name=c.name + "-reduced",
        n_layers=pat + min(2, max(1, c.n_layers % pat or 2)),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if c.d_ff == 0 else 128,
        moe_d_ff=0 if c.moe_d_ff == 0 else 96,
        vocab_size=256,
        n_experts=0 if c.n_experts == 0 else 4,
        capacity_factor=8.0,   # no token dropping at smoke scale →
        # prefill/decode exactly match the full forward (capacity dropping
        # is order-dependent and intentionally kept at production scale)
        window=32,
        ssm_state=0 if c.ssm_state == 0 else 16,
        ssm_head_dim=16,
        ssm_chunk=8,
        rglru_width=0 if c.rglru_width == 0 else 64,
        n_frontend_tokens=0 if c.n_frontend_tokens == 0 else 16,
        param_dtype="float32",
        use_fsdp=False,
        remat=False,
    )


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ARCHS", "GRAPHS", "get", "reduced"]
