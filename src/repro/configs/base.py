"""Architecture config schema + input-shape registry.

One ArchConfig per assigned architecture (see configs/<id>.py), plus the
paper's own `flasheigen` graph configs. `reduced()` produces the smoke-test
scale of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads
    # attention
    attn_kind: str = "full"          # full | swa
    window: int = 4096
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    # layer pattern, repeated to n_layers (remainder applied unscanned)
    pattern: Tuple[str, ...] = ("attn",)   # attn | swa | cross | ssm | rglru
    # moe
    n_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0                # expert hidden size (0 → d_ff)
    dense_residual: bool = False     # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_conv: int = 4
    # rglru
    rglru_width: int = 0             # 0 → d_model
    # frontend stubs
    frontend: str | None = None      # patch | audio | None
    n_frontend_tokens: int = 0       # image tokens (vlm)
    # norm / act
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    glu: bool = True
    tie_embeddings: bool = False
    # numerics / distribution
    param_dtype: str = "bfloat16"
    use_fsdp: bool = False           # shard params over 'data' too (big archs)
    remat: bool = True
    # long-context eligibility (sub-quadratic attention)
    subquadratic: bool = False
    decoder: bool = True             # False → encoder-only (no decode shapes)
    # scan unrolling (1 = while-loop; n_super = fully unrolled — used by the
    # dry-run's FLOP-accounting lowering, where while bodies would be
    # counted once by HloCostAnalysis)
    scan_unroll: int = 1
    # §Perf hillclimb knobs (baseline = paper-faithful-naive = all off)
    moe_decode_regroup: bool = False   # single-group MoE dispatch at S==1
    prefill_last_only: bool = False    # prefill emits last-position logits
    shard_cache_seq: bool = False      # seq-shard KV cache when kv∤model
    bf16_residual: bool = False        # pin residual stream to param dtype
    # (baseline leaks f32 from attention einsums → 2× TP-psum/act bytes)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        ffw = d * self.d_ff * (3 if self.glu else 2)
        dff_e = self.moe_d_ff or self.d_ff
        moe = self.n_experts * d * dff_e * (3 if self.glu else 2) \
            + d * self.n_experts
        if self.dense_residual:
            moe += ffw
        d_in = self.ssm_expand * d
        ssm = d * (2 * d_in + 2 * self.ssm_state) + d_in * d \
            + d_in * self.ssm_conv
        rw = self.rglru_width or d
        rglru = 2 * d * rw + rw * d + 3 * rw + rw * self.ssm_conv
        per_layer = {"attn": attn + ffw, "swa": attn + ffw,
                     "cross": attn + ffw,
                     "moe_attn": attn + moe,
                     "ssm": ssm + ffw if self.d_ff else ssm,
                     "rglru": rglru + ffw}
        kinds = [("moe_attn" if self.n_experts and k == "attn" else k)
                 for k in self.pattern]
        full_reps = [per_layer[k] for k in kinds]
        total += self.n_super * sum(full_reps)
        total += sum(full_reps[:self.n_remainder])
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D roofline)."""
        if not self.n_experts:
            return self.param_count()
        dff_e = self.moe_d_ff or self.d_ff
        unused = (self.n_experts - self.top_k) * self.d_model * dff_e \
            * (3 if self.glu else 2)
        return self.param_count() - self.n_layers * unused


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs, with the skip reason."""
    if not cfg.decoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; O(L²) infeasible at 524288"
    return True, ""
