"""The paper's own configs: graph eigenproblems (Table 2 + parameters §4.3).

Each GraphConfig is one dry-run cell for the eigensolver `eigen_step`
(distributed SpMM + CGS2 + CholQR fused, see dist/dspmm.py).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    name: str
    n_vertices: int
    n_edges: int
    block_size: int      # b — paper §4.3 choices
    num_blocks: int      # NB; subspace m = b · NB
    nev: int
    directed: bool = False

    @property
    def subspace(self) -> int:
        return self.block_size * self.num_blocks


GRAPHS = {
    # Table 2 datasets with the paper's §4.3 parameter choices
    "twitter": GraphConfig("twitter", 42_000_000, 1_500_000_000,
                           block_size=4, num_blocks=8, nev=8),
    "friendster": GraphConfig("friendster", 65_000_000, 1_700_000_000,
                              block_size=4, num_blocks=8, nev=8),
    "knn": GraphConfig("knn", 62_000_000, 12_000_000_000,
                       block_size=4, num_blocks=32, nev=8),
    # the billion-node result (Table 3): b=2, NB=2·ev, SVD on directed graph
    "page": GraphConfig("page", 3_400_000_000, 129_000_000_000,
                        block_size=2, num_blocks=16, nev=8, directed=True),
}
