"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]. SWA ⇒ sub-quadratic ⇒ long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    pattern=("swa",), attn_kind="swa", window=4096,
    subquadratic=True,
)
