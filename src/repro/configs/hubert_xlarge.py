"""hubert-xlarge [audio] — encoder-only, w2v2-style backbone
[arXiv:2106.07447; unverified]. Frontend (conv feature extractor) is a STUB:
input_specs() provides precomputed frame embeddings (B, S, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    pattern=("attn",),
    causal=False, decoder=False,
    norm="layernorm", act="gelu", glu=False,
    frontend="audio",
)
