"""llama-3.2-vision-90b [vlm] — cross-attn image layers (1 per 5)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. Vision tower is a STUB:
input_specs() provides precomputed patch embeddings (B, n_img, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    frontend="patch", n_frontend_tokens=1600,
    use_fsdp=True,
)
