"""mamba2-780m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]. Constant-size state ⇒ long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=0, vocab_size=50280, head_dim=64,
    pattern=("ssm",),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
    subquadratic=True,
)
