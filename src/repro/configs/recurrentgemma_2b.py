"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427; hf]. Recurrent state + windowed cache ⇒ long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    pattern=("rglru", "rglru", "swa"), window=2048,
    rglru_width=2560,
    act="gelu", tie_embeddings=True,
    subquadratic=True,
)
