"""FlashEigen-JAX core: out-of-core block eigensolver (the paper's contribution)."""
from repro.core.tiered import (TieredStore, IOStats, DEVICE, HOST,
                               ReadOnlyError)
from repro.core.multivector import MultiVector
from repro.core.stream import SubspacePass
from repro.core.ortho import cholqr, svqb, svqb_transform, bcgs2, ortho_error
from repro.core.operator import (GraphOperator, NormalOperator, DenseOperator,
                                 HvpOperator, LinearOperator,
                                 ShiftInvertOperator, ChebyshevFilterOperator,
                                 estimate_spectral_range, capabilities,
                                 CAP_FUSED_EXPAND, CAP_SPECTRAL_TRANSFORM)
from repro.core.krylov_schur import eigsh
from repro.core.lanczos import lanczos_eigsh
from repro.core.lobpcg import lobpcg
from repro.core.svd import svds, SvdResult
from repro.core.solver import (Solver, SolverContext, register_solver,
                               solve, solver_names)
from repro.core.residuals import EigResult, true_residuals

__all__ = [
    "TieredStore", "IOStats", "DEVICE", "HOST", "ReadOnlyError",
    "MultiVector", "SubspacePass",
    "cholqr", "svqb", "svqb_transform", "bcgs2", "ortho_error",
    "GraphOperator", "NormalOperator", "DenseOperator", "HvpOperator",
    "LinearOperator", "ShiftInvertOperator", "ChebyshevFilterOperator",
    "estimate_spectral_range", "capabilities",
    "CAP_FUSED_EXPAND", "CAP_SPECTRAL_TRANSFORM",
    "eigsh", "lanczos_eigsh", "lobpcg", "svds", "SvdResult",
    "Solver", "SolverContext", "register_solver", "solve", "solver_names",
    "EigResult", "true_residuals",
]
