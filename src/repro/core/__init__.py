"""FlashEigen-JAX core: out-of-core block eigensolver (the paper's contribution)."""
from repro.core.tiered import (TieredStore, IOStats, DEVICE, HOST,
                               ReadOnlyError)
from repro.core.multivector import MultiVector
from repro.core.stream import SubspacePass
from repro.core.ortho import cholqr, svqb, bcgs2, ortho_error
from repro.core.operator import (GraphOperator, NormalOperator, DenseOperator,
                                 HvpOperator, LinearOperator)
from repro.core.krylov_schur import eigsh
from repro.core.lanczos import lanczos_eigsh
from repro.core.svd import svds, SvdResult
from repro.core.residuals import EigResult, true_residuals

__all__ = [
    "TieredStore", "IOStats", "DEVICE", "HOST", "ReadOnlyError",
    "MultiVector", "SubspacePass",
    "cholqr", "svqb", "bcgs2", "ortho_error",
    "GraphOperator", "NormalOperator", "DenseOperator", "HvpOperator",
    "LinearOperator", "eigsh", "lanczos_eigsh", "svds", "SvdResult",
    "EigResult", "true_residuals",
]
