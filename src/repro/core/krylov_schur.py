"""Block Krylov–Schur (thick-restart) eigensolver — the paper's driver.

For symmetric operators the Krylov–Schur method of Stewart [21] reduces to
thick-restart block Lanczos: maintain a Krylov decomposition

    A V = V H + Q S eᵀ_last-block ,   H = Vᵀ A V  (symmetric, m×m)

expand the subspace block-by-block (semi-external SpMM + out-of-core CGS2
reorthogonalization), and at m = b·NB restart by compressing V onto the k
best Ritz vectors (one big out-of-core GEMM, `MultiVector.compress`) with
H collapsing to diag(θ) plus the arrow coupling — which regenerates
automatically because H is recomputed as VᵀAQ each expansion.

I/O discipline (the paper's contribution) is inherited from the substrate:
the subspace lives in the TieredStore host tier, the newest block is pinned
in the device tier, MvTransMv/MvTimesMatAddMv stream in groups, and restart
compression is the only whole-subspace write.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multivector import MultiVector
from repro.core.operator import CAP_FUSED_EXPAND, capabilities
from repro.core.ortho import cholqr, bcgs2
from repro.core.residuals import EigResult, ritz_residual_bounds, sort_ritz
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


def _expand(op, v: MultiVector, q: jnp.ndarray, h: np.ndarray,
            impl: kops.Impl, *, fused_passes: bool = True
            ) -> tuple[jnp.ndarray, np.ndarray, np.ndarray]:
    """One block expansion. Appends q to V; returns (q_next, new H, R_next).

    Every path produces the identical Krylov invariant A·q = V·h + q_next·r
    with h = h1 + h2 (the bcgs2 convention — the second-pass correction
    belongs in the H column, since W = V·(h1+h2) + Q·R is what actually
    holds; the solver used to hand-inline CGS2 here and drop h2):

      * local: semi-external SpMM then `ortho.bcgs2` over the out-of-core
        subspace — two streamed reads of V when fused_passes (each CGS
        pass is one `SubspacePass` read, §3.4.3), four when not;
      * operator-fused (declares the `fused_expand` capability, e.g. the
        sharded `dist.DistOperator`): one combined SpMM+CGS2/CholQR2 step
        over the operator's device-resident subspace shards — V's blocks
        are *not* re-read from the store at all; the MultiVector is the
        spill/restart copy (the paper's "subspace on SSD, recent matrix
        cached in fast memory" split).
    """
    b = q.shape[1]
    v.append_block(q)
    if CAP_FUSED_EXPAND in capabilities(op):
        q_next, h_col, r_next = op.fused_expand(v, q)
    else:
        w = op.matmat(q)                               # semi-external SpMM
        q_next, h_col, r_next = bcgs2(v, w, impl=impl, fused=fused_passes)

    m_old = h.shape[0]
    m_new = m_old + b
    h_new = np.zeros((m_new, m_new), dtype=np.float64)
    h_new[:m_old, :m_old] = h
    col = np.asarray(h_col, dtype=np.float64)
    h_new[:, m_old:] = col
    h_new[m_old:, :] = col.T                            # enforce symmetry
    return q_next, h_new, np.asarray(r_next, dtype=np.float64)


def eigsh(op, nev: int, *, block_size: int = 4, num_blocks: int | None = None,
          tol: float = 1e-6, max_restarts: int = 60, which: str = "LM",
          store: TieredStore | None = None, impl: kops.Impl = "auto",
          group_size: int = 8, seed: int = 0,
          compute_eigenvectors: bool = True, fused_passes: bool = True,
          callback: Callable | None = None,
          checkpointer=None) -> EigResult:
    """Compute `nev` eigenpairs of a symmetric LinearOperator.

    Defaults follow the paper's parameter study (§4.3): block size b,
    num_blocks NB with subspace m = b·NB; NB defaults to 2·ceil(nev/b)+2.

    Pass `store=TieredStore(backend="safs", backend_opts={"root": dir})`
    to keep the subspace in SAFS page files on disk (§3.4.1) instead of
    the default in-RAM emulation — the solver code is backend-agnostic.

    fused_passes=True (default) runs every whole-subspace operation
    through the fused streamed-pass engine (§3.4.3): CGS2 reorthogonali-
    zation in 2 subspace reads per expansion instead of 4, restart
    compression in exactly 1 read regardless of k_keep. fused_passes=
    False keeps the unfused reference path (parity tests, I/O benches).

    checkpointer: a `ckpt.solver.SolveCheckpointer` (normally built by
    `core.solver.solve(..., checkpoint=/resume=)`). Snapshots land at
    restart boundaries — right after thick-restart compression, when the
    live state is exactly the compressed subspace plus H = diag(θ), q and
    r_next (the paper's §3.4 observation: restart compression IS the
    checkpoint compression). Resume restores that state bit-identically
    and continues at the next restart index.
    """
    b = block_size
    if num_blocks is None:
        num_blocks = 2 * (-(-nev // b)) + 2
    num_blocks = max(num_blocks, -(-nev // b) + 2)
    m_max = b * num_blocks
    keep_blocks = max(-(-nev // b) + 1, num_blocks // 2)
    k_keep = min(keep_blocks * b, m_max - b)

    store = store or TieredStore()
    n = op.n

    resume = checkpointer.load(store) if checkpointer is not None else None
    if resume is not None:
        # bit-identical continuation from the last committed restart
        # boundary: same subspace blocks, same H/q/r_next, same counters
        v = resume.mvs["v"]
        h = np.asarray(resume.arrays["h"], np.float64)
        q = jnp.asarray(resume.arrays["q"], jnp.float32)
        r_next = np.asarray(resume.arrays["r_next"], np.float64)
        theta_out = np.asarray(resume.arrays["theta_out"], np.float64)
        res_out = np.asarray(resume.arrays["res_out"], np.float64)
        n_ops = int(resume.extra["n_ops"])
        start_restart = resume.step
    else:
        key = jax.random.PRNGKey(seed)
        q, _ = cholqr(jax.random.normal(key, (n, b), jnp.float32),
                      impl=impl)
        v = MultiVector(store, n, group_size=group_size, impl=impl)
        h = np.zeros((0, 0), dtype=np.float64)
        r_next = np.zeros((b, b), dtype=np.float64)
        n_ops = 0
        theta_out = np.zeros(nev)
        res_out = np.full(nev, np.inf)
        start_restart = 0
    converged = False
    restarts = start_restart

    for restarts in range(start_restart, max_restarts):
        while v.ncols + b <= m_max:
            q, h, r_next = _expand(op, v, q, h, impl,
                                   fused_passes=fused_passes)
            n_ops += 1

        # --- restart: Rayleigh-Ritz on H ---------------------------------
        theta, y = np.linalg.eigh(h)
        order = sort_ritz(theta, which)
        theta, y = theta[order], y[:, order]

        # residual bounds via the coupling S = R_next · y[last block rows]
        s = r_next @ y[-b:, :]
        res = np.linalg.norm(s, axis=0)
        scale = np.maximum(1.0, np.abs(theta))
        ok = res <= tol * scale
        theta_out = theta[:nev].copy()
        res_out = res[:nev].copy()
        if callback is not None:
            # fresh copies: theta_out/res_out are returned in EigResult,
            # so a mutating callback must not be able to corrupt them
            callback(restarts, theta_out.copy(), res_out.copy())
        if bool(ok[:nev].all()):
            converged = True
            break

        # --- thick restart: compress V onto k best Ritz vectors ----------
        # fused: all k_keep/b output blocks from ONE streamed read of V
        yk = jnp.asarray(y[:, :k_keep], jnp.float32)
        v_new = v.compress(yk, [b] * (k_keep // b), fused=fused_passes)
        v.delete()
        v = v_new
        h = np.diag(theta[:k_keep])
        # A V_new = V_new Θ + Q S  with S = r_next @ y_keep[last rows]
        # regenerated automatically on next expansion via VᵀAQ.

        if checkpointer is not None:
            # restart boundary = snapshot point (module docstring); may
            # raise SolveSuspended after committing on preemption
            checkpointer.maybe_checkpoint(store, restarts + 1, lambda: {
                "mvs": {"v": v},
                "arrays": {"h": h, "q": np.asarray(q), "r_next": r_next,
                           "theta_out": theta_out, "res_out": res_out},
                "extra": {"n_ops": n_ops}})

    # --- materialize Ritz vectors: one more streamed pass (the same
    # multi-accumulator engine as restart compression — one read of V) ----
    vec = None
    if compute_eigenvectors:
        theta_full, y_full = np.linalg.eigh(h)
        order = sort_ritz(theta_full, which)
        yk = jnp.asarray(y_full[:, order[:nev]], jnp.float32)
        vec = np.asarray(v.mv_times_mat(yk))

    return EigResult(
        eigenvalues=theta_out, eigenvectors=vec, residuals=res_out,
        n_restarts=restarts, n_ops=n_ops, m_subspace=m_max,
        converged=converged,
        io_stats=store.stats.as_dict() if store else None,
        resumed_step=(checkpointer.resumed_step
                      if checkpointer is not None else None),
    )
