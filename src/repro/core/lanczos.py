"""Block Lanczos with full reorthogonalization — the HEIGEN-style baseline.

The paper compares against HEIGEN [12], a basic Lanczos implementation.
This module provides that baseline: build the full m = b·NB subspace once
(no restarts), Rayleigh–Ritz, done. Same out-of-core substrate, so the I/O
comparison against Krylov–Schur (which restarts and therefore bounds the
subspace) is apples-to-apples — reproducing the paper's motivation for
choosing Krylov–Schur (least I/O of the Anasazi solvers).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multivector import MultiVector
from repro.core.ortho import cholqr
from repro.core.krylov_schur import _expand
from repro.core.residuals import EigResult, sort_ritz
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


def lanczos_eigsh(op, nev: int, *, block_size: int = 4,
                  num_blocks: int | None = None, which: str = "LM",
                  store: TieredStore | None = None,
                  impl: kops.Impl = "auto", group_size: int = 8,
                  seed: int = 0, compute_eigenvectors: bool = True,
                  fused_passes: bool = True,
                  callback: Callable | None = None) -> EigResult:
    """`callback(step, theta, res)` fires once per block expansion with the
    current Ritz values / residual bounds of the growing subspace —
    nev-length arrays (positions past the subspace dimension padded with
    0 / inf), freshly allocated per call (mutation-safe). The per-step
    tridiagonal eigensolve it needs is only paid when a callback is set."""
    b = block_size
    if num_blocks is None:
        num_blocks = 4 * (-(-nev // b)) + 2
    m_max = b * num_blocks

    store = store or TieredStore()
    key = jax.random.PRNGKey(seed)
    q, _ = cholqr(jax.random.normal(key, (op.n, b), jnp.float32), impl=impl)

    v = MultiVector(store, op.n, group_size=group_size, impl=impl)
    h = np.zeros((0, 0), dtype=np.float64)
    r_next = np.zeros((b, b), dtype=np.float64)
    n_ops = 0
    while v.ncols + b <= m_max:
        q, h, r_next = _expand(op, v, q, h, impl, fused_passes=fused_passes)
        n_ops += 1
        if callback is not None:
            th, y = np.linalg.eigh(h)
            order = sort_ritz(th, which)
            th, y = th[order], y[:, order]
            rn = np.linalg.norm(r_next @ y[-b:, :], axis=0)
            k = min(nev, th.shape[0])
            theta_cb = np.zeros(nev)
            res_cb = np.full(nev, np.inf)
            theta_cb[:k] = th[:k]
            res_cb[:k] = rn[:k]
            callback(n_ops - 1, theta_cb, res_cb)

    theta, y = np.linalg.eigh(h)
    order = sort_ritz(theta, which)
    theta, y = theta[order], y[:, order]
    s = r_next @ y[-b:, :]
    res = np.linalg.norm(s, axis=0)

    vec = None
    if compute_eigenvectors:
        vec = np.asarray(v.mv_times_mat(jnp.asarray(y[:, :nev], jnp.float32)))

    return EigResult(
        eigenvalues=theta[:nev], eigenvectors=vec, residuals=res[:nev],
        n_restarts=0, n_ops=n_ops, m_subspace=m_max,
        converged=bool((res[:nev] <= 1e-4 * np.maximum(1.0, np.abs(theta[:nev]))).all()),
        io_stats=store.stats.as_dict() if store else None,
    )
