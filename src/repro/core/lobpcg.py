"""Block LOBPCG — the other Anasazi-family solver (paper §2, and the one
Zhou et al. [31] ran on SSD clusters).

Locally-optimal block preconditioned conjugate gradient: the subspace per
iteration is span[X, R, P] (current block, residuals, search directions) —
only 3·b vectors resident, no growing Krylov basis. That is the opposite
I/O trade from Krylov–Schur: LOBPCG keeps the fast tier tiny but applies
the operator every iteration without restart compression; the paper picks
Krylov–Schur because on power-law graphs the total streamed bytes end up
lower. Having both on the same MultiVector/TieredStore substrate lets the
benchmarks make that comparison quantitatively.

Supports largest ('LA') / smallest ('SA') algebraic eigenpairs and an
optional preconditioner callable.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ortho import svqb
from repro.core.residuals import EigResult
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


def _rayleigh_ritz(s_blocks, a_s_blocks, nev: int, which: str):
    """Small dense RR on the [X R P] subspace (m ≤ 3b)."""
    s = jnp.concatenate(s_blocks, axis=1)
    a_s = jnp.concatenate(a_s_blocks, axis=1)
    g = np.asarray(kops.gram(s, s, impl="ref"), np.float64)
    h = np.asarray(kops.gram(s, a_s, impl="ref"), np.float64)
    h = 0.5 * (h + h.T)
    # generalized symmetric eigenproblem h y = g y θ via Cholesky whitening
    tr = np.trace(g) / g.shape[0]
    l = None
    for jitter in (1e-10, 1e-7, 1e-4, 1e-2):
        try:
            l = np.linalg.cholesky(g + jitter * tr * np.eye(g.shape[0]))
            break
        except np.linalg.LinAlgError:
            continue
    if l is None:
        raise np.linalg.LinAlgError("RR basis numerically singular")
    linv = np.linalg.inv(l)
    hw = linv @ h @ linv.T
    theta, z = np.linalg.eigh(0.5 * (hw + hw.T))
    y = linv.T @ z
    order = np.argsort(-theta) if which == "LA" else np.argsort(theta)
    return theta[order], y[:, order]


def lobpcg(op, nev: int, *, block_size: int | None = None,
           tol: float = 1e-6, max_iters: int = 200, which: str = "LA",
           precond: Callable | None = None,
           store: TieredStore | None = None, seed: int = 0,
           impl: kops.Impl = "ref") -> EigResult:
    b = block_size or nev
    assert b >= nev
    store = store or TieredStore()
    n = op.n
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, b), jnp.float32)
    x, _ = svqb(x, impl=impl)
    p = None
    n_ops = 0
    theta = np.zeros(b)
    res_norms = np.full(b, np.inf)

    for it in range(max_iters):
        ax = op.matmat(x)
        n_ops += 1
        # accounting: X/R/P round-trip the store once per iteration (the
        # LOBPCG working set — 3 blocks — is what lives in fast memory)
        store.put("lobpcg/x", x)
        theta_x = np.asarray(jnp.sum(x * ax, axis=0), np.float64)
        r = ax - x * jnp.asarray(theta_x, jnp.float32)[None, :]
        res_norms = np.asarray(jnp.linalg.norm(r, axis=0))
        scale = np.maximum(1.0, np.abs(theta_x))
        if bool((res_norms[:nev] <= tol * scale[:nev]).all()) and it > 0:
            theta = theta_x
            break
        w = precond(r) if precond is not None else r
        # orthogonalize the residual block against X (keeps the RR Gram
        # well-conditioned — standard LOBPCG practice)
        w = w - x @ kops.gram(x, w, impl=impl)
        w, _ = svqb(w, impl=impl)
        aw = op.matmat(w)
        n_ops += 1

        s_blocks = [x, w]
        a_blocks = [ax, aw]
        if p is not None:
            p_o = p - x @ kops.gram(x, p, impl=impl)
            p_o = p_o - w @ kops.gram(w, p_o, impl=impl)
            p_o, rank = svqb(p_o, impl=impl)
            if rank > 0:
                s_blocks.append(p_o)
                a_blocks.append(op.matmat(p_o))
                n_ops += 1
        theta_all, y = _rayleigh_ritz(s_blocks, a_blocks, nev, which)
        yb = jnp.asarray(y[:, :b], jnp.float32)
        s = jnp.concatenate(s_blocks, axis=1)
        x_new = s @ yb
        # search direction: the R/P contribution to the update
        y_rp = yb.at[:b, :].set(0.0) if hasattr(yb, "at") else yb
        p = s @ y_rp
        x, _ = svqb(x_new, impl=impl)
        theta = theta_all[:b]

    vec = np.asarray(x[:, :nev])
    return EigResult(
        eigenvalues=np.asarray(theta[:nev]),
        eigenvectors=vec,
        residuals=res_norms[:nev],
        n_restarts=it, n_ops=n_ops, m_subspace=3 * b,
        converged=bool((res_norms[:nev]
                        <= tol * np.maximum(1.0, np.abs(theta[:nev]))).all()),
        io_stats=store.stats.as_dict(),
    )
