"""Block LOBPCG on the streamed-pass substrate — the other Anasazi-family
solver (paper §2, and the one Zhou et al. [31] ran on SSD clusters).

Locally-optimal block preconditioned conjugate gradient: the subspace per
iteration is span[X, W, P] (Ritz block, preconditioned residuals, search
directions) — only 3·b basis vectors, no growing Krylov history. That is
the opposite I/O trade from Krylov–Schur: there is no restart compression
and no history to reorthogonalize against, but the operator is applied
every iteration and the whole [X, W, P] basis (plus its A-images) streams
from the slow tier several times per iteration. The paper picks
Krylov–Schur because on power-law graphs the total streamed bytes end up
lower — with both solvers on the same MultiVector/TieredStore substrate
that claim is a benchmark (`benchmarks/bench_eigen.py --smoke` →
results/BENCH_solver_family.json), not a docstring assertion.

Out-of-core layout: two 3-block MultiVectors hold the basis S = [X, W, P]
and its images AS = [AX, AW, AP]; every block is written through to the
slow tier immediately (`_put_spilled` = write + demote), so the pass
accounting below is byte-exact on ANY device budget. A-images are
maintained algebraically — every linear transform applied to a basis
block is co-applied to its image (`ortho.svqb_transform`) — so the
operator runs exactly once per iteration (on W).

Streamed passes per iteration (fused_passes=True), B = n·b·4 bytes:

  residual pass   reads X ⊕ AX                 (2 blocks, 2B)
                  → Rayleigh quotients, residual norms, W candidate
  gram pass       reads [X, W (, P)] ⊕ images  (4B at it 0, else 6B)
                  → inline P deflation (ortho vs X, W + SVQB, transforms
                    co-applied to AP, write-back), then G = SᵀS, H = SᵀAS
  update pass     reads the same blocks        (4B / 6B)
                  → four accumulators in one read: X' = S·y_x,
                    P' = S·y_p, AX' = AS·y_x, AP' = AS·y_p

so a run that converges at iteration `it` (the check fires after the
residual pass; it ≥ 1) costs exactly

  passes     = 3·it + 1
  pass bytes = (10 + 14·(it − 1) + 2) · B

— asserted byte-exactly by tests/test_extensions.py on the ram AND safs
backends (assuming P never fully deflates, which drops the 2B P⊕AP share
of the gram/update passes for that iteration). fused_passes=False splits
every consumer into its own single-consumer pass — deflation walk, G
walk, S⊕AS walk for H, one pass per update accumulator: 8 passes and 29B
per full iteration — the unfused reference for parity tests and the I/O
benches.

Supports largest ('LA') / smallest ('SA') algebraic eigenpairs and an
optional preconditioner callable. The preconditioner runs outside the
passes and must not touch the solver's TieredStore, or the accounting
above stops being attributable.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multivector import MultiVector
from repro.core.ortho import svqb, svqb_transform
from repro.core.residuals import EigResult
from repro.core.stream import SubspacePass
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


def _put_spilled(mv: MultiVector, i: int, arr: jnp.ndarray) -> None:
    """Write block i (append when it doesn't exist yet) and immediately
    demote it: the basis lives on "SSD", every pass read is a host read,
    and the module-docstring pass accounting holds on any device budget."""
    if i < mv.nblocks:
        mv.set_block(i, arr)
    else:
        assert i == mv.nblocks, (i, mv.nblocks)
        mv.append_block(arr, pin_recent=False)
    mv.store.demote(mv._block_name(i))


def _rayleigh_ritz(g: np.ndarray, h: np.ndarray, which: str
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Dense RR on the [X W P] Grams (m ≤ 3b): the generalized symmetric
    problem H y = G y θ via Cholesky whitening with an escalating-jitter
    ladder (the basis is deflated, but can still be borderline near
    convergence)."""
    h = 0.5 * (h + h.T)
    tr = np.trace(g) / g.shape[0]
    l = None
    for jitter in (1e-10, 1e-7, 1e-4, 1e-2):
        try:
            l = np.linalg.cholesky(g + jitter * tr * np.eye(g.shape[0]))
            break
        except np.linalg.LinAlgError:
            continue
    if l is None:
        raise np.linalg.LinAlgError("RR basis numerically singular")
    linv = np.linalg.inv(l)
    hw = linv @ h @ linv.T
    theta, z = np.linalg.eigh(0.5 * (hw + hw.T))
    y = linv.T @ z
    order = np.argsort(-theta) if which == "LA" else np.argsort(theta)
    return theta[order], y[:, order]


def _deflate_p(x, ax, w, aw, p, ap, impl
               ) -> Tuple[Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    """Orthogonalize P against X and W, then SVQB; every transform is
    co-applied to AP so the image stays exact with zero operator applies.
    Returns (None, None) when P is numerically rank deficient after
    deflation — the caller drops P from this iteration's basis instead of
    letting zero columns poison the RR Gram."""
    c = kops.gram(x, p, impl=impl)
    p = kops.tsgemm(x, c, alpha=-1.0, beta=1.0, c0=p, impl=impl)
    ap = kops.tsgemm(ax, c, alpha=-1.0, beta=1.0, c0=ap, impl=impl)
    c = kops.gram(w, p, impl=impl)
    p = kops.tsgemm(w, c, alpha=-1.0, beta=1.0, c0=p, impl=impl)
    ap = kops.tsgemm(aw, c, alpha=-1.0, beta=1.0, c0=ap, impl=impl)
    t, rank = svqb_transform(p, impl=impl)
    if rank < p.shape[1]:
        return None, None
    return kops.tsgemm(p, t, impl=impl), kops.tsgemm(ap, t, impl=impl)


def _assemble_grams(held: List[Tuple[jnp.ndarray, jnp.ndarray]], impl
                    ) -> Tuple[np.ndarray, np.ndarray]:
    s_mat = jnp.concatenate([t[0] for t in held], axis=1)
    as_mat = jnp.concatenate([t[1] for t in held], axis=1)
    g = np.asarray(kops.gram(s_mat, s_mat, impl=impl), np.float64)
    h = np.asarray(kops.gram(s_mat, as_mat, impl=impl), np.float64)
    return g, h


def _gram_fused(s, a_s, have_p, impl) -> Tuple[np.ndarray, np.ndarray, bool]:
    """ONE multi-consumer streamed pass: basis blocks and their images
    (peers, lockstep) stream once; the P visit deflates the search
    directions in place (write-back via `_put_spilled`), then G and H
    assemble from the pass's materialized blocks. The full 3+3 block
    working set stays device-resident for the pass — that IS the LOBPCG
    memory model (3·b vectors of fast memory, paper §2)."""
    held: List[Tuple[jnp.ndarray, jnp.ndarray]] = []
    gp = SubspacePass(s, peers=[a_s],
                      block_ids=[0, 1, 2] if have_p else [0, 1])

    def visit(i, blk, peers):
        img = peers[0]
        if i == 2:
            (x, ax), (w, aw) = held[0], held[1]
            blk, img = _deflate_p(x, ax, w, aw, blk, img, impl)
            if blk is None:
                return
            _put_spilled(s, 2, blk)
            _put_spilled(a_s, 2, img)
        held.append((blk, img))

    gp.add_visit(visit, axis=None)
    gp.run()
    g, h = _assemble_grams(held, impl)
    return g, h, len(held) == 3


def _gram_unfused(s, a_s, have_p, impl
                  ) -> Tuple[np.ndarray, np.ndarray, bool]:
    """Same results as `_gram_fused` as single-consumer passes: a
    deflation walk (write-back), a basis walk for G, a basis⊕image walk
    for H — three subspace reads where the fused pass pays one."""
    use_p = have_p
    if have_p:
        held: List = []
        dp = SubspacePass(s, peers=[a_s], block_ids=[0, 1, 2])

        def deflate(i, blk, peers):
            if i < 2:
                held.append((blk, peers[0]))
                return
            p, ap = _deflate_p(held[0][0], held[0][1], held[1][0],
                               held[1][1], blk, peers[0], impl)
            held.append(p)
            if p is not None:
                _put_spilled(s, 2, p)
                _put_spilled(a_s, 2, ap)

        dp.add_visit(deflate, axis=None)
        dp.run()
        use_p = held[2] is not None
    ids = [0, 1, 2] if use_p else [0, 1]

    g_pass = SubspacePass(s, block_ids=ids)
    hg = g_pass.add_visit(lambda i, blk, peers: blk, axis=1)
    g_pass.run()
    s_mat = hg.value
    g = np.asarray(kops.gram(s_mat, s_mat, impl=impl), np.float64)

    h_pass = SubspacePass(s, peers=[a_s], block_ids=ids)
    hh = h_pass.add_visit(lambda i, blk, peers: (blk, peers[0]), axis=None)
    h_pass.run()
    sm = jnp.concatenate([t[0] for t in hh.value], axis=1)
    am = jnp.concatenate([t[1] for t in hh.value], axis=1)
    h = np.asarray(kops.gram(sm, am, impl=impl), np.float64)
    return g, h, use_p


def _update_fused(s, a_s, y_x, y_p, ids, impl) -> List[jnp.ndarray]:
    """ONE streamed read of basis⊕images filling four accumulators:
    X' = S·y_x, P' = S·y_p, AX' = AS·y_x, AP' = AS·y_p."""
    widths = s.block_widths()
    offs, off = {}, 0
    for i in ids:
        offs[i] = off
        off += widths[i]
    n, b = s.n, y_x.shape[1]
    accs = [jnp.zeros((n, b), jnp.float32) for _ in range(4)]
    up = SubspacePass(s, peers=[a_s], block_ids=ids)

    def visit(i, blk, peers):
        rows = slice(offs[i], offs[i] + widths[i])
        for j, (src, small) in enumerate(((blk, y_x), (blk, y_p),
                                          (peers[0], y_x), (peers[0], y_p))):
            accs[j] = kops.tsgemm(src, small[rows], beta=1.0, c0=accs[j],
                                  impl=impl)

    up.add_visit(visit, axis=None)
    up.run()
    return accs


def _update_unfused(s, a_s, y_x, y_p, ids, impl) -> List[jnp.ndarray]:
    outs = []
    for mv, small in ((s, y_x), (s, y_p), (a_s, y_x), (a_s, y_p)):
        up = SubspacePass(mv, block_ids=ids)
        h = up.add_matmul(small)
        up.run()
        outs.append(h.value[0])
    return outs


def lobpcg(op, nev: int, *, block_size: int | None = None,
           tol: float = 1e-6, max_iters: int = 200, which: str = "LA",
           precond: Callable | None = None,
           store: TieredStore | None = None, seed: int = 0,
           impl: kops.Impl = "ref", fused_passes: bool = True,
           group_size: int = 8, stall_iters: int = 8,
           callback: Callable | None = None,
           checkpointer=None) -> EigResult:
    """Compute `nev` eigenpairs by block LOBPCG with the [X, W, P] basis
    streamed from the TieredStore (pass accounting: module docstring).

    which: 'LA' (largest algebraic) or 'SA' (smallest). LOBPCG optimizes
    an extreme Rayleigh quotient, so 'LM' has no natural meaning here —
    wrap the operator in a spectral transform instead (`core.operator.
    ShiftInvertOperator` / `ChebyshevFilterOperator` via `core.solve`).

    stall_iters: stagnation guard. The f32 residual floor can sit above
    `tol`; once it is reached, W is pure rounding noise and further
    iterations slowly poison the RR basis — under which='LA' the spurious
    Ritz values are then SELECTED into X and the solve diverges. After
    `stall_iters` iterations without residual improvement the loop exits
    (converged=False unless `tol` was met) and the BEST iterate seen —
    not the last — is returned.

    callback(it, theta[:nev], res[:nev]) fires once per iteration right
    after the residual pass — the solver-family telemetry hook
    (`core.solver.SolverContext.callback`).

    checkpointer: a `ckpt.solver.SolveCheckpointer` (normally built by
    `core.solver.solve(..., checkpoint=/resume=)`). LOBPCG has no
    restarts, so the snapshot boundary is the end of an iteration: the
    whole live state is the two 3-block MultiVectors S = [X, W, P] and
    AS (already spilled to the slow tier by `_put_spilled`) plus the
    Ritz values, residual norms, best-iterate tracker and a few flags.
    """
    if which not in ("LA", "SA"):
        raise ValueError(f"lobpcg supports which='LA'|'SA', got {which!r}")
    b = block_size or nev
    assert b >= nev
    store = store or TieredStore()
    n = op.n

    resume = checkpointer.load(store) if checkpointer is not None else None
    if resume is not None:
        # the next iteration's residual pass re-reads X ⊕ AX from the
        # restored blocks, so x/ax need no separate restore; the best-
        # iterate tracker continues where it stopped
        s = resume.mvs["s"]
        a_s = resume.mvs["a_s"]
        theta = np.asarray(resume.arrays["theta"], np.float64)
        res_norms = np.asarray(resume.arrays["res_norms"], np.float64)
        best_x = jnp.asarray(resume.arrays["best_x"], jnp.float32)
        best_theta = np.asarray(resume.arrays["best_theta"], np.float64)
        best_res = np.asarray(resume.arrays["best_res"], np.float64)
        n_ops = int(resume.extra["n_ops"])
        have_p = bool(resume.extra["have_p"])
        stall = int(resume.extra["stall"])
        best = float(resume.extra["best"])
        x = best_x
        start_it = resume.step
    else:
        key = jax.random.PRNGKey(seed)
        x, _ = svqb(jax.random.normal(key, (n, b), jnp.float32), impl=impl)
        ax = op.matmat(x)
        n_ops = 1
        s = MultiVector(store, n, group_size=group_size, impl=impl)
        a_s = MultiVector(store, n, group_size=group_size, impl=impl)
        _put_spilled(s, 0, x)
        _put_spilled(a_s, 0, ax)
        have_p = False
        theta = np.zeros(b)
        res_norms = np.full(b, np.inf)
        best = np.inf
        stall = 0
        best_x, best_theta, best_res = x, theta[:nev], res_norms[:nev]
        start_it = 0
    converged = False
    it = start_it

    for it in range(start_it, max_iters):
        # --- residual pass: one streamed read of X ⊕ AX ------------------
        rp = SubspacePass(s, peers=[a_s], block_ids=[0])
        hr = rp.add_visit(lambda i, blk, peers: (blk, peers[0]), axis=None)
        rp.run()
        x, ax = hr.value[0]
        theta_f = jnp.sum(x * ax, axis=0)       # Rayleigh (X orthonormal)
        theta = np.asarray(theta_f, np.float64)
        r = ax - x * theta_f[None, :]           # f32 end to end (the seed
        # bounced theta through f64 and back per column right here)
        res_norms = np.asarray(jnp.linalg.norm(r, axis=0), np.float64)
        scale = np.maximum(1.0, np.abs(theta))
        if callback is not None:
            callback(it, theta[:nev].copy(), res_norms[:nev].copy())
        cur = float(np.max(res_norms[:nev] / scale[:nev]))
        if cur < best * (1.0 - 1e-3):
            best, stall = cur, 0
            best_x = x
            best_theta = theta[:nev].copy()
            best_res = res_norms[:nev].copy()
        else:
            stall += 1
        if it > 0 and bool((res_norms[:nev] <= tol * scale[:nev]).all()):
            converged = True
            break
        if stall >= stall_iters:
            break               # f32 floor reached — stop before the noise
            # W blocks degrade the basis (see docstring)

        # --- residual block W: precondition, deflate vs X, renormalize ---
        w = precond(r) if precond is not None else r
        w = kops.tsgemm(x, kops.gram(x, w, impl=impl), alpha=-1.0,
                        beta=1.0, c0=w, impl=impl)
        w, _ = svqb(w, impl=impl)
        aw = op.matmat(w)                       # the ONLY operator apply
        n_ops += 1
        _put_spilled(s, 1, w)
        _put_spilled(a_s, 1, aw)

        # --- gram pass: P deflation + G = SᵀS, H = SᵀAS ------------------
        gram = _gram_fused if fused_passes else _gram_unfused
        g, h, use_p = gram(s, a_s, have_p, impl)

        theta_all, y = _rayleigh_ritz(g, h, which)
        y_x = y[:, :b]
        y_p = y_x.copy()
        y_p[:b, :] = 0.0
        # ^ the search direction is the (W, P) share of the update only:
        #   zeroing the X rows in numpy replaces the seed's dead
        #   `hasattr(yb, "at")` fallback whose else-branch silently kept
        #   the X contribution in P
        y_x = jnp.asarray(y_x, jnp.float32)
        y_p = jnp.asarray(y_p, jnp.float32)

        # --- update pass: four accumulators from one read ----------------
        ids = [0, 1, 2] if use_p else [0, 1]
        upd = _update_fused if fused_passes else _update_unfused
        x, p_new, ax, ap_new = upd(s, a_s, y_x, y_p, ids, impl)
        # X' = S·y_x is G-orthonormal by RR construction (the whitening is
        # measured from the actual blocks each iteration, so orthogonality
        # errors do not accumulate). Do NOT re-run SVQB here: on an
        # already-near-orthonormal block its Gram is I + f32 noise, whose
        # eigenvector factor is an arbitrary dense rotation — it scrambles
        # the Ritz columns into mixtures and the per-column residual check
        # never fires (the seed solver had exactly this bug and reached
        # max_iters on every nontrivial problem).
        _put_spilled(s, 0, x)
        _put_spilled(a_s, 0, ax)
        _put_spilled(s, 2, p_new)
        _put_spilled(a_s, 2, ap_new)
        have_p = True
        theta = theta_all[:b]

        if checkpointer is not None:
            # iteration boundary = snapshot point (docstring); may raise
            # SolveSuspended after committing on preemption
            checkpointer.maybe_checkpoint(store, it + 1, lambda: {
                "mvs": {"s": s, "a_s": a_s},
                "arrays": {"theta": np.asarray(theta, np.float64),
                           "res_norms": res_norms,
                           "best_x": np.asarray(best_x),
                           "best_theta": best_theta, "best_res": best_res},
                "extra": {"n_ops": n_ops, "have_p": have_p,
                          "stall": stall, "best": float(best)}})

    if converged:
        vec, lam, rn = x[:, :nev], theta[:nev], res_norms[:nev]
    else:                       # stall / max_iters: best iterate, not last
        vec, lam, rn = best_x[:, :nev], best_theta, best_res
    return EigResult(
        eigenvalues=np.asarray(lam),
        eigenvectors=np.asarray(vec),
        residuals=np.asarray(rn),
        n_restarts=it, n_ops=n_ops, m_subspace=3 * b,
        converged=converged,
        io_stats=store.stats.as_dict(),
        resumed_step=(checkpointer.resumed_step
                      if checkpointer is not None else None),
    )
