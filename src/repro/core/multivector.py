"""Out-of-core TAS MultiVector — the paper's §3.4 vector subspace.

The Krylov subspace S ∈ R^{n×m} is stored as NB column blocks of width b
(one "TAS matrix" per block, each a separate object in the TieredStore — the
analogue of one SAFS file per matrix, §3.4.1). The eleven Anasazi MultiVector
operations of Table 1 are implemented block-streamed.

I/O discipline (§3.4.3 pass minimization): every whole-subspace operation is
expressed as a `core.stream.SubspacePass` — ONE block-streamed read feeding
any number of consumers per block visit, with the full pass's block list
announced to `TieredStore.prefetch` up front so the backend's readahead
window always has the true access pattern (this replaced the old per-group
`_prefetch_group` hints; the small reductions mv_dot / mv_norm / clone_view
previously streamed with no readahead at all). Pass-level rules:

  * one `TieredStore.get` per block per pass, shared by all consumers —
    `IOStats.passes` counts the streamed reads, so bytes-per-pass is
    byte-exact and benchmarkable (`benchmarks/bench_subspace_io.py`);
  * MvScale is *lazy* — a scalar per block folded into the shared
    materialization (the paper's lazy evaluation, §3.4.4), zero I/O;
  * `project_out` fuses a whole CGS step (h = Vᵀw, w ← w − V h) into one
    read — `ortho.bcgs2(fused=True)` runs CGS2 in 2 subspace reads where
    the unfused path pays 4;
  * `compress` computes ALL restart output blocks in one streamed read
    (multi-accumulator TSGEMM) instead of one full pass per output block;
  * the newest block is pinned in the device tier (most-recent-block
    cache) and the just-demoted predecessor's pages stay pinned in the
    backend page cache (§3.4.4);
  * transpose/CloneView share `data_id` with their parent so the cache
    recognizes identical bytes.
"""
from __future__ import annotations

import dataclasses
import re
import threading
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stream import SubspacePass
from repro.core.tiered import TieredStore, DEVICE, HOST
from repro.kernels import ops as kops


@dataclasses.dataclass
class _Block:
    name: str
    ncols: int
    scale: float = 1.0   # lazy MvScale factor


# Transient device-accumulator budget for one fused compress pass: every
# output block of the pass stays resident (k·n·4 bytes for k columns), so
# an unbounded single pass would OOM a billion-row restart. Under this cap
# any laptop/bench-scale compress is still exactly one pass; past it the
# output column groups chunk into ceil(k_keep·n·4 / cap) passes — still
# far below the pre-fusion one-pass-per-output-block.
COMPRESS_PASS_ACC_BYTES = 1 << 30


class MultiVector:
    """A tall-and-skinny (n × m) matrix as a sequence of column blocks."""

    _counter = 0
    _counter_lock = threading.Lock()   # concurrent sessions auto-name MVs

    def __init__(self, store: TieredStore | None, n: int, *,
                 name: str | None = None, group_size: int = 8,
                 readahead: int = 2, impl: kops.Impl = "auto",
                 backend="ram", backend_opts: dict | None = None):
        if name is None:
            with MultiVector._counter_lock:
                MultiVector._counter += 1
                name = f"mv{MultiVector._counter}"
        else:
            # A resumed solve recreates MultiVectors under their
            # checkpointed auto-names; keep the counter ahead of them so
            # later auto-named instances can't collide in a shared store.
            m = re.fullmatch(r"mv(\d+)", name)
            if m:
                with MultiVector._counter_lock:
                    MultiVector._counter = max(MultiVector._counter,
                                               int(m.group(1)))
        if store is None:  # own store on the requested backend ("ram"|"safs")
            store = TieredStore(backend=backend, backend_opts=backend_opts)
        self.store = store
        self.n = n
        self.name = name
        self.group_size = group_size
        self.readahead = max(1, int(readahead))  # groups announced ahead
        self.impl = impl
        self._blocks: List[_Block] = []

    # ------------------------------------------------------------------ basics
    @property
    def ncols(self) -> int:
        return sum(b.ncols for b in self._blocks)

    @property
    def nblocks(self) -> int:
        return len(self._blocks)

    def block_widths(self) -> List[int]:
        return [b.ncols for b in self._blocks]

    def block_names(self) -> List[str]:
        """Store names of the blocks, in column order (stable identity —
        operators mirroring the subspace on-device key their shard cache
        on these)."""
        return [b.name for b in self._blocks]

    def _block_name(self, i: int) -> str:
        return self._blocks[i].name

    def block(self, i: int) -> jnp.ndarray:
        """Materialize block i (applies any lazy scale)."""
        b = self._blocks[i]
        val = self.store.get(b.name)
        if b.scale != 1.0:
            val = b.scale * val
        return val

    def append_block(self, arr: jnp.ndarray, *, pin_recent: bool = True) -> None:
        """Append a new rightmost block; pins it (most-recent-block cache)
        and demotes the previously pinned block to the host tier, pinning
        the demoted block's pages in the backend page cache (§3.4.4: it is
        the newest on-"SSD" matrix, about to be re-read by the CGS2
        passes) until the next append supersedes it."""
        assert arr.shape[0] == self.n, (arr.shape, self.n)
        idx = len(self._blocks)
        name = f"{self.name}/b{idx}"
        self.store.put(name, jnp.asarray(arr, jnp.float32))
        if pin_recent:
            if idx > 0:
                prev = self._blocks[-1].name
                self.store.unpin(prev)
                self.store.demote(prev)
                self.store.host_pin(prev)
            self.store.pin(name)
        self._blocks.append(_Block(name, int(arr.shape[1])))

    def set_block(self, i: int, arr: jnp.ndarray) -> None:
        """Anasazi SetBlock: overwrite one block in place."""
        b = self._blocks[i]
        assert arr.shape == (self.n, b.ncols)
        self.store.put(b.name, jnp.asarray(arr, jnp.float32))
        b.scale = 1.0

    def delete(self) -> None:
        for b in self._blocks:
            self.store.delete(b.name)
        self._blocks.clear()

    # --------------------------------------------------------------- Table 1
    def mv_random(self, key: jax.Array, widths: Sequence[int]) -> None:
        """MvRandom: (re)initialize blocks with random values."""
        self.delete()
        for w in widths:
            key, sub = jax.random.split(key)
            self.append_block(jax.random.normal(sub, (self.n, w), jnp.float32))

    def mv_scale(self, factors: Sequence[float] | float) -> None:
        """MvScale1 — lazy: fold the scalar into block metadata (zero I/O)."""
        if np.isscalar(factors):
            for b in self._blocks:
                b.scale *= float(factors)
        else:
            assert len(factors) == self.nblocks
            for b, f in zip(self._blocks, factors):
                b.scale *= float(f)

    def mv_scale_diag(self, vec: jnp.ndarray) -> None:
        """MvScale2: BB <- AA diag(vec) — materializes (per-column scales).
        One streamed pass (full block list announced to the readahead
        window up front); each visit writes its scaled block back in
        place. Previously this was a bare get/put loop with no prefetch
        announcement at all."""
        if self.nblocks == 0:
            return
        offs, off = [], 0
        for b in self._blocks:
            offs.append(off)
            off += b.ncols

        p = SubspacePass(self)

        def scale(i, blk, peers):
            w = self._blocks[i].ncols
            self.set_block(i, blk * vec[offs[i]:offs[i] + w][None, :])

        p.add_visit(scale, axis=None)
        p.run()

    def mv_times_mat(self, small: jnp.ndarray, *, alpha: float = 1.0,
                     beta: float = 0.0, c0: jnp.ndarray | None = None
                     ) -> jnp.ndarray:
        """MvTimesMatAddMv: returns alpha * self @ small + beta * c0, where
        small is (m, k). One streamed pass over the blocks."""
        m, k = small.shape
        assert m == self.ncols, (m, self.ncols)
        if self.nblocks == 0:
            acc = jnp.zeros((self.n, k), jnp.float32)
        else:
            p = SubspacePass(self)
            h = p.add_matmul(small, alpha=alpha)
            p.run()
            (acc,) = h.value
        if c0 is not None and beta != 0.0:
            acc = acc + beta * c0
        return acc

    def mv_trans_mv(self, other: jnp.ndarray, *, alpha: float = 1.0
                    ) -> jnp.ndarray:
        """MvTransMv: alpha * selfᵀ @ other → (m, k) small matrix.
        One streamed pass; the right operand is shared across visits
        (§3.4.3 shared-I/O optimization — it stays in the device tier)."""
        p = SubspacePass(self)
        h = p.add_gram(other, alpha=alpha)
        p.run()
        return h.value

    def project_out(self, w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """One *fused* CGS step in a single streamed read: per block visit
        h_i = V_iᵀw then w ← w − V_i h_i (block-MGS update order; the
        telescoping w₀ = Σ V_i h_i + w keeps W = V·h + w exact). Returns
        (h, w). The unfused equivalent (mv_trans_mv + mv_times_mat) reads
        the subspace twice."""
        p = SubspacePass(self)
        h = p.add_project(w)
        p.run()
        return h.value

    def mv_add_mv(self, alpha: float, other: "MultiVector", beta: float
                  ) -> "MultiVector":
        """MvAddMv: C <- alpha*A + beta*B (blockwise, same block structure),
        both operands streamed in lockstep with readahead."""
        assert self.block_widths() == other.block_widths()
        out = MultiVector(self.store, self.n, group_size=self.group_size,
                          readahead=self.readahead, impl=self.impl)
        p = SubspacePass(self, peers=[other])

        def emit(i, blk, peers):
            out.append_block(alpha * blk + beta * peers[0], pin_recent=False)

        p.add_visit(emit, axis=None)
        p.run()
        return out

    def mv_dot(self, other: "MultiVector") -> jnp.ndarray:
        """MvDot: columnwise dot products vec[i] = selfᵀ[:,i] · other[:,i]."""
        assert self.block_widths() == other.block_widths()
        p = SubspacePass(self, peers=[other])
        h = p.add_dot()
        p.run()
        return h.value

    def mv_norm(self) -> jnp.ndarray:
        """MvNorm: column 2-norms."""
        p = SubspacePass(self)
        h = p.add_norm()
        p.run()
        return h.value

    def clone_view(self, idxs: Sequence[int]) -> jnp.ndarray:
        """CloneView: gather a set of columns (materialized, one pass)."""
        want = set(int(i) for i in idxs)
        offs, off = [], 0
        for b in self._blocks:
            offs.append(off)
            off += b.ncols
        p = SubspacePass(self)

        def pick(i, blk, peers):
            local = [j for j in range(blk.shape[1]) if offs[i] + j in want]
            return blk[:, local] if local else None

        h = p.add_visit(pick, axis=1)
        p.run()
        return h.value

    def conv_layout(self) -> jnp.ndarray:
        """ConvLayout: column-major subspace block → row-major operand for
        SpMM. On TPU this is a logical no-op (XLA layouts); kept for API
        fidelity. Returns the most recent block materialized."""
        return self.block(self.nblocks - 1)

    # ------------------------------------------------------------ restart ops
    def compress(self, q: jnp.ndarray, new_widths: Sequence[int], *,
                 fused: bool = True, pass_acc_bytes: int | None = None
                 ) -> "MultiVector":
        """V_new = V @ Q for restart compression (Krylov–Schur). Q is
        (m, m_new); output blocks of widths new_widths. This is the big
        out-of-core GEMM of the restart step.

        fused=True (default): ONE streamed read computes every output
        block via multi-accumulator TSGEMM — the subspace is read exactly
        once regardless of k_keep. The pass's output accumulators stay
        device-resident (k·n·4 bytes of fast memory, the paper's TAS
        working-set assumption); when k_keep·n·4 exceeds `pass_acc_bytes`
        (default COMPRESS_PASS_ACC_BYTES, 1 GiB) the output column groups
        chunk into the minimum number of passes that fit the budget.
        fused=False keeps the pre-fusion path — one full grouped pass
        *per output block* (k_keep/b subspace reads) — for parity tests
        and the bench_subspace_io before/after column."""
        assert q.shape[0] == self.ncols
        assert sum(new_widths) == q.shape[1]
        out = MultiVector(self.store, self.n, group_size=self.group_size,
                          readahead=self.readahead, impl=self.impl)
        if fused and self.nblocks:
            budget = pass_acc_bytes
            if budget is None:
                # a session under an arbiter allotment caps the transient
                # accumulators at its share of the device budget (the
                # namespace facade reports it); a plain store keeps the
                # global 1 GiB default
                cap = getattr(self.store, "compress_acc_bytes",
                              lambda: None)()
                budget = (COMPRESS_PASS_ACC_BYTES if cap is None
                          else min(COMPRESS_PASS_ACC_BYTES, cap))
            groups: List[List[int]] = [[]]
            acc = 0
            for w in new_widths:
                if groups[-1] and (acc + w) * self.n * 4 > budget:
                    groups.append([])
                    acc = 0
                groups[-1].append(w)
                acc += w
            off = 0
            for gw in groups:
                k = sum(gw)
                p = SubspacePass(self)
                h = p.add_matmul(q[:, off:off + k], gw)
                p.run()
                for blk in h.value:
                    out.append_block(blk, pin_recent=False)
                off += k
        else:
            off = 0
            for w in new_widths:
                blk = self.mv_times_mat(q[:, off:off + w])
                out.append_block(blk, pin_recent=False)
                off += w
        return out

    def to_dense(self) -> jnp.ndarray:
        if self.nblocks == 0:
            return jnp.zeros((self.n, 0), jnp.float32)
        p = SubspacePass(self)
        h = p.add_visit(lambda i, blk, peers: blk, axis=1)
        p.run()
        return h.value
