"""Out-of-core TAS MultiVector — the paper's §3.4 vector subspace.

The Krylov subspace S ∈ R^{n×m} is stored as NB column blocks of width b
(one "TAS matrix" per block, each a separate object in the TieredStore — the
analogue of one SAFS file per matrix, §3.4.1). The eleven Anasazi MultiVector
operations of Table 1 are implemented block-streamed:

  * the *group decomposition* of Fig. 5 bounds fast-tier memory: operations
    touching many blocks (MvTimesMatAddMv / MvTransMv) stream the blocks in
    groups of `group_size`, materializing only partial results;
  * MvScale is *lazy* — a scalar per block folded into the next consumer
    (the paper's lazy evaluation, §3.4.4), costing zero I/O;
  * the newest block is pinned in the device tier (most-recent-block cache);
  * transpose/CloneView share `data_id` with their parent so the cache
    recognizes identical bytes;
  * grouped streaming reads ahead: before contracting group g the next
    `readahead` groups' blocks are handed to `TieredStore.prefetch`, so
    with the file backend (`TieredStore(backend="safs")`, §3.4.1) the
    multi-worker readahead pool keeps page reads in flight under the JAX
    compute of the current group (a no-op on the default ram backend).
    The scheduler's own `depth` bounds how much of the announced pattern
    is actually queued, so a deep `readahead` cannot thrash the cache.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiered import TieredStore, DEVICE, HOST
from repro.kernels import ops as kops


@dataclasses.dataclass
class _Block:
    name: str
    ncols: int
    scale: float = 1.0   # lazy MvScale factor


class MultiVector:
    """A tall-and-skinny (n × m) matrix as a sequence of column blocks."""

    _counter = 0

    def __init__(self, store: TieredStore | None, n: int, *,
                 name: str | None = None, group_size: int = 8,
                 readahead: int = 2, impl: kops.Impl = "auto",
                 backend="ram", backend_opts: dict | None = None):
        if name is None:
            MultiVector._counter += 1
            name = f"mv{MultiVector._counter}"
        if store is None:  # own store on the requested backend ("ram"|"safs")
            store = TieredStore(backend=backend, backend_opts=backend_opts)
        self.store = store
        self.n = n
        self.name = name
        self.group_size = group_size
        self.readahead = max(1, int(readahead))  # groups announced ahead
        self.impl = impl
        self._blocks: List[_Block] = []

    # ------------------------------------------------------------------ basics
    @property
    def ncols(self) -> int:
        return sum(b.ncols for b in self._blocks)

    @property
    def nblocks(self) -> int:
        return len(self._blocks)

    def block_widths(self) -> List[int]:
        return [b.ncols for b in self._blocks]

    def block_names(self) -> List[str]:
        """Store names of the blocks, in column order (stable identity —
        operators mirroring the subspace on-device key their shard cache
        on these)."""
        return [b.name for b in self._blocks]

    def _block_name(self, i: int) -> str:
        return self._blocks[i].name

    def _prefetch_group(self, g0: int) -> None:
        """Readahead: announce the next `readahead` groups' blocks to the
        backend's scheduler (async I/O overlapping the current group's
        compute; no-op on ram backend). The scheduler's depth bounds how
        many are actually queued."""
        self.store.prefetch(
            [b.name for b in
             self._blocks[g0:g0 + self.readahead * self.group_size]])

    def block(self, i: int) -> jnp.ndarray:
        """Materialize block i (applies any lazy scale)."""
        b = self._blocks[i]
        val = self.store.get(b.name)
        if b.scale != 1.0:
            val = b.scale * val
        return val

    def append_block(self, arr: jnp.ndarray, *, pin_recent: bool = True) -> None:
        """Append a new rightmost block; pins it (most-recent-block cache)
        and demotes the previously pinned block to the host tier, pinning
        the demoted block's pages in the backend page cache (§3.4.4: it is
        the newest on-"SSD" matrix, about to be re-read four times by the
        CGS2 passes) until the next append supersedes it."""
        assert arr.shape[0] == self.n, (arr.shape, self.n)
        idx = len(self._blocks)
        name = f"{self.name}/b{idx}"
        self.store.put(name, jnp.asarray(arr, jnp.float32))
        if pin_recent:
            if idx > 0:
                prev = self._blocks[-1].name
                self.store.unpin(prev)
                self.store.demote(prev)
                self.store.host_pin(prev)
            self.store.pin(name)
        self._blocks.append(_Block(name, int(arr.shape[1])))

    def set_block(self, i: int, arr: jnp.ndarray) -> None:
        """Anasazi SetBlock: overwrite one block in place."""
        b = self._blocks[i]
        assert arr.shape == (self.n, b.ncols)
        self.store.put(b.name, jnp.asarray(arr, jnp.float32))
        b.scale = 1.0

    def delete(self) -> None:
        for b in self._blocks:
            self.store.delete(b.name)
        self._blocks.clear()

    # --------------------------------------------------------------- Table 1
    def mv_random(self, key: jax.Array, widths: Sequence[int]) -> None:
        """MvRandom: (re)initialize blocks with random values."""
        self.delete()
        for w in widths:
            key, sub = jax.random.split(key)
            self.append_block(jax.random.normal(sub, (self.n, w), jnp.float32))

    def mv_scale(self, factors: Sequence[float] | float) -> None:
        """MvScale1 — lazy: fold the scalar into block metadata (zero I/O)."""
        if np.isscalar(factors):
            for b in self._blocks:
                b.scale *= float(factors)
        else:
            assert len(factors) == self.nblocks
            for b, f in zip(self._blocks, factors):
                b.scale *= float(f)

    def mv_scale_diag(self, vec: jnp.ndarray) -> None:
        """MvScale2: BB <- AA diag(vec) — materializes (per-column scales)."""
        off = 0
        for i, b in enumerate(self._blocks):
            blk = self.block(i) * vec[off:off + b.ncols][None, :]
            self.set_block(i, blk)
            off += b.ncols

    def mv_times_mat(self, small: jnp.ndarray, *, alpha: float = 1.0,
                     beta: float = 0.0, c0: jnp.ndarray | None = None
                     ) -> jnp.ndarray:
        """MvTimesMatAddMv: returns alpha * self @ small + beta * c0, where
        small is (m, k). Streams blocks in groups (Fig. 5 decomposition):
        each group contributes a partial product; only one group's blocks
        are promoted at a time."""
        m, k = small.shape
        assert m == self.ncols, (m, self.ncols)
        acc = jnp.zeros((self.n, k), jnp.float32)
        off = 0
        for g0 in range(0, self.nblocks, self.group_size):
            self._prefetch_group(g0 + self.group_size)
            for i in range(g0, min(g0 + self.group_size, self.nblocks)):
                b = self._blocks[i]
                rows = small[off:off + b.ncols, :]
                eff_alpha = alpha * b.scale
                acc = kops.tsgemm(self.store.get(b.name), rows,
                                  alpha=eff_alpha, beta=1.0, c0=acc,
                                  impl=self.impl)
                off += b.ncols
        if c0 is not None and beta != 0.0:
            acc = acc + beta * c0
        return acc

    def mv_trans_mv(self, other: jnp.ndarray, *, alpha: float = 1.0
                    ) -> jnp.ndarray:
        """MvTransMv: alpha * selfᵀ @ other → (m, k) small matrix.
        Per-block Gram products streamed in groups; the right operand is
        shared across groups (§3.4.3 shared-I/O optimization — it is read
        once because it stays in the device tier)."""
        parts = []
        for i, b in enumerate(self._blocks):
            if i % self.group_size == 0:
                self._prefetch_group(i + self.group_size)
            g = kops.gram(self.store.get(b.name), other,
                          alpha=alpha * b.scale, impl=self.impl)
            parts.append(g)
        return jnp.concatenate(parts, axis=0)

    def mv_add_mv(self, alpha: float, other: "MultiVector", beta: float
                  ) -> "MultiVector":
        """MvAddMv: C <- alpha*A + beta*B (blockwise, same block structure)."""
        assert self.block_widths() == other.block_widths()
        out = MultiVector(self.store, self.n, group_size=self.group_size,
                          readahead=self.readahead, impl=self.impl)
        for i in range(self.nblocks):
            out.append_block(alpha * self.block(i) + beta * other.block(i),
                             pin_recent=False)
        return out

    def mv_dot(self, other: "MultiVector") -> jnp.ndarray:
        """MvDot: columnwise dot products vec[i] = selfᵀ[:,i] · other[:,i]."""
        assert self.block_widths() == other.block_widths()
        outs = []
        for i in range(self.nblocks):
            outs.append(jnp.sum(self.block(i) * other.block(i), axis=0))
        return jnp.concatenate(outs)

    def mv_norm(self) -> jnp.ndarray:
        """MvNorm: column 2-norms."""
        outs = []
        for i in range(self.nblocks):
            outs.append(jnp.sqrt(jnp.sum(self.block(i) ** 2, axis=0)))
        return jnp.concatenate(outs)

    def clone_view(self, idxs: Sequence[int]) -> jnp.ndarray:
        """CloneView: gather a set of columns (materialized)."""
        cols = []
        off = 0
        want = set(int(i) for i in idxs)
        for i, b in enumerate(self._blocks):
            local = [j for j in range(b.ncols) if off + j in want]
            if local:
                cols.append(self.block(i)[:, local])
            off += b.ncols
        return jnp.concatenate(cols, axis=1)

    def conv_layout(self) -> jnp.ndarray:
        """ConvLayout: column-major subspace block → row-major operand for
        SpMM. On TPU this is a logical no-op (XLA layouts); kept for API
        fidelity. Returns the most recent block materialized."""
        return self.block(self.nblocks - 1)

    # ------------------------------------------------------------ restart ops
    def compress(self, q: jnp.ndarray, new_widths: Sequence[int]
                 ) -> "MultiVector":
        """V_new = V @ Q for restart compression (Krylov–Schur). Q is
        (m, m_new); output blocks of widths new_widths. This is the big
        out-of-core GEMM of the restart step — each output block is one
        grouped mv_times_mat pass over the subspace."""
        assert q.shape[0] == self.ncols
        assert sum(new_widths) == q.shape[1]
        out = MultiVector(self.store, self.n, group_size=self.group_size,
                          readahead=self.readahead, impl=self.impl)
        off = 0
        for w in new_widths:
            blk = self.mv_times_mat(q[:, off:off + w])
            out.append_block(blk, pin_recent=False)
            off += w
        return out

    def to_dense(self) -> jnp.ndarray:
        return jnp.concatenate([self.block(i) for i in range(self.nblocks)],
                               axis=1)
