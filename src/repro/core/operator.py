"""LinearOperator — matrix-free "multiply a TAS block by A".

Three first-class implementations (DESIGN.md §4):

  GraphOperator   block-sparse graph adjacency/Laplacian (the paper's case);
                  streams the matrix image and accounts the bytes as SSD
                  reads in the TieredStore (semi-external-memory SpMM).
  NormalOperator  AᵀA for SVD of directed graphs (page graph, §4.3.2).
  HvpOperator     Hessian-vector products of a model loss — the beyond-paper
                  integration that points the eigensolver at the LM substrate
                  (loss-curvature spectra).

Plus the composable *spectral transforms* every solver of the family
inherits through the same `matmat` seam (the Anasazi OperatorTraits idiom
of paper §2):

  ShiftInvertOperator     (A − σI)⁻¹ via an inner blocked CG/CGNR on the
                          wrapped operator's matmat — interior / smallest
                          eigenpairs with which="LM" on the transform.
  ChebyshevFilterOperator p(A) with p a Chebyshev polynomial damping a
                          measured spectral interval — polynomial filtering
                          for the same interior/edge modes without a solve.

Operators *declare* what they can do through `capabilities()` (see below);
solvers dispatch on the declared set instead of sniffing attributes, so a
transform wrapping e.g. the sharded `dist.DistOperator` explicitly drops
the fused-expansion capability (the fused SpMM+CGS2 program computes A·q,
not f(A)·q) rather than silently keeping or losing it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Protocol, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.graphs.tiles import TiledMatrix
from repro.core.tiered import HOST, TieredStore
from repro.kernels import ops as kops
from repro.obs import trace


class LinearOperator(Protocol):
    n: int  # problem size (rows of padded operand)

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A @ X for a TAS block X (n, b)."""
        ...


# --------------------------------------------------------------- capabilities
# Declared operator capabilities — the protocol the solver family dispatches
# on (replaces per-call-site getattr sniffing of `supports_fused_expand`):
#
#   CAP_FUSED_EXPAND        the operator runs one whole expansion step
#                           (SpMM + CGS2 + CholQR2) itself via
#                           `fused_expand(v, q)` — dist.DistOperator.
#   CAP_SPECTRAL_TRANSFORM  matmat applies f(A), not A: the operator wraps
#                           an `.inner` operator and offers
#                           `untransform(theta, vecs)` to map Ritz values
#                           of f(A) back to eigenvalues of A.
CAP_FUSED_EXPAND = "fused_expand"
CAP_SPECTRAL_TRANSFORM = "spectral_transform"


def capabilities(op) -> frozenset:
    """The operator's declared capability set.

    Operators declare via a `capabilities` method (or attribute). Operators
    predating the protocol are adapted here — the legacy
    `supports_fused_expand` attribute sniff lives in THIS function only,
    so call sites (krylov_schur._expand) stay protocol-pure.
    """
    declared = getattr(op, "capabilities", None)
    if declared is not None and not isinstance(declared, property):
        caps = declared() if callable(declared) else declared
        return frozenset(caps)
    caps = set()
    if getattr(op, "supports_fused_expand", False):
        caps.add(CAP_FUSED_EXPAND)
    return frozenset(caps)


@dataclasses.dataclass
class _ImageChunk:
    """One streamed span of the matrix image: the dense blocks of block
    rows [br_lo, br_hi) live in the page store under `name`; the *index*
    (block_cols, rebased block_rows, row mask) stays in fast memory —
    exactly the paper's split of §3.3.1 (matrix index in RAM, edge tiles
    on SSD)."""
    name: str
    n_block_rows: int
    block_cols: jnp.ndarray
    block_rows: jnp.ndarray
    row_mask: jnp.ndarray


class GraphOperator:
    """Semi-external-memory SpMM operator over a TiledMatrix image.

    The matrix image lives on the slow tier; every matmat streams it once
    (sequential read — the paper's §3.3.3 pattern) and the TieredStore
    read counter advances by the image size. The dense operand X is the
    in-memory/fast-tier side of the semi-external split.

    Two residency modes for the image:

      * default (stream_image=False): the dense blocks are RAM/device
        resident jnp arrays; the stream is *accounted* against the store
        but not physically performed — the seed emulation;
      * stream_image=True (requires a store): the edge tiles really do
        live in the store's page files — `__init__` spills them as
        block-row chunks of ~image_chunk_bytes (plus the COO remainder),
        and every matmat walks the chunks through `TieredStore.stream`,
        SpMM-ing each span while the readahead pool stages the next one.
        With `TieredStore(backend="safs")` this makes matmat truly
        semi-external: subspace AND matrix bytes traverse the same page
        cache / vectored-I/O path. Only the matrix *index* stays in fast
        memory, as in the paper.
    """

    _counter = 0

    def __init__(self, tm: TiledMatrix, *, store: TieredStore | None = None,
                 impl: kops.Impl = "auto", symmetric: bool = True,
                 stream_image: bool = False,
                 image_chunk_bytes: int = 4 << 20,
                 image_readahead: int = 2, name: str | None = None):
        self.n = tm.shape[0]
        self.store = store
        self.impl = impl
        self.symmetric = symmetric
        self._image_bytes = tm.nbytes_image()
        self.stream_image = bool(stream_image)
        if self.stream_image:
            if store is None:
                raise ValueError("stream_image=True requires a TieredStore")
            self.tm = None      # blocks live in the page store, not here
            self._init_streamed(tm, image_chunk_bytes, image_readahead, name)
        else:
            self.tm = tm
            self._blocks = jnp.asarray(tm.blocks)
            self._block_cols = jnp.asarray(tm.block_cols)
            self._block_rows = jnp.asarray(
                kops.block_rows_from_ptr(np.asarray(tm.row_ptr)))
            self._row_mask = jnp.asarray(
                kops.empty_row_mask(np.asarray(tm.row_ptr),
                                    tm.block_shape[0]))
            self._coo = (jnp.asarray(tm.coo_rows), jnp.asarray(tm.coo_cols),
                         jnp.asarray(tm.coo_vals))

    # ------------------------------------------------- SSD-streamed image
    def _init_streamed(self, tm: TiledMatrix, chunk_bytes: int,
                       readahead: int, name: str | None) -> None:
        GraphOperator._counter += 1
        self._name = name or f"Aimg{GraphOperator._counter}"
        self._bm = tm.block_shape[0]
        self._readahead = int(readahead)
        self._chunks: List[_ImageChunk] = []
        row_ptr = np.asarray(tm.row_ptr)
        # readonly: the streamed image has no per-chunk dirty tracking, so
        # writing through a chunk name must raise, not silently diverge
        for k, (r0, r1, b0, b1) in enumerate(tm.chunk_block_rows(chunk_bytes)):
            cname = f"{self._name}/tiles/c{k}"
            self.store.put(cname, tm.blocks[b0:b1], tier=HOST, readonly=True)
            sub_ptr = row_ptr[r0:r1 + 1]
            self._chunks.append(_ImageChunk(
                name=cname, n_block_rows=r1 - r0,
                block_cols=jnp.asarray(tm.block_cols[b0:b1]),
                block_rows=jnp.asarray(
                    kops.block_rows_from_ptr(sub_ptr - sub_ptr[0])),
                row_mask=jnp.asarray(
                    kops.empty_row_mask(sub_ptr, self._bm))))
        self._has_coo = tm.coo_vals.size > 0
        if self._has_coo:
            for part, arr in (("coo_rows", tm.coo_rows),
                              ("coo_cols", tm.coo_cols),
                              ("coo_vals", tm.coo_vals)):
                self.store.put(f"{self._name}/{part}", arr, tier=HOST,
                               readonly=True)

    def _matmat_streamed(self, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels.spmm_ref import coo_spmm_ref
        k = x.shape[1]
        parts: List[jnp.ndarray] = []
        names = [c.name for c in self._chunks]
        for ci, blocks in enumerate(self.store.stream(
                names, readahead=self._readahead)):
            c = self._chunks[ci]
            if blocks.shape[0] == 0:     # span of empty block rows
                parts.append(jnp.zeros((c.n_block_rows * self._bm, k),
                                       jnp.float32))
                continue
            parts.append(kops.spmm_blocks(
                blocks, c.block_cols, c.block_rows, c.row_mask, x,
                n_block_rows=c.n_block_rows, impl=self.impl))
        y = (jnp.concatenate(parts, axis=0) if parts
             else jnp.zeros((self.n, k), jnp.float32))
        if self._has_coo:
            y = y + coo_spmm_ref(self.store.get(f"{self._name}/coo_rows"),
                                 self.store.get(f"{self._name}/coo_cols"),
                                 self.store.get(f"{self._name}/coo_vals"),
                                 x, self.n)
        return y

    def delete_image(self) -> None:
        """Drop the spilled image entries (streamed mode only)."""
        if not self.stream_image:
            return
        for c in self._chunks:
            self.store.delete(c.name)
        if self._has_coo:
            for part in ("coo_rows", "coo_cols", "coo_vals"):
                self.store.delete(f"{self._name}/{part}")

    # ---------------------------------------------------------------- apply
    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        with trace.span("operator.matmat", op="GraphOperator",
                        k=int(x.shape[1]), n=self.n,
                        streamed=self.stream_image,
                        bytes=self._image_bytes):
            if self.stream_image:   # reads counted by the store itself
                return self._matmat_streamed(x)
            if self.store is not None:  # account the emulated image stream
                # account_read keeps the parent/session dual books in sync
                # when the store is a namespace facade
                self.store.account_read(self._image_bytes)
            y = kops.spmm_blocks(self._blocks, self._block_cols,
                                 self._block_rows, self._row_mask, x,
                                 n_block_rows=self.tm.n_block_rows,
                                 impl=self.impl)
            rows, cols, vals = self._coo
            if vals.shape[0]:
                from repro.kernels.spmm_ref import coo_spmm_ref
                y = y + coo_spmm_ref(rows, cols, vals, x, self.n)
            return y


class NormalOperator:
    """AᵀA (or AAᵀ) for SVD on directed graphs. Requires the transpose
    image (packed once, offline — the paper builds both images too).

    Both constituent images follow the streamed-image machinery: build via
    `from_tiles(..., stream_image=True)` to spill *both* the forward and
    transpose edge tiles into the page store (an SVD solve otherwise
    silently keeps two full images in RAM), and `delete_image()` drops
    both spills when the solve is done."""

    def __init__(self, a_op: GraphOperator, at_op: GraphOperator):
        self.a = a_op
        self.at = at_op
        self.n = at_op.n

    @classmethod
    def from_tiles(cls, tm_a: TiledMatrix, tm_at: TiledMatrix, *,
                   store: TieredStore | None = None,
                   impl: kops.Impl = "auto", stream_image: bool = False,
                   image_chunk_bytes: int = 4 << 20,
                   image_readahead: int = 2,
                   name: str | None = None) -> "NormalOperator":
        """Build both GraphOperators with the streamed-image configuration
        forwarded to each (the transpose image spills too)."""
        kw = dict(store=store, impl=impl, symmetric=False,
                  stream_image=stream_image,
                  image_chunk_bytes=image_chunk_bytes,
                  image_readahead=image_readahead)
        a_op = GraphOperator(tm_a, name=None if name is None else f"{name}/A",
                             **kw)
        at_op = GraphOperator(tm_at,
                              name=None if name is None else f"{name}/At",
                              **kw)
        return cls(a_op, at_op)

    @property
    def stream_image(self) -> bool:
        return self.a.stream_image or self.at.stream_image

    def delete_image(self) -> None:
        """Drop both operators' spilled images (streamed mode only)."""
        self.a.delete_image()
        self.at.delete_image()

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        with trace.span("operator.matmat", op="NormalOperator",
                        k=int(x.shape[1]), n=self.n):
            return self.at.matmat(self.a.matmat(x))


class DenseOperator:
    """Small dense test operator (oracle in tests)."""

    def __init__(self, a: jnp.ndarray):
        self.a = jnp.asarray(a, jnp.float32)
        self.n = a.shape[0]

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        with trace.span("operator.matmat", op="DenseOperator",
                        k=int(x.shape[1]), n=self.n):
            return self.a @ x


class HvpOperator:
    """Matrix-free Hessian(-GGN)-vector product of `loss_fn(params)`.

    Flattens params to a single vector space of size n (padded to pad_to).
    Each column of the TAS block is one HVP — jitted and vmapped.
    """

    def __init__(self, loss_fn: Callable, params, *, pad_to: int = 8):
        self.loss_fn = loss_fn
        flat, self._unravel = jax.flatten_util.ravel_pytree(params)
        self._params_flat = flat
        self.n_logical = flat.shape[0]
        self.n = -(-self.n_logical // pad_to) * pad_to

        def hvp_single(v_flat):
            def grad_flat(p_flat):
                g = jax.grad(self.loss_fn)(self._unravel(p_flat))
                return jax.flatten_util.ravel_pytree(g)[0]
            _, hv = jax.jvp(grad_flat, (self._params_flat,), (v_flat,))
            return hv

        self._hvp = jax.jit(jax.vmap(hvp_single, in_axes=1, out_axes=1))

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        with trace.span("operator.matmat", op="HvpOperator",
                        k=int(x.shape[1]), n=self.n):
            v = x[:self.n_logical, :]
            hv = self._hvp(v)
            if self.n == self.n_logical:
                return hv
            return jnp.pad(hv, ((0, self.n - self.n_logical), (0, 0)))


# ---------------------------------------------------------------- transforms
def _rayleigh_eigenvalues(inner, vecs) -> np.ndarray:
    """λ_i = v_iᵀ A v_i / v_iᵀ v_i — recover original-operator eigenvalues
    from a transform's Ritz vectors (one extra inner matmat)."""
    v = jnp.asarray(vecs, jnp.float32)
    av = inner.matmat(v)
    num = jnp.sum(v * av, axis=0)
    den = jnp.sum(v * v, axis=0)
    return np.asarray(num / jnp.maximum(den, 1e-30), np.float64)


class ShiftInvertOperator:
    """(A − σI)⁻¹ as a LinearOperator: interior/smallest eigenpairs for the
    whole solver family through the matmat seam.

    Eigenvalues map as μ = 1/(λ − σ), so the λ nearest σ become the largest
    |μ| — run any solver with which="LM" on the transform and the wanted
    interior modes converge first. `untransform` maps Ritz values back
    (Rayleigh quotients on the inner operator when vectors are available —
    more accurate than σ + 1/μ once the inner solves are inexact).

    Each matmat solves (A − σI) Y = X blocked over the columns with an
    inner Krylov iteration on the *wrapped* operator's matmat:

      inner="cg"    plain conjugate gradients — fastest, but requires the
                    shifted operator to be definite (σ outside the
                    spectrum: smallest/largest-eigenpair use);
      inner="cgnr" (default) CG on the squared system
                    (A − σI)² Y = (A − σI) X — SPD for ANY σ that is not
                    exactly an eigenvalue, so interior shifts are safe at
                    the cost of two inner matmats per iteration (and a
                    squared condition number).

    Composes with any inner operator, including `dist.DistOperator` —
    the declared capability set is {spectral_transform} only: the inner
    operator's fused-expansion program computes A·q, not (A−σI)⁻¹·q, so
    the transform drops CAP_FUSED_EXPAND *explicitly* (solvers fall back
    to the streamed bcgs2 path by protocol, not by silent getattr miss).
    """

    def __init__(self, inner, sigma: float, *, inner_solver: str = "cgnr",
                 cg_tol: float = 1e-8, cg_maxiter: int = 400):
        if inner_solver not in ("cg", "cgnr"):
            raise ValueError(f"inner_solver must be cg|cgnr, "
                             f"got {inner_solver!r}")
        self.inner = inner
        self.sigma = float(sigma)
        self.n = inner.n
        self.inner_solver = inner_solver
        self.cg_tol = float(cg_tol)
        self.cg_maxiter = int(cg_maxiter)
        self.n_inner_iters = 0      # total inner CG iterations (telemetry)

    def capabilities(self) -> frozenset:
        return frozenset({CAP_SPECTRAL_TRANSFORM})

    def _shifted(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.inner.matmat(x) - self.sigma * x

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        with trace.span("operator.matmat", op="ShiftInvertOperator",
                        k=int(x.shape[1]), n=self.n,
                        inner=self.inner_solver) as sp:
            x = jnp.asarray(x, jnp.float32)
            if self.inner_solver == "cg":
                apply_fn, rhs = self._shifted, x
            else:                               # CGNR: (A−σ)² y = (A−σ) x
                apply_fn = lambda v: self._shifted(self._shifted(v))  # noqa: E731,E501
                rhs = self._shifted(x)
            y, iters = _block_cg(apply_fn, rhs, tol=self.cg_tol,
                                 maxiter=self.cg_maxiter)
            self.n_inner_iters += iters
            sp.set(inner_iters=iters)
            return y

    def untransform(self, theta, vecs=None) -> np.ndarray:
        if vecs is not None:
            return _rayleigh_eigenvalues(self.inner, vecs)
        mu = np.asarray(theta, np.float64)
        safe = np.where(np.abs(mu) > 1e-300, mu, 1e-300)
        return self.sigma + 1.0 / safe


def _block_cg(apply_fn, b: jnp.ndarray, *, tol: float, maxiter: int
              ) -> Tuple[jnp.ndarray, int]:
    """CG on an SPD apply_fn, all columns of b advanced together (per-column
    step sizes). Columns that converge early just keep taking ~zero-length
    steps; the loop exits when the worst column is under tol."""
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.sum(r * r, axis=0)
    b_norm = jnp.sqrt(jnp.maximum(jnp.sum(b * b, axis=0), 1e-30))
    it = 0
    for it in range(1, maxiter + 1):
        ap = apply_fn(p)
        denom = jnp.sum(p * ap, axis=0)
        alpha = jnp.where(jnp.abs(denom) > 1e-30, rs / denom, 0.0)
        x = x + p * alpha[None, :]
        r = r - ap * alpha[None, :]
        rs_new = jnp.sum(r * r, axis=0)
        if float(jnp.max(jnp.sqrt(rs_new) / b_norm)) <= tol:
            rs = rs_new
            break
        beta = jnp.where(rs > 1e-30, rs_new / rs, 0.0)
        p = r + p * beta[None, :]
        rs = rs_new
    return x, it


class ChebyshevFilterOperator:
    """p(A) with p = T_deg ∘ affine: polynomial spectral filter.

    The affine map sends the *damped* interval [lo, hi] onto [−1, 1] where
    Chebyshev polynomials stay bounded by 1; eigenvalues outside the
    interval are amplified like cosh(deg·acosh|t(λ)|) — exponentially in
    the degree. Damping the unwanted part of a measured spectral range
    (`estimate_spectral_range`) therefore turns edge/interior modes into
    the dominant eigenvalues of p(A), reachable with which="LM" by any
    solver — no linear solves, `degree` inner matmats per application.

    Like ShiftInvertOperator this is a declared spectral transform:
    `untransform` recovers λ via Rayleigh quotients on the inner operator
    (T_deg is not invertible — the polynomial value alone cannot identify
    λ, so vectors are required).
    """

    def __init__(self, inner, interval: Tuple[float, float], *,
                 degree: int = 10):
        lo, hi = float(interval[0]), float(interval[1])
        if not hi > lo:
            raise ValueError(f"damped interval must have hi > lo, "
                             f"got ({lo}, {hi})")
        self.inner = inner
        self.n = inner.n
        self.lo, self.hi = lo, hi
        self.degree = int(degree)

    def capabilities(self) -> frozenset:
        return frozenset({CAP_SPECTRAL_TRANSFORM})

    def _mapped(self, x: jnp.ndarray) -> jnp.ndarray:
        c = 0.5 * (self.lo + self.hi)
        e = 0.5 * (self.hi - self.lo)
        return (self.inner.matmat(x) - c * x) / e

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        with trace.span("operator.matmat", op="ChebyshevFilterOperator",
                        k=int(x.shape[1]), n=self.n, degree=self.degree):
            t_prev = jnp.asarray(x, jnp.float32)
            t_cur = self._mapped(t_prev)
            for _ in range(self.degree - 1):
                t_prev, t_cur = t_cur, 2.0 * self._mapped(t_cur) - t_prev
            return t_cur

    def untransform(self, theta, vecs=None) -> np.ndarray:
        if vecs is None:
            raise ValueError("ChebyshevFilterOperator.untransform needs the "
                             "Ritz vectors (the polynomial is not invertible)"
                             " — solve with compute_eigenvectors=True")
        return _rayleigh_eigenvalues(self.inner, vecs)


def estimate_spectral_range(op, *, iters: int = 30, seed: int = 0,
                            safety: float = 0.05) -> Tuple[float, float]:
    """Cheap [λmin, λmax] estimate for filter construction: `iters` steps
    of scalar Lanczos (full reorthogonalization, host-side tridiagonal),
    widened by the last off-diagonal coupling plus a relative `safety`
    margin so the true extremes stay inside the returned interval."""
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (op.n, 1), jnp.float32)
    v = v / jnp.linalg.norm(v)
    basis = [v]
    alphas: List[float] = []
    betas: List[float] = []
    beta = 0.0
    for _ in range(iters):
        w = op.matmat(basis[-1])
        alpha = float(jnp.sum(basis[-1] * w))
        alphas.append(alpha)
        for u in basis:                       # full reorth — iters is tiny
            w = w - u * jnp.sum(u * w)
        beta = float(jnp.linalg.norm(w))
        if beta < 1e-12:
            beta = 0.0
            break
        betas.append(beta)
        basis.append(w / beta)
    t = np.diag(np.asarray(alphas))
    if len(alphas) > 1:
        off = np.asarray(betas[:len(alphas) - 1])
        t += np.diag(off, 1) + np.diag(off, -1)
    ritz = np.linalg.eigvalsh(t)
    lo, hi = float(ritz[0]) - beta, float(ritz[-1]) + beta
    pad = safety * max(abs(lo), abs(hi), 1e-30)
    return lo - pad, hi + pad
