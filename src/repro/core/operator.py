"""LinearOperator — matrix-free "multiply a TAS block by A".

Three first-class implementations (DESIGN.md §4):

  GraphOperator   block-sparse graph adjacency/Laplacian (the paper's case);
                  streams the matrix image and accounts the bytes as SSD
                  reads in the TieredStore (semi-external-memory SpMM).
  NormalOperator  AᵀA for SVD of directed graphs (page graph, §4.3.2).
  HvpOperator     Hessian-vector products of a model loss — the beyond-paper
                  integration that points the eigensolver at the LM substrate
                  (loss-curvature spectra).
"""
from __future__ import annotations

from typing import Callable, Protocol

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.graphs.tiles import TiledMatrix
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


class LinearOperator(Protocol):
    n: int  # problem size (rows of padded operand)

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A @ X for a TAS block X (n, b)."""
        ...


class GraphOperator:
    """Semi-external-memory SpMM operator over a TiledMatrix image.

    The matrix image lives on the slow tier; every matmat streams it once
    (sequential read — the paper's §3.3.3 pattern) and the TieredStore
    read counter advances by the image size. The dense operand X is the
    in-memory/fast-tier side of the semi-external split.
    """

    def __init__(self, tm: TiledMatrix, *, store: TieredStore | None = None,
                 impl: kops.Impl = "auto", symmetric: bool = True):
        self.tm = tm
        self.n = tm.shape[0]
        self.store = store
        self.impl = impl
        self.symmetric = symmetric
        self._blocks = jnp.asarray(tm.blocks)
        self._block_cols = jnp.asarray(tm.block_cols)
        self._block_rows = jnp.asarray(
            kops.block_rows_from_ptr(np.asarray(tm.row_ptr)))
        self._row_mask = jnp.asarray(
            kops.empty_row_mask(np.asarray(tm.row_ptr), tm.block_shape[0]))
        self._coo = (jnp.asarray(tm.coo_rows), jnp.asarray(tm.coo_cols),
                     jnp.asarray(tm.coo_vals))
        self._image_bytes = tm.nbytes_image()

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.store is not None:  # account the streamed image read
            self.store.stats.host_bytes_read += self._image_bytes
            self.store.stats.host_reads += 1
        y = kops.spmm_blocks(self._blocks, self._block_cols, self._block_rows,
                             self._row_mask, x,
                             n_block_rows=self.tm.n_block_rows, impl=self.impl)
        rows, cols, vals = self._coo
        if vals.shape[0]:
            from repro.kernels.spmm_ref import coo_spmm_ref
            y = y + coo_spmm_ref(rows, cols, vals, x, self.n)
        return y


class NormalOperator:
    """AᵀA (or AAᵀ) for SVD on directed graphs. Requires the transpose
    image (packed once, offline — the paper builds both images too)."""

    def __init__(self, a_op: GraphOperator, at_op: GraphOperator):
        self.a = a_op
        self.at = at_op
        self.n = at_op.n

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.at.matmat(self.a.matmat(x))


class DenseOperator:
    """Small dense test operator (oracle in tests)."""

    def __init__(self, a: jnp.ndarray):
        self.a = jnp.asarray(a, jnp.float32)
        self.n = a.shape[0]

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.a @ x


class HvpOperator:
    """Matrix-free Hessian(-GGN)-vector product of `loss_fn(params)`.

    Flattens params to a single vector space of size n (padded to pad_to).
    Each column of the TAS block is one HVP — jitted and vmapped.
    """

    def __init__(self, loss_fn: Callable, params, *, pad_to: int = 8):
        self.loss_fn = loss_fn
        flat, self._unravel = jax.flatten_util.ravel_pytree(params)
        self._params_flat = flat
        self.n_logical = flat.shape[0]
        self.n = -(-self.n_logical // pad_to) * pad_to

        def hvp_single(v_flat):
            def grad_flat(p_flat):
                g = jax.grad(self.loss_fn)(self._unravel(p_flat))
                return jax.flatten_util.ravel_pytree(g)[0]
            _, hv = jax.jvp(grad_flat, (self._params_flat,), (v_flat,))
            return hv

        self._hvp = jax.jit(jax.vmap(hvp_single, in_axes=1, out_axes=1))

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        v = x[:self.n_logical, :]
        hv = self._hvp(v)
        if self.n == self.n_logical:
            return hv
        return jnp.pad(hv, ((0, self.n - self.n_logical), (0, 0)))
