"""Block (re)orthogonalization — step (1) of Algorithm 1.

The paper identifies reorthogonalization (MvTransMv + MvTimesMatAddMv) as
the dominant cost when computing many eigenvalues (>90% of SEM runtime).
We provide the TPU-native primitives:

  * cholqr  — CholeskyQR2: Gram → Cholesky → triangular solve, twice.
              This is THE tall-skinny QR for TPUs (two MXU GEMMs + a tiny
              host-side factorization) replacing Householder QR.
  * svqb    — Stathopoulos–Wu SVQB, rank-revealing fallback when the block
              is numerically rank deficient.
  * bcgs2   — block Gram–Schmidt (×2) of a new block against an
              out-of-core MultiVector basis. fused=True (default) runs
              each pass as ONE streamed subspace read
              (`MultiVector.project_out`: h_i = V_iᵀw and w ← w − V_i h_i
              in the same block visit), so CGS2 costs 2 reads of the
              on-SSD subspace; fused=False keeps the textbook
              MvTransMv + MvTimesMatAddMv pair per pass (4 reads) — the
              paper's unfused I/O pattern, retained for parity testing
              and the bench_subspace_io before/after column (§3.4.3:
              minimizing passes over the subspace is the whole game).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.multivector import MultiVector
from repro.kernels import ops as kops


def _robust_cholesky(g: jnp.ndarray) -> jnp.ndarray:
    """Shifted Cholesky with escalating shifts (rank-deficient guards):
    computes candidates at increasing regularization and keeps the first
    NaN-free one — branch-free, so it stays jittable."""
    eye = jnp.eye(g.shape[0], dtype=g.dtype)
    tr = jnp.trace(g) / g.shape[0] + 1e-30
    l = jnp.linalg.cholesky(g + 1e-7 * tr * eye)
    for shift in (1e-4, 1e-1):
        cand = jnp.linalg.cholesky(g + shift * tr * eye)
        bad = jnp.any(jnp.isnan(l))
        l = jnp.where(bad, cand, l)
    return l


def cholqr(x: jnp.ndarray, *, impl: kops.Impl = "auto", iters: int = 2
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CholeskyQR² — returns (Q, R) with Q orthonormal, X = Q R.

    Shifted-Cholesky guards ill-conditioning: G + eps*tr(G)*I, with
    escalating shifts on (near-)rank-deficient blocks.
    """
    r_total = jnp.eye(x.shape[1], dtype=jnp.float32)
    q = x
    for _ in range(iters):
        g = kops.gram(q, q, impl=impl)
        l = _robust_cholesky(g)
        r = l.T
        q = jax.scipy.linalg.solve_triangular(l, q.T, lower=True).T
        r_total = r @ r_total
    return q, r_total


def svqb_transform(x: jnp.ndarray, *, impl: kops.Impl = "auto",
                   tol: float = 1e-10) -> Tuple[jnp.ndarray, int]:
    """The SVQB basis transform T (b×b) with Q = X @ T orthonormal on the
    numerical range of X; returns (T, numerical_rank). Rank-deficient
    directions map to zero columns of Q.

    Exposed separately from `svqb` so callers can co-apply the SAME
    transform to a parallel image of the block: LOBPCG maintains AS
    algebraically (AX ← AX·T whenever X ← X·T), which keeps the A-images
    exact without any extra operator applies."""
    g = kops.gram(x, x, impl=impl)
    d = jnp.sqrt(jnp.clip(jnp.diag(g), 1e-30, None))
    dinv = 1.0 / d
    gs = g * dinv[:, None] * dinv[None, :]
    w, v = jnp.linalg.eigh(gs)
    keep = w > tol * jnp.max(w)
    winv = jnp.where(keep, 1.0 / jnp.sqrt(jnp.clip(w, 1e-30, None)), 0.0)
    t = (dinv[:, None] * v) * winv[None, :]
    return t, int(jnp.sum(keep))


def svqb(x: jnp.ndarray, *, impl: kops.Impl = "auto", tol: float = 1e-10
         ) -> Tuple[jnp.ndarray, int]:
    """SVQB orthonormalization; returns (Q, numerical_rank). Rank-deficient
    directions are replaced by zero columns (caller refreshes them)."""
    t, rank = svqb_transform(x, impl=impl, tol=tol)
    return kops.tsgemm(x, t, impl=impl), rank


def bcgs2(basis: MultiVector, w: jnp.ndarray, *, impl: kops.Impl = "auto",
          fused: bool = True
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Orthogonalize block W against the out-of-core basis V, twice, then
    orthonormalize within the block (CholQR).

    Returns (Q, H, R):  W = V @ H + Q @ R,  VᵀQ = 0,  QᵀQ = I.
    H is (m, b) — the projection coefficients (Krylov H entries). This is
    the ONE convention: H = h1 + h2 including the second-pass correction,
    so the Krylov invariant holds with the returned H exactly.

    I/O per pass: fused=True streams the basis once (`project_out` — the
    Gram and the AXPY update share the block visit; block-MGS order, so
    W = V·h + w stays exact by telescoping); fused=False streams it twice
    (MvTransMv then MvTimesMatAddMv — classical CGS order). Both yield
    the same Q/H/R to rounding; CGS2's second pass wipes the O(eps·κ)
    first-pass difference either way.
    """
    if basis.nblocks == 0:
        q, r = cholqr(w, impl=impl)
        h = jnp.zeros((0, w.shape[1]), jnp.float32)
        return q, h, r
    if fused:
        h1, w = basis.project_out(w)              # one streamed read
        h2, w = basis.project_out(w)              # second pass (CGS2)
    else:
        h1 = basis.mv_trans_mv(w)                 # VᵀW
        w = w - basis.mv_times_mat(h1)            # W -= V (VᵀW)
        h2 = basis.mv_trans_mv(w)
        w = w - basis.mv_times_mat(h2)
    q, r = cholqr(w, impl=impl)
    return q, h1 + h2, r


def ortho_error(q: jnp.ndarray) -> float:
    """‖QᵀQ − I‖_max — test invariant."""
    g = q.T @ q
    return float(jnp.max(jnp.abs(g - jnp.eye(g.shape[0], dtype=g.dtype))))
