"""Ritz residuals and convergence tests (Algorithm 1, steps 3–4)."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


def sort_ritz(theta: jnp.ndarray, which: str) -> np.ndarray:
    """Return index order putting the wanted Ritz values first.

    LM: largest magnitude (spectral analysis default),
    LA: largest algebraic, SA: smallest algebraic.
    """
    t = np.asarray(theta)
    if which == "LM":
        return np.argsort(-np.abs(t), kind="stable")
    if which == "LA":
        return np.argsort(-t, kind="stable")
    if which == "SA":
        return np.argsort(t, kind="stable")
    raise ValueError(f"unknown which={which}")


def ritz_residual_bounds(s_coupling: jnp.ndarray, y: jnp.ndarray
                         ) -> jnp.ndarray:
    """Cheap residual norms from the Krylov relation A V = V H + Q S eᵀ:
    ‖A x_i − θ_i x_i‖ = ‖S y_i[last-block rows]‖ — no I/O needed.

    s_coupling: (b, m) coupling (nonzero only in trailing columns pre-restart)
    y:          (m, k) Ritz eigenvectors of H.
    """
    return jnp.linalg.norm(s_coupling @ y, axis=0)


@dataclasses.dataclass
class EigResult:
    eigenvalues: np.ndarray        # (nev,)
    eigenvectors: np.ndarray | None  # (n, nev) or None if not materialized
    residuals: np.ndarray          # (nev,) cheap bounds at convergence
    n_restarts: int
    n_ops: int                     # number of operator block applications
    m_subspace: int
    converged: bool
    io_stats: dict | None = None
    trace: object | None = None    # obs.Tracer when solve(..., trace=) was used
    resumed_step: int | None = None  # checkpoint step this solve resumed from


def true_residuals(op, x: jnp.ndarray, theta: Sequence[float]) -> np.ndarray:
    """‖A x_i − θ_i x_i‖₂ / max(1,|θ_i|) — the expensive exact check used by
    tests and benchmarks (one extra operator pass)."""
    ax = op.matmat(x)
    th = jnp.asarray(theta, jnp.float32)
    r = ax - x * th[None, :]
    return np.asarray(jnp.linalg.norm(r, axis=0)
                      / jnp.maximum(1.0, jnp.abs(th)))
