"""The pluggable solver family — one protocol over the streamed substrate.

The paper frames FlashEigen as an Anasazi-framework extension (§2):
Krylov–Schur, Block Davidson and LOBPCG are interchangeable *solver
managers* over the same MultiVector/SpMM traits. This module is that seam
for the repo: every eigensolver registers as a `Solver` implementation and
drivers call

    solve(op, nev, method="krylov_schur" | "lanczos" | "lobpcg" | "svd")

instead of hard-coding one algorithm. All implementations share the same
substrate contract through `SolverContext`:

  operator    any `LinearOperator` (GraphOperator, DistOperator, HvpOperator,
              a spectral transform, ...) — consulted for declared
              capabilities (`core.operator.capabilities`), never sniffed;
  store       the `TieredStore` holding every out-of-core block the method
              allocates, so `EigResult.io_stats` is comparable across
              methods (bytes-per-converged-pair is the paper's real
              question — `benchmarks/bench_eigen.py` measures it);
  ortho       the orthogonalization policy ("fused" streams each CGS /
              gram / update step as one multi-consumer `SubspacePass`;
              "unfused" keeps the single-consumer reference passes);
  which/tol/max_iters and the convergence state they imply;
  callback    per-restart (or per-iteration) telemetry
              `callback(step, theta[:nev], res[:nev])` for convergence
              traces without re-running.

Spectral transforms compose at this layer: when the operator declares
`CAP_SPECTRAL_TRANSFORM` (ShiftInvertOperator, ChebyshevFilterOperator),
`solve` runs the chosen method on the transform — `which` then selects in
the *transformed* spectrum, "LM" being the natural choice since both
transforms map wanted eigenvalues to dominant ones — and afterwards maps
the Ritz values back through `op.untransform` and replaces the cheap
residual bounds with true residuals measured against the *inner* operator,
so the returned `EigResult` always describes eigenpairs of A itself.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Protocol, Union

import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.progress import ConvergenceTracker
from repro.core.krylov_schur import eigsh
from repro.core.lanczos import lanczos_eigsh
from repro.core.lobpcg import lobpcg
from repro.core.operator import CAP_SPECTRAL_TRANSFORM, capabilities
from repro.core.residuals import EigResult
from repro.core.svd import svds
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


@dataclasses.dataclass
class SolverContext:
    """Everything a solver implementation receives: the operator, the
    shared block substrate, the ortho policy, the convergence targets and
    the telemetry hook. One context = one solve."""
    op: object
    nev: int
    which: str
    tol: float
    max_iters: int
    store: TieredStore
    block_size: Optional[int] = None
    ortho: str = "fused"                  # "fused" | "unfused" pass policy
    impl: kops.Impl = "auto"
    seed: int = 0
    compute_eigenvectors: bool = True
    callback: Optional[Callable] = None
    checkpoint: Optional[object] = None   # ckpt.solver.CheckpointPolicy
    resume: Optional[str] = None          # checkpoint root to resume from
    options: Dict = dataclasses.field(default_factory=dict)
    # method-specific extras (num_blocks, precond, at_op, ...)

    @property
    def fused_passes(self) -> bool:
        return self.ortho == "fused"


class Solver(Protocol):
    """A solver implementation: a name for the registry plus a solve
    entrypoint. Implementations are thin adapters over the algorithm
    modules — the algorithms stay importable and testable on their own."""
    name: str

    def solve(self, ctx: SolverContext) -> EigResult:
        ...


def _make_checkpointer(ctx: SolverContext, method: str, *, block_size):
    """Build the checkpoint/resume bridge for the methods that support it
    (None when the context asks for neither). The solve-shape params are
    recorded in every snapshot and verified on resume, so a checkpoint
    can never silently continue a *different* solve."""
    if ctx.checkpoint is None and ctx.resume is None:
        return None
    from repro.ckpt.solver import SolveCheckpointer
    return SolveCheckpointer(
        ctx.checkpoint, method=method,
        resume_from=(os.fspath(ctx.resume) if ctx.resume else None),
        params={"nev": ctx.nev, "which": ctx.which,
                "block_size": block_size})


class _KrylovSchur:
    name = "krylov_schur"
    default_which = "LM"

    def solve(self, ctx: SolverContext) -> EigResult:
        b = ctx.block_size or 4
        return eigsh(
            ctx.op, ctx.nev, block_size=b,
            num_blocks=ctx.options.get("num_blocks"),
            tol=ctx.tol, max_restarts=ctx.max_iters, which=ctx.which,
            store=ctx.store, impl=ctx.impl, seed=ctx.seed,
            group_size=ctx.options.get("group_size", 8),
            compute_eigenvectors=ctx.compute_eigenvectors,
            fused_passes=ctx.fused_passes, callback=ctx.callback,
            checkpointer=_make_checkpointer(ctx, self.name, block_size=b))


class _Lanczos:
    name = "lanczos"
    default_which = "LM"

    def solve(self, ctx: SolverContext) -> EigResult:
        return lanczos_eigsh(
            ctx.op, ctx.nev, block_size=ctx.block_size or 4,
            num_blocks=ctx.options.get("num_blocks"), which=ctx.which,
            store=ctx.store, impl=ctx.impl, seed=ctx.seed,
            group_size=ctx.options.get("group_size", 8),
            compute_eigenvectors=ctx.compute_eigenvectors,
            fused_passes=ctx.fused_passes, callback=ctx.callback)


class _Lobpcg:
    name = "lobpcg"
    default_which = "LA"

    def solve(self, ctx: SolverContext) -> EigResult:
        return lobpcg(
            ctx.op, ctx.nev, block_size=ctx.block_size,
            tol=ctx.tol, max_iters=ctx.max_iters, which=ctx.which,
            precond=ctx.options.get("precond"), store=ctx.store,
            seed=ctx.seed, impl=ctx.impl, fused_passes=ctx.fused_passes,
            group_size=ctx.options.get("group_size", 8),
            callback=ctx.callback,
            checkpointer=_make_checkpointer(
                ctx, self.name, block_size=ctx.block_size or ctx.nev))


class _Svd:
    """`svd.svds` behind the family dispatch: eigensolve of AᵀA via the
    Krylov–Schur manager, σ = √λ. Requires `at_op` (the Aᵀ operator) in
    ctx.options; the returned EigResult carries σ as `eigenvalues` and U
    as `eigenvectors` (use `svd.svds` directly for the full triplet)."""
    name = "svd"
    default_which = "LA"

    def solve(self, ctx: SolverContext) -> EigResult:
        at_op = ctx.options.get("at_op")
        if at_op is None:
            raise ValueError("method='svd' needs options={'at_op': <Aᵀ op>}")
        r = svds(ctx.op, at_op, ctx.nev, block_size=ctx.block_size or 2,
                 num_blocks=ctx.options.get("num_blocks"), tol=ctx.tol,
                 max_restarts=ctx.max_iters, store=ctx.store, impl=ctx.impl,
                 seed=ctx.seed, compute_vectors=ctx.compute_eigenvectors,
                 callback=ctx.callback)
        return EigResult(
            eigenvalues=r.s, eigenvectors=r.u,
            residuals=np.zeros_like(r.s), n_restarts=r.n_restarts,
            n_ops=r.n_ops, m_subspace=0, converged=r.converged,
            io_stats=r.io_stats)


_REGISTRY: Dict[str, Solver] = {}


def register_solver(solver: Solver) -> None:
    """Add (or replace) a family member. Exposed so experiments can
    register e.g. a Block-Davidson prototype without touching core."""
    _REGISTRY[solver.name] = solver


def solver_names() -> list:
    return sorted(_REGISTRY)


for _s in (_KrylovSchur(), _Lanczos(), _Lobpcg(), _Svd()):
    register_solver(_s)


def _untransform(op, res: EigResult) -> EigResult:
    """Map an EigResult computed on a spectral transform back to the inner
    operator: eigenvalues via `op.untransform` (Rayleigh quotients on the
    inner operator when vectors were materialized), residuals re-measured
    against the inner operator (the solver's cheap bounds were residuals
    of f(A), which say nothing quantitative about A)."""
    vecs = res.eigenvectors
    lam = op.untransform(res.eigenvalues,
                         None if vecs is None else jnp.asarray(vecs))
    if vecs is None:
        return dataclasses.replace(res, eigenvalues=lam)
    x = jnp.asarray(vecs, jnp.float32)
    ax = op.inner.matmat(x)
    th = jnp.asarray(lam, jnp.float32)
    resid = np.asarray(jnp.linalg.norm(ax - x * th[None, :], axis=0),
                       np.float64)
    return dataclasses.replace(res, eigenvalues=lam, residuals=resid)


def solve(op, nev: int, *, method: str = "krylov_schur",
          which: str | None = None, tol: float = 1e-6,
          max_iters: int = 60, block_size: int | None = None,
          store: TieredStore | None = None, ortho: str = "fused",
          impl: kops.Impl = "auto", seed: int = 0,
          compute_eigenvectors: bool = True,
          callback: Callable | None = None,
          trace: Union[obs_trace.Tracer, str, os.PathLike, None] = None,
          checkpoint=None, resume: Union[str, os.PathLike, None] = None,
          **options) -> EigResult:
    """Solve for `nev` eigenpairs of `op` with the chosen family member.

    method: one of `solver_names()` — "krylov_schur" (the paper's driver),
    "lanczos" (HEIGEN-style no-restart baseline), "lobpcg" (3·b working
    set, out-of-core [X, W, P]), "svd" (AᵀA Gram path; needs
    options={'at_op': ...}).

    which defaults per method ("LM" for the Krylov solvers, "LA" for
    LOBPCG/svd). When `op` declares CAP_SPECTRAL_TRANSFORM, `which`
    selects in the transformed spectrum (default "LM": both transforms
    map the wanted part of the spectrum to dominant eigenvalues) and the
    result is mapped back to eigenpairs of the inner operator — so e.g.

        solve(ShiftInvertOperator(a_op, sigma), nev, method="lobpcg")

    returns the `nev` eigenvalues of A nearest sigma, ordered by
    proximity, with true A-residuals.

    trace: pass an `obs.Tracer` (or a path — a fresh Tracer is created and
    its JSONL timeline written there on completion) to record the whole
    solve: a root "solve" span, every instrumented substrate span
    (operator applies, streamed passes, SAFS fill/evict/retire/
    prefetch-wait), per-step "convergence.step" events with an ETA
    estimate, and a "solve.io" metrics record with before/after/delta
    I/O-counter snapshots. The solver implementations are untouched —
    everything rides the module-level tracer + the `callback` seam. The
    Tracer is attached to the result as `EigResult.trace`; feed its JSONL
    to `python -m repro.obs.report` for the human/CI report or
    `write_chrome()` for Perfetto.

    checkpoint: a `ckpt.solver.CheckpointPolicy(root, every_restarts=N,
    guard=...)` — the solve snapshots its full state at restart (eigsh) /
    iteration (lobpcg) boundaries into `root` and, when the policy's
    `ft.PreemptionGuard` fires mid-solve, finishes the in-flight restart,
    checkpoints, and raises `ckpt.solver.SolveSuspended` (exit-resumable
    SIGTERM handling). resume: a checkpoint root to continue from — the
    solve restores the newest committed snapshot bit-identically and
    walks on; pass both to keep checkpointing after a resume. Supported
    by the out-of-core iterative methods ("krylov_schur", "lobpcg").

    All remaining keyword arguments land in `SolverContext.options`
    (num_blocks, group_size, precond, at_op, ...).
    """
    if method not in _REGISTRY:
        raise ValueError(f"unknown method {method!r}; "
                         f"registered: {solver_names()}")
    if (checkpoint is not None or resume is not None) and method not in (
            "krylov_schur", "lobpcg"):
        raise ValueError(
            f"checkpoint/resume is supported for methods "
            f"'krylov_schur' and 'lobpcg', not {method!r}")
    solver = _REGISTRY[method]
    is_transform = CAP_SPECTRAL_TRANSFORM in capabilities(op)
    if which is None:
        which = "LM" if is_transform else getattr(solver, "default_which",
                                                  "LM")
    if is_transform and method == "lobpcg" and which == "LM":
        # LOBPCG optimizes an algebraic extreme; for the transforms LM ≈ LA
        # (shift-invert near a dominant σ-neighborhood, Chebyshev filters
        # are ≥ 1 on the wanted set) — take the algebraic top.
        which = "LA"

    trace_path = None
    tracer = None
    if trace is not None:
        if isinstance(trace, obs_trace.Tracer):
            tracer = trace
        else:
            trace_path = os.fspath(trace)
            tracer = obs_trace.Tracer()

    ctx = SolverContext(
        op=op, nev=nev, which=which, tol=tol, max_iters=max_iters,
        store=store or TieredStore(), block_size=block_size, ortho=ortho,
        impl=impl, seed=seed, compute_eigenvectors=compute_eigenvectors,
        callback=callback, checkpoint=checkpoint,
        resume=os.fspath(resume) if resume is not None else None,
        options=options)

    if tracer is None:
        res = solver.solve(ctx)
        if is_transform:
            res = _untransform(op, res)
        return res

    conv = ConvergenceTracker(tracer, tol=tol, nev=nev, method=method)
    ctx.callback = conv.chain(callback)
    with obs_trace.tracing(tracer):
        with obs_trace.span("solve", method=method, nev=nev, which=which,
                            tol=tol) as sp:
            s0 = obs_metrics.snapshot_store(ctx.store)
            res = solver.solve(ctx)
            if is_transform:
                res = _untransform(op, res)
            s1 = obs_metrics.snapshot_store(ctx.store)
            sp.set(converged=res.converged, restarts=res.n_restarts,
                   n_ops=res.n_ops)
        tracer.metric("solve.io", {"start": s0, "end": s1,
                                   "delta": obs_metrics.delta(s0, s1)})
    if trace_path is not None:
        tracer.write_jsonl(trace_path)
    return dataclasses.replace(res, trace=tracer)
