"""Fused streamed subspace passes — §3.4.3's pass minimization made a type.

The paper's cost model is brutal and simple: reorthogonalization dominates
SEM runtime (>90%) and its cost is *streamed reads of the on-SSD subspace*.
The cheapest bandwidth is the bytes you never read, so every whole-subspace
operation should piggyback on the same block visit instead of walking the
subspace again. `SubspacePass` is that plan: attach any number of consumers
(Gram against a device-resident operand, multi-accumulator TSGEMM, a fused
project-out update, dot/norm reductions, arbitrary per-block visitors),
then `run()` streams each block of the MultiVector **exactly once**,
handing the materialized block to every consumer in attachment order.

I/O discipline per pass:

  * the full pass's block list is announced to `TieredStore.prefetch` up
    front (the backend's readahead window bounds how much actually
    queues), and the window is re-offered as the walk advances — this
    replaces the ad-hoc per-group `_prefetch_group` calls, so *every*
    subspace walk gets readahead, including the small reductions
    (mv_dot / mv_norm / clone_view) that previously had none;
  * one `TieredStore.get` per block per pass, shared by all consumers
    (lazy MvScale factors are applied once, to the shared value);
  * `TieredStore.begin_pass()` is called once per run, so
    `IOStats.passes` counts streamed subspace reads and bytes-per-pass
    falls out of the byte-exact counters (benchmarks/bench_subspace_io.py
    archives reads-per-expansion and reads-per-restart off these).

Peers: a pass may walk other MultiVectors in lockstep (mv_dot, mv_add_mv);
their blocks are interleaved into the announced list and materialized at
the same visit.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.obs import trace


class Handle:
    """Result slot for one consumer; filled when the pass runs."""

    __slots__ = ("_value", "_ready")

    def __init__(self):
        self._ready = False
        self._value = None

    def _set(self, v) -> None:
        self._value = v
        self._ready = True

    @property
    def value(self):
        if not self._ready:
            raise RuntimeError("SubspacePass consumer read before run()")
        return self._value


class _Consumer:
    handle: Handle

    def visit(self, i: int, block: jnp.ndarray,
              peers: Sequence[jnp.ndarray]) -> None:
        raise NotImplementedError

    def finalize(self):
        raise NotImplementedError


class _Gram(_Consumer):
    """MvTransMv: alpha * Vᵀ @ other, other device-resident (§3.4.3 shared
    I/O — the right operand is read zero times from the slow tier)."""

    def __init__(self, other, alpha, impl):
        self.other, self.alpha, self.impl = other, alpha, impl
        self.parts: List[jnp.ndarray] = []
        self.handle = Handle()

    def visit(self, i, block, peers):
        self.parts.append(kops.gram(block, self.other, alpha=self.alpha,
                                    impl=self.impl))

    def finalize(self):
        if not self.parts:
            return jnp.zeros((0, self.other.shape[1]), jnp.float32)
        return jnp.concatenate(self.parts, axis=0)


class _Matmul(_Consumer):
    """MvTimesMatAddMv with N output accumulators: one streamed read
    computes every column group of `small` (restart compression computes
    all k_keep/b output blocks in the same visit — the pre-PR path paid
    one full subspace pass per output block)."""

    def __init__(self, small, row_offsets, out_widths, alpha, n, impl):
        self.small = small
        self.row_offsets = row_offsets      # block index -> row offset
        self.alpha, self.impl = alpha, impl
        self.out_cols: List[slice] = []
        off = 0
        for w in out_widths:
            self.out_cols.append(slice(off, off + w))
            off += w
        self.accs = [jnp.zeros((n, w), jnp.float32) for w in out_widths]
        self.handle = Handle()

    def visit(self, i, block, peers):
        r0 = self.row_offsets[i]
        rows = self.small[r0:r0 + block.shape[1], :]
        for j, cols in enumerate(self.out_cols):
            self.accs[j] = kops.tsgemm(block, rows[:, cols],
                                       alpha=self.alpha, beta=1.0,
                                       c0=self.accs[j], impl=self.impl)

    def finalize(self):
        return self.accs


class _Project(_Consumer):
    """Fused BCGS pass: per visit h_i = V_iᵀw, then w ← w − V_i h_i in the
    *same* read — one streamed pass where the unfused CGS pass pays two
    (MvTransMv + MvTimesMatAddMv). Block-MGS update order; the telescoping
    w₀ = Σ V_i h_i + w_final keeps the Krylov invariant exact."""

    def __init__(self, w, impl):
        self.w, self.impl = w, impl
        self.parts: List[jnp.ndarray] = []
        self.handle = Handle()

    def visit(self, i, block, peers):
        h_i = kops.gram(block, self.w, impl=self.impl)
        self.parts.append(h_i)
        self.w = kops.tsgemm(block, h_i, alpha=-1.0, beta=1.0, c0=self.w,
                             impl=self.impl)

    def finalize(self):
        if not self.parts:
            h = jnp.zeros((0, self.w.shape[1]), jnp.float32)
        else:
            h = jnp.concatenate(self.parts, axis=0)
        return h, self.w


class _Visit(_Consumer):
    """Generic per-block visitor: fn(i, block, peers) -> part or None;
    finalize concatenates collected parts along `axis` (or returns them
    raw with axis=None). mv_add_mv / clone_view / to_dense ride this."""

    def __init__(self, fn, axis: Optional[int]):
        self.fn, self.axis = fn, axis
        self.parts: List = []
        self.handle = Handle()

    def visit(self, i, block, peers):
        part = self.fn(i, block, peers)
        if part is not None:
            self.parts.append(part)

    def finalize(self):
        if self.axis is None:
            return self.parts
        return jnp.concatenate(self.parts, axis=self.axis)


class SubspacePass:
    """One planned streamed read of a MultiVector feeding many consumers.

    Usage::

        p = SubspacePass(v)
        h = p.add_gram(w)          # handles fill at run()
        p.run()
        g = h.value

    `peers` are MultiVectors with the same block structure walked in
    lockstep (their blocks arrive as the `peers` argument of each visit).
    `readahead` is the number of *store names* kept announced ahead of the
    walk; it defaults to the MultiVector's group-level readahead
    (`readahead * group_size` blocks — the same depth the retired
    `_prefetch_group` maintained).

    `block_ids` restricts the walk to a subset of blocks (in the given
    order); visitors still receive the *original* block index. LOBPCG's
    residual pass reads only the X block of its [X, W, P] basis this way
    instead of paying a full-basis read.
    """

    def __init__(self, mv, *, peers: Sequence = (),
                 readahead: int | None = None,
                 block_ids: Sequence[int] | None = None):
        self.mv = mv
        self.peers = list(peers)
        for p in self.peers:
            assert p.nblocks == mv.nblocks, (p.nblocks, mv.nblocks)
        self.block_ids = (list(range(mv.nblocks)) if block_ids is None
                          else [int(i) for i in block_ids])
        for i in self.block_ids:
            assert 0 <= i < mv.nblocks, (i, mv.nblocks)
        self.store = mv.store
        if readahead is None:
            readahead = mv.readahead * mv.group_size * (1 + len(self.peers))
        self.readahead = max(0, int(readahead))
        self._consumers: List[_Consumer] = []
        self._ran = False

    # ------------------------------------------------------------ consumers
    def _attach(self, c: _Consumer) -> Handle:
        self._consumers.append(c)
        return c.handle

    def add_gram(self, other: jnp.ndarray, *, alpha: float = 1.0) -> Handle:
        """h = alpha * selfᵀ @ other → (m, k)."""
        return self._attach(_Gram(other, alpha, self.mv.impl))

    def add_matmul(self, small: jnp.ndarray,
                   out_widths: Sequence[int] | None = None, *,
                   alpha: float = 1.0) -> Handle:
        """accs[j] = alpha * self @ small[:, cols_j] — a list of output
        accumulators, one per entry of out_widths (default: one output of
        small's full width). All outputs stay device-resident for the
        pass, so a caller splitting very wide products should bound
        out_widths per pass (MultiVector.compress does). On a restricted
        walk (`block_ids`), `small`'s rows span the visited blocks only,
        stacked in walk order."""
        m, k = small.shape
        widths = self.mv.block_widths()
        m_visited = sum(widths[i] for i in self.block_ids)
        assert m == m_visited, (m, m_visited)
        if out_widths is None:
            out_widths = [k]
        assert sum(out_widths) == k, (out_widths, k)
        offsets, off = {}, 0
        for i in self.block_ids:
            offsets[i] = off
            off += widths[i]
        return self._attach(_Matmul(small, offsets, out_widths, alpha,
                                    self.mv.n, self.mv.impl))

    def add_project(self, w: jnp.ndarray) -> Handle:
        """Fused CGS step: returns (h, w − self @ h) from one read."""
        return self._attach(_Project(w, self.mv.impl))

    def add_dot(self) -> Handle:
        """Columnwise dots against peer 0 (MvDot)."""
        assert self.peers, "add_dot needs a peer MultiVector"
        return self.add_visit(
            lambda i, blk, peers: jnp.sum(blk * peers[0], axis=0), axis=0)

    def add_norm(self) -> Handle:
        """Column 2-norms (MvNorm)."""
        return self.add_visit(
            lambda i, blk, peers: jnp.sqrt(jnp.sum(blk ** 2, axis=0)),
            axis=0)

    def add_visit(self, fn: Callable, *, axis: Optional[int] = 0) -> Handle:
        return self._attach(_Visit(fn, axis))

    # ------------------------------------------------------------------ run
    def _names(self) -> List[str]:
        names = []
        for i in self.block_ids:
            names.append(self.mv._block_name(i))
            for p in self.peers:
                names.append(p._block_name(i))
        return names

    def run(self) -> None:
        """Stream every block once; fill all consumer handles. Single-use:
        consumers accumulate state across visits, so re-running would
        silently double every result — build a fresh pass instead."""
        if self._ran:
            raise RuntimeError("SubspacePass already ran; build a new pass")
        self._ran = True
        mv = self.mv
        names = self._names()
        read0 = self.store.begin_pass()
        # the span's `bytes` attribute is the same host_bytes_read delta
        # end_pass attributes to pass_bytes_read — the report reconciles
        # the two accountants byte-exactly
        with trace.span("pass.subspace", blocks=len(self.block_ids),
                        consumers=len(self._consumers),
                        peers=len(self.peers)) as sp:
            if names:
                self.store.prefetch(names)  # whole pass announced up front
            pos = 0
            for i in self.block_ids:
                if self.readahead:
                    # re-offer the window: ids past the backend's readahead
                    # depth were dropped at announce time and re-queue here
                    self.store.prefetch(
                        names[pos + 1:pos + 1 + self.readahead])
                block = self._materialize(mv, i)
                pos += 1
                pblocks = []
                for p in self.peers:
                    pblocks.append(self._materialize(p, i))
                    pos += 1
                for c in self._consumers:
                    c.visit(i, block, pblocks)
            self.store.end_pass(read0)
            sp.set(bytes=self.store.stats.host_bytes_read - read0)
        for c in self._consumers:
            c.handle._set(c.finalize())

    @staticmethod
    def _materialize(mv, i: int) -> jnp.ndarray:
        """One store read per block per pass, shared by all consumers
        (lazy MvScale applied once, here)."""
        b = mv._blocks[i]
        val = mv.store.get(b.name)
        if b.scale != 1.0:
            val = b.scale * val
        return val
