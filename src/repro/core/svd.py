"""SVD for directed graphs (the page graph path, §4.3.2).

A directed adjacency matrix is asymmetric, so the paper computes the SVD
instead of an eigendecomposition. We run the symmetric Krylov–Schur solver
on the Gram operator AᵀA (two streamed SpMMs per application: A then Aᵀ,
both images resident on the slow tier), recover σ = sqrt(λ) and the left
vectors as U = A V Σ⁻¹.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.krylov_schur import eigsh
from repro.core.operator import GraphOperator, NormalOperator
from repro.core.tiered import TieredStore
from repro.kernels import ops as kops


@dataclasses.dataclass
class SvdResult:
    s: np.ndarray                 # (nsv,) singular values, descending
    u: np.ndarray | None          # (n_rows, nsv)
    v: np.ndarray | None          # (n_cols, nsv)
    n_restarts: int
    n_ops: int
    converged: bool
    io_stats: dict | None


def svds(a_op: GraphOperator, at_op: GraphOperator, nsv: int, *,
         block_size: int = 2, num_blocks: int | None = None,
         tol: float = 1e-8, max_restarts: int = 60,
         store: TieredStore | None = None, impl: kops.Impl = "auto",
         seed: int = 0, compute_vectors: bool = True,
         callback: Callable | None = None) -> SvdResult:
    """Leading nsv singular triplets of A (n_rows × n_cols).

    The paper uses block size 2 and NB = 2·nsv for the page graph because
    SpMM is SSD-bound there — the same defaults apply here.

    `callback(restart, sigma, res)` fires per inner restart with the
    current σ estimates (σ = √max(θ, 0) — translated from the Gram
    operator's eigenvalue space) and the Gram residual bounds; arrays are
    fresh copies per call (mutation-safe).
    """
    store = store or TieredStore()
    gram_op = NormalOperator(a_op, at_op)
    cb = None
    if callback is not None:
        def cb(k, theta, res):
            callback(k, np.sqrt(np.maximum(theta, 0.0)), res.copy())
    res = eigsh(gram_op, nsv, block_size=block_size, num_blocks=num_blocks,
                tol=tol, max_restarts=max_restarts, which="LA", store=store,
                impl=impl, seed=seed, compute_eigenvectors=compute_vectors,
                callback=cb)
    lam = np.maximum(res.eigenvalues, 0.0)
    s = np.sqrt(lam)
    u = v = None
    if compute_vectors and res.eigenvectors is not None:
        v = res.eigenvectors
        av = np.asarray(a_op.matmat(jnp.asarray(v, jnp.float32)))
        sinv = np.where(s > 1e-12, 1.0 / np.maximum(s, 1e-30), 0.0)
        u = av * sinv[None, :]
    return SvdResult(s=s, u=u, v=v, n_restarts=res.n_restarts,
                     n_ops=res.n_ops, converged=res.converged,
                     io_stats=store.stats.as_dict())
