"""TieredStore — the SSD/host-offload tier with byte-exact I/O accounting.

The paper keeps the Krylov subspace on SSD (§3.4) and fights for two
resources: read bandwidth and *write endurance* (DWPD). On a TPU the slow
tier is host DRAM reached over PCIe (`memory_kind='pinned_host'`); in this
CPU container the tier split is emulated with a pluggable storage backend
(`repro.safs.backend`):

  backend="ram"   numpy buffers in host memory (the default; tier-1 tests);
  backend="safs"  the paper's real layer — one page file per data_id under
                  `backend_opts["root"]`, an LRU page cache with write-back
                  and most-recent-block pinning, and async prefetch
                  (`TieredStore.prefetch`) overlapping reads with compute.

Either way `stats` stays byte-exact *logical* tier traffic, so the paper's
Table-3 read/write claims are validated quantitatively by the benchmarks;
with safs the backend's own `stats` additionally count physical disk bytes
(endurance — less than logical whenever the page cache absorbs re-reads).
`stats.passes` additionally counts streamed whole-subspace reads
(`begin_pass`, driven by `core.stream.SubspacePass`) — the §3.4.3 unit the
pass-fusion work minimizes; `benchmarks/bench_subspace_io.py` archives
reads-per-expansion and reads-per-restart off these counters.

Policies implemented from §3.4.4:
  * most-recent-block caching — the newest subspace block stays in the
    device tier (it is about to be re-read by reorthogonalization), and the
    most recently *appended-then-demoted* subspace block's pages stay pinned
    in the page cache (`host_pin`, driven by MultiVector.append_block — an
    explicit lifecycle, so unrelated LRU demotions cannot steal the pin);
  * data identifiers — a transposed view shares its parent's identifier so
    cached bytes are recognized (we key the cache by `data_id`, not by
    object);
  * write-avoidance — demotion only writes when the block is dirty.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace

DEVICE = "device"
HOST = "host"  # the "SSD" tier


class ReadOnlyError(RuntimeError):
    """Write attempted against a read-only store entry (streamed matrix
    image chunks: per-chunk dirty tracking is not implemented, so a write
    would silently diverge from the on-disk image)."""


@dataclasses.dataclass
class IOStats:
    host_bytes_read: int = 0       # "SSD" reads (paper Table 3: 145 TB)
    host_bytes_written: int = 0    # "SSD" writes (paper Table 3: 4 TB)
    host_reads: int = 0
    host_writes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    passes: int = 0                # streamed whole-subspace reads (§3.4.3)
    pass_bytes_read: int = 0       # host bytes read INSIDE those passes
    retries: int = 0               # transient-I/O retries absorbed (safs)

    def bytes_per_pass(self) -> float:
        """Average slow-tier bytes read per streamed subspace pass — the
        §3.4.3 figure of merit (fusion shrinks `passes` while the bytes
        of the surviving passes stay put). Attributed: only bytes read
        inside SubspacePass runs count — operator tile / streamed-image
        reads sharing the store do not dilute the figure."""
        return self.pass_bytes_read / max(self.passes, 1)

    def hit_rate(self) -> float:
        """Fraction of lookups served without a slow-tier read. Every
        stats surface (logical tier, page cache, merged backend snapshot)
        reports this identically via `as_dict`."""
        return self.cache_hits / max(self.cache_hits + self.cache_misses, 1)

    def as_dict(self) -> Dict[str, float]:
        # Dict[str, float]: the raw fields are ints, but the derived
        # bytes_per_pass / hit_rate gauges are ratios
        d = dataclasses.asdict(self)
        d["bytes_per_pass"] = self.bytes_per_pass()
        d["hit_rate"] = self.hit_rate()
        return d


@dataclasses.dataclass
class _Entry:
    data_id: str
    tier: str
    device_val: Optional[jnp.ndarray]
    has_host: bool                 # backend holds a copy of data_id
    nbytes: int
    dirty: bool                    # device copy newer than host copy
    readonly: bool = False         # writes raise (streamed matrix image)


class TieredStore:
    """Named tensor store with a device-tier budget and explicit residency.

    device_budget_bytes caps the *device* tier; putting past the budget
    demotes the least-recently-used non-pinned entries to the host tier
    (counted as SSD writes if dirty). `pin` marks the most-recent subspace
    block per §3.4.4. The host tier's bytes live in `backend` ("ram" |
    "safs" | a StorageBackend instance; see module docstring).
    """

    def __init__(self, device_budget_bytes: int = 1 << 62, *,
                 backend="ram", backend_opts: dict | None = None):
        from repro.safs.backend import make_backend  # late: avoids cycle
        self.device_budget = device_budget_bytes
        self.stats = IOStats()
        self.backend = make_backend(backend, **(backend_opts or {}))
        self._entries: Dict[str, _Entry] = {}
        self._lru: "OrderedDict[str, None]" = OrderedDict()  # oldest first
        self._pinned: set[str] = set()
        self._recent_host_id: str | None = None  # page-cache pin (§3.4.4)
        self._device_nbytes = 0     # running counter — no per-op full scans

    # -- residency accounting -------------------------------------------------
    def device_bytes(self) -> int:
        return self._device_nbytes

    def host_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values() if e.has_host)

    def _touch(self, name: str) -> None:
        if name in self._lru:
            self._lru.move_to_end(name)
        else:
            self._lru[name] = None

    def _evict_for(self, incoming: int) -> None:
        if self._device_nbytes + incoming <= self.device_budget:
            return
        for name in list(self._lru):                # oldest first
            if self._device_nbytes + incoming <= self.device_budget:
                break
            e = self._entries[name]
            if e.tier == DEVICE and name not in self._pinned:
                self.demote(name)

    def _drop_entry(self, name: str, e: "_Entry") -> None:
        # an entry leaving the table (delete / overwrite) releases its
        # device residency from the running counter
        if e.tier == DEVICE:
            self._device_nbytes -= e.nbytes

    # -- core API --------------------------------------------------------------
    def put(self, name: str, value: jnp.ndarray, *, tier: str = DEVICE,
            data_id: str | None = None, readonly: bool = False) -> None:
        prev = self._entries.get(name)
        if prev is not None and prev.readonly:
            raise ReadOnlyError(
                f"store entry {name!r} is read-only (streamed matrix image "
                f"chunk; per-chunk dirty tracking is not implemented — "
                f"rebuild the operator instead of writing through it)")
        nbytes = int(np.prod(value.shape)) * value.dtype.itemsize
        if prev is not None:
            # retire the stale entry wholly before eviction runs, so
            # _evict_for can neither demote the about-to-be-replaced bytes
            # nor double-release them from the running counter
            self._drop_entry(name, prev)
            del self._entries[name]
            self._lru.pop(name, None)
        if tier == DEVICE:
            self._evict_for(nbytes)
            self._entries[name] = _Entry(data_id or name, DEVICE,
                                         jnp.asarray(value), False, nbytes,
                                         True, readonly)
            self._device_nbytes += nbytes
        else:
            e = _Entry(data_id or name, HOST, None, True, nbytes, False,
                       readonly)
            self.backend.store(e.data_id, np.asarray(value))
            self.stats.host_bytes_written += nbytes
            self.stats.host_writes += 1
            self._entries[name] = e
        self._touch(name)

    def get(self, name: str) -> jnp.ndarray:
        """Read a tensor; host-tier reads are counted as SSD reads."""
        e = self._entries[name]
        self._touch(name)
        if e.tier == DEVICE:
            self.stats.cache_hits += 1
            return e.device_val
        self.stats.cache_misses += 1
        self.stats.host_bytes_read += e.nbytes
        self.stats.host_reads += 1
        # span on the slow-tier branch only: device hits are free and
        # would dominate the trace with noise
        with trace.span("store.get", block=name, bytes=e.nbytes):
            return jnp.asarray(self.backend.load(e.data_id))

    def promote(self, name: str) -> jnp.ndarray:
        """Move to device tier (counted read if it was on host)."""
        e = self._entries[name]
        if e.tier == DEVICE:
            return e.device_val
        val = self.get(name)
        self._evict_for(e.nbytes)
        e.device_val, e.tier, e.dirty = val, DEVICE, False
        self._device_nbytes += e.nbytes
        return val

    def demote(self, name: str) -> None:
        """Move to host tier; writes only if dirty (write-avoidance)."""
        e = self._entries[name]
        if e.tier == HOST:
            return
        if e.dirty or not e.has_host:
            with trace.span("store.demote", block=name, bytes=e.nbytes):
                self.backend.store(e.data_id, np.asarray(e.device_val))
            e.has_host = True
            self.stats.host_bytes_written += e.nbytes
            self.stats.host_writes += 1
        e.device_val, e.tier, e.dirty = None, HOST, False
        self._device_nbytes -= e.nbytes

    def host_pin(self, name: str) -> None:
        """Pin `name`'s pages in the backend page cache until the next
        host_pin supersedes it — the §3.4.4 "cache the most recent dense
        matrix" policy. The pin is owned by the subspace append lifecycle
        (MultiVector.append_block pins the block it just demoted): plain
        LRU demotions must NOT move it, or restart-compression's output
        spills steal the pin from the block reorthogonalization is about
        to re-read (the page cache then never hits on the solver path)."""
        e = self._entries[name]
        if self._recent_host_id == e.data_id:
            return
        if self._recent_host_id is not None:
            self.backend.unpin(self._recent_host_id)
        self.backend.pin(e.data_id)
        self._recent_host_id = e.data_id

    def pin(self, name: str) -> None:
        """Pin in device tier — the most-recent-block cache of §3.4.4."""
        self.promote(name)
        self._pinned.add(name)

    def unpin(self, name: str) -> None:
        self._pinned.discard(name)

    def delete(self, name: str) -> None:
        e = self._entries.pop(name, None)
        if e is not None:
            self._drop_entry(name, e)
        self._lru.pop(name, None)
        self._pinned.discard(name)
        if e is not None and not any(o.data_id == e.data_id
                                     for o in self._entries.values()):
            self.backend.delete(e.data_id)
            if self._recent_host_id == e.data_id:
                self.backend.unpin(e.data_id)
                self._recent_host_id = None

    def names(self):
        return list(self._entries)

    def tier_of(self, name: str) -> str:
        return self._entries[name].tier

    # -- streaming helpers ------------------------------------------------------
    def begin_pass(self) -> int:
        """Mark the start of one streamed whole-subspace read (called by
        `core.stream.SubspacePass.run`). `stats.passes` then counts the
        §3.4.3 unit of cost — full passes over the on-SSD subspace.
        Returns the host_bytes_read watermark; hand it back to `end_pass`
        so `pass_bytes_read` attributes exactly the bytes the pass itself
        streamed (matrix-image reads sharing the store stay excluded)."""
        self.stats.passes += 1
        return self.stats.host_bytes_read

    def end_pass(self, read_watermark: int) -> None:
        """Close the pass opened by `begin_pass`, attributing the bytes
        read since the watermark to `stats.pass_bytes_read`."""
        self.stats.pass_bytes_read += (self.stats.host_bytes_read
                                       - read_watermark)

    def prefetch(self, names: Iterable[str]) -> None:
        """Hint the backend to stage host-tier entries' pages ahead of the
        next grouped pass (async; a no-op on the ram backend)."""
        ids = [self._entries[n].data_id for n in names
               if n in self._entries and self._entries[n].tier == HOST]
        if ids:
            trace.event("store.prefetch", n=len(ids), first=ids[0])
            self.backend.prefetch(ids)

    def stream(self, names: Iterable[str], *, readahead: int = 2):
        """Yield `get(name)` for each name while keeping the next
        `readahead` entries' pages in flight on the backend's readahead
        pool — the generic sequential-scan driver (SSD-streamed SpMM
        walks the matrix-image chunks with it; grouped MultiVector passes
        use the same pattern via `prefetch`). On the ram backend it
        degenerates to a plain `get` loop."""
        names = list(names)
        for i, nm in enumerate(names):
            if readahead > 0:
                self.prefetch(names[i + 1:i + 1 + readahead])
            yield self.get(nm)

    def flush(self) -> None:
        """Force dirty host-tier pages down to the physical medium."""
        self.backend.flush()

    def close(self) -> None:
        self.backend.close()

    def reset_stats(self) -> IOStats:
        old, self.stats = self.stats, IOStats()
        return old
