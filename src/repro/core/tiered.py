"""TieredStore — the SSD/host-offload tier with byte-exact I/O accounting.

The paper keeps the Krylov subspace on SSD (§3.4) and fights for two
resources: read bandwidth and *write endurance* (DWPD). On a TPU the slow
tier is host DRAM reached over PCIe (`memory_kind='pinned_host'`); in this
CPU container the tier split is emulated with a pluggable storage backend
(`repro.safs.backend`):

  backend="ram"   numpy buffers in host memory (the default; tier-1 tests);
  backend="safs"  the paper's real layer — one page file per data_id under
                  `backend_opts["root"]`, an LRU page cache with write-back
                  and most-recent-block pinning, and async prefetch
                  (`TieredStore.prefetch`) overlapping reads with compute.

Either way `stats` stays byte-exact *logical* tier traffic, so the paper's
Table-3 read/write claims are validated quantitatively by the benchmarks;
with safs the backend's own `stats` additionally count physical disk bytes
(endurance — less than logical whenever the page cache absorbs re-reads).
`stats.passes` additionally counts streamed whole-subspace reads
(`begin_pass`, driven by `core.stream.SubspacePass`) — the §3.4.3 unit the
pass-fusion work minimizes; `benchmarks/bench_subspace_io.py` archives
reads-per-expansion and reads-per-restart off these counters.

Policies implemented from §3.4.4:
  * most-recent-block caching — the newest subspace block stays in the
    device tier (it is about to be re-read by reorthogonalization), and the
    most recently *appended-then-demoted* subspace block's pages stay pinned
    in the page cache (`host_pin`, driven by MultiVector.append_block — an
    explicit lifecycle, so unrelated LRU demotions cannot steal the pin);
  * data identifiers — a transposed view shares its parent's identifier so
    cached bytes are recognized (we key the cache by `data_id`, not by
    object);
  * write-avoidance — demotion only writes when the block is dirty.

Multi-tenancy (serving layer, paper §3.4's shared page cache writ large —
FlashGraph runs many graph workloads over one SSD cache):
  * `namespace(session_id)` returns a `StoreNamespace` facade that prefixes
    every key with `"<sid>::"`, keeps per-namespace `IOStats`, and exposes
    the full store duck-API, so solvers run unmodified inside a session;
  * per-namespace device budgets (`set_namespace_budget`) let an arbiter
    split one global device budget across live sessions — a session
    overflowing its allotment demotes its *own* LRU entries first;
  * one host-pin slot *per namespace*: concurrent sessions cannot steal
    each other's §3.4.4 most-recent-block page pin;
  * `drop_namespace(sid)` retires a session — entries and backend pages
    are deleted, the namespace's IOStats survive for post-mortem reports;
  * every public method is serialized by one reentrant lock, and `IOStats`
    increments go through `IOStats.add` (its own lock), so two sessions
    hammering one store reconcile their counters exactly.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace

DEVICE = "device"
HOST = "host"  # the "SSD" tier

NS_SEP = "::"  # session prefix in qualified ids: "<session_id>::<name>"


def ns_of(data_id: str) -> str:
    """Namespace (session id) of a qualified id; "" for root-owned ids."""
    i = data_id.find(NS_SEP)
    return data_id[:i] if i >= 0 else ""


class ReadOnlyError(RuntimeError):
    """Write attempted against a read-only store entry (streamed matrix
    image chunks: per-chunk dirty tracking is not implemented, so a write
    would silently diverge from the on-disk image)."""


@dataclasses.dataclass
class IOStats:
    host_bytes_read: int = 0       # "SSD" reads (paper Table 3: 145 TB)
    host_bytes_written: int = 0    # "SSD" writes (paper Table 3: 4 TB)
    host_reads: int = 0
    host_writes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    passes: int = 0                # streamed whole-subspace reads (§3.4.3)
    pass_bytes_read: int = 0       # host bytes read INSIDE those passes
    retries: int = 0               # transient-I/O retries absorbed (safs)
    retry_sleep_ms: float = 0.0    # cumulative backoff slept in retries
    #                                (bounded per op by max_total_sleep)

    def __post_init__(self):
        # not a dataclass field: asdict/eq stay counter-only, and every
        # instance gets its own lock even through dataclasses.replace
        self._lock = threading.Lock()

    def add(self, **deltas: int) -> None:
        """Atomically bump counters. One instance is shared between the
        page cache, the write-behind retire thread and the backend's
        caller threads (three different outer locks) — unsynchronized
        `+=` there loses updates under load."""
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)

    def bytes_per_pass(self) -> float:
        """Average slow-tier bytes read per streamed subspace pass — the
        §3.4.3 figure of merit (fusion shrinks `passes` while the bytes
        of the surviving passes stay put). Attributed: only bytes read
        inside SubspacePass runs count — operator tile / streamed-image
        reads sharing the store do not dilute the figure."""
        return self.pass_bytes_read / max(self.passes, 1)

    def hit_rate(self) -> float:
        """Fraction of lookups served without a slow-tier read. Every
        stats surface (logical tier, page cache, merged backend snapshot)
        reports this identically via `as_dict`."""
        return self.cache_hits / max(self.cache_hits + self.cache_misses, 1)

    def as_dict(self) -> Dict[str, float]:
        # Dict[str, float]: the raw fields are ints, but the derived
        # bytes_per_pass / hit_rate gauges are ratios
        d = dataclasses.asdict(self)
        d["bytes_per_pass"] = self.bytes_per_pass()
        d["hit_rate"] = self.hit_rate()
        return d


@dataclasses.dataclass
class _Entry:
    data_id: str
    tier: str
    device_val: Optional[jnp.ndarray]
    has_host: bool                 # backend holds a copy of data_id
    nbytes: int
    dirty: bool                    # device copy newer than host copy
    readonly: bool = False         # writes raise (streamed matrix image)
    ns: str = ""                   # owning session ("" = root)


class TieredStore:
    """Named tensor store with a device-tier budget and explicit residency.

    device_budget_bytes caps the *device* tier; putting past the budget
    demotes the least-recently-used non-pinned entries to the host tier
    (counted as SSD writes if dirty). `pin` marks the most-recent subspace
    block per §3.4.4. The host tier's bytes live in `backend` ("ram" |
    "safs" | a StorageBackend instance; see module docstring).
    """

    def __init__(self, device_budget_bytes: int = 1 << 62, *,
                 backend="ram", backend_opts: dict | None = None):
        from repro.safs.backend import make_backend  # late: avoids cycle
        self.device_budget = device_budget_bytes
        self.stats = IOStats()
        self.backend = make_backend(backend, **(backend_opts or {}))
        self._entries: Dict[str, _Entry] = {}
        self._lru: "OrderedDict[str, None]" = OrderedDict()  # oldest first
        self._pinned: set[str] = set()
        # page-cache pin (§3.4.4) — one slot PER NAMESPACE, so concurrent
        # sessions cannot steal each other's most-recent-block pin
        self._recent_host_ids: Dict[str, str] = {}
        self._device_nbytes = 0     # running counter — no per-op full scans
        self._lock = threading.RLock()          # serializes all public ops
        self._ns_stats: Dict[str, IOStats] = {}
        self._ns_budget: Dict[str, int] = {}    # per-session device caps
        self._ns_device: Dict[str, int] = {}    # device bytes per session
        self._namespaces: Dict[str, "StoreNamespace"] = {}

    # -- multi-tenancy ---------------------------------------------------------
    def namespace(self, session_id: str) -> "StoreNamespace":
        """Session-scoped facade: keys prefixed `"<sid>::"`, IOStats split
        per session, optional per-session device budget. Re-entering the
        same id (e.g. a preempted job resuming) returns a facade over the
        same accumulated stats."""
        if not session_id or NS_SEP in session_id:
            raise ValueError(f"invalid session id {session_id!r}")
        with self._lock:
            ns = self._namespaces.get(session_id)
            if ns is None:
                ns = StoreNamespace(self, session_id)
                self._namespaces[session_id] = ns
            return ns

    def set_namespace_budget(self, session_id: str,
                             nbytes: Optional[int]) -> None:
        """Cap a session's device-tier bytes (None lifts the cap). The
        arbiter recomputes these on admit/finish; shrinking a live
        session's allotment demotes its own LRU entries immediately."""
        with self._lock:
            if nbytes is None:
                self._ns_budget.pop(session_id, None)
                return
            self._ns_budget[session_id] = int(nbytes)
            self._evict_for(0, session_id)

    def namespace_budget(self, session_id: str) -> Optional[int]:
        with self._lock:
            return self._ns_budget.get(session_id)

    def drop_namespace(self, session_id: str) -> None:
        """Retire a session: delete its entries and backend pages, release
        its pins and budget. Its IOStats survive (post-mortem reporting —
        the serve report reconciles them against backend totals)."""
        with self._lock:
            for name in [n for n, e in self._entries.items()
                         if e.ns == session_id]:
                self.delete(name)
            rid = self._recent_host_ids.pop(session_id, None)
            if rid is not None:
                self.backend.unpin(rid)
            self._ns_budget.pop(session_id, None)
            self._ns_device.pop(session_id, None)
            self._namespaces.pop(session_id, None)
            drop = getattr(self.backend, "drop_namespace", None)
            if drop is not None:
                drop(session_id)

    def namespace_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-session logical IOStats snapshots (includes retired
        sessions — stats outlive `drop_namespace`)."""
        with self._lock:
            return {sid: st.as_dict() for sid, st in self._ns_stats.items()}

    def _ns_io(self, sid: str) -> IOStats:
        st = self._ns_stats.get(sid)
        if st is None:
            st = self._ns_stats.setdefault(sid, IOStats())
        return st

    def _acct(self, ns: str, **deltas: int) -> None:
        """Bump the store-wide counters, and the owning session's split.
        Parent totals therefore equal root traffic plus the namespace
        sums exactly — the reconciliation the serve report asserts."""
        self.stats.add(**deltas)
        if ns:
            self._ns_io(ns).add(**deltas)

    # -- residency accounting -------------------------------------------------
    def device_bytes(self) -> int:
        return self._device_nbytes

    def host_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._entries.values()
                       if e.has_host)

    def _touch(self, name: str) -> None:
        if name in self._lru:
            self._lru.move_to_end(name)
        else:
            self._lru[name] = None

    def _evict_for(self, incoming: int, ns: str = "") -> None:
        # a capped session overflowing its allotment demotes its OWN
        # least-recently-used entries first — it cannot push another
        # session's working set off the device tier
        budget = self._ns_budget.get(ns)
        if budget is not None:
            while self._ns_device.get(ns, 0) + incoming > budget:
                victim = next(
                    (n for n in self._lru
                     if self._entries[n].tier == DEVICE
                     and self._entries[n].ns == ns
                     and n not in self._pinned), None)
                if victim is None:
                    break
                self.demote(victim)
        if self._device_nbytes + incoming <= self.device_budget:
            return
        for name in list(self._lru):                # oldest first
            if self._device_nbytes + incoming <= self.device_budget:
                break
            e = self._entries[name]
            if e.tier == DEVICE and name not in self._pinned:
                self.demote(name)

    def _drop_entry(self, name: str, e: "_Entry") -> None:
        # an entry leaving the table (delete / overwrite) releases its
        # device residency from the running counter
        if e.tier == DEVICE:
            self._device_nbytes -= e.nbytes
            if e.ns:
                self._ns_device[e.ns] = (
                    self._ns_device.get(e.ns, 0) - e.nbytes)

    def _add_device(self, e: "_Entry") -> None:
        self._device_nbytes += e.nbytes
        if e.ns:
            self._ns_device[e.ns] = self._ns_device.get(e.ns, 0) + e.nbytes

    # -- core API --------------------------------------------------------------
    def put(self, name: str, value: jnp.ndarray, *, tier: str = DEVICE,
            data_id: str | None = None, readonly: bool = False) -> None:
        with self._lock:
            ns = ns_of(name)
            prev = self._entries.get(name)
            if prev is not None and prev.readonly:
                raise ReadOnlyError(
                    f"store entry {name!r} is read-only (streamed matrix "
                    f"image chunk; per-chunk dirty tracking is not "
                    f"implemented — rebuild the operator instead of "
                    f"writing through it)")
            nbytes = int(np.prod(value.shape)) * value.dtype.itemsize
            if prev is not None:
                # retire the stale entry wholly before eviction runs, so
                # _evict_for can neither demote the about-to-be-replaced
                # bytes nor double-release them from the running counter
                self._drop_entry(name, prev)
                del self._entries[name]
                self._lru.pop(name, None)
            if tier == DEVICE:
                self._evict_for(nbytes, ns)
                e = _Entry(data_id or name, DEVICE, jnp.asarray(value),
                           False, nbytes, True, readonly, ns)
                self._entries[name] = e
                self._add_device(e)
            else:
                e = _Entry(data_id or name, HOST, None, True, nbytes,
                           False, readonly, ns)
                self.backend.store(e.data_id, np.asarray(value))
                self._acct(ns, host_bytes_written=nbytes, host_writes=1)
                self._entries[name] = e
            self._touch(name)

    def get(self, name: str) -> jnp.ndarray:
        """Read a tensor; host-tier reads are counted as SSD reads."""
        with self._lock:
            e = self._entries[name]
            self._touch(name)
            if e.tier == DEVICE:
                self._acct(e.ns, cache_hits=1)
                return e.device_val
            self._acct(e.ns, cache_misses=1, host_bytes_read=e.nbytes,
                       host_reads=1)
            # span on the slow-tier branch only: device hits are free and
            # would dominate the trace with noise
            with trace.span("store.get", block=name, bytes=e.nbytes):
                return jnp.asarray(self.backend.load(e.data_id))

    def promote(self, name: str) -> jnp.ndarray:
        """Move to device tier (counted read if it was on host)."""
        with self._lock:
            e = self._entries[name]
            if e.tier == DEVICE:
                return e.device_val
            val = self.get(name)
            self._evict_for(e.nbytes, e.ns)
            e.device_val, e.tier, e.dirty = val, DEVICE, False
            self._add_device(e)
            return val

    def demote(self, name: str) -> None:
        """Move to host tier; writes only if dirty (write-avoidance)."""
        with self._lock:
            e = self._entries[name]
            if e.tier == HOST:
                return
            if e.dirty or not e.has_host:
                with trace.span("store.demote", block=name, bytes=e.nbytes):
                    self.backend.store(e.data_id, np.asarray(e.device_val))
                e.has_host = True
                self._acct(e.ns, host_bytes_written=e.nbytes, host_writes=1)
            e.device_val, e.tier, e.dirty = None, HOST, False
            self._device_nbytes -= e.nbytes
            if e.ns:
                self._ns_device[e.ns] = (
                    self._ns_device.get(e.ns, 0) - e.nbytes)

    def host_pin(self, name: str) -> None:
        """Pin `name`'s pages in the backend page cache until the next
        host_pin *from the same namespace* supersedes it — the §3.4.4
        "cache the most recent dense matrix" policy, one slot per session
        so concurrent solves keep their own pins. The pin is owned by the
        subspace append lifecycle (MultiVector.append_block pins the block
        it just demoted): plain LRU demotions must NOT move it, or
        restart-compression's output spills steal the pin from the block
        reorthogonalization is about to re-read (the page cache then never
        hits on the solver path)."""
        with self._lock:
            e = self._entries[name]
            cur = self._recent_host_ids.get(e.ns)
            if cur == e.data_id:
                return
            if cur is not None:
                self.backend.unpin(cur)
            self.backend.pin(e.data_id)
            self._recent_host_ids[e.ns] = e.data_id

    def pin(self, name: str) -> None:
        """Pin in device tier — the most-recent-block cache of §3.4.4."""
        with self._lock:
            self.promote(name)
            self._pinned.add(name)

    def unpin(self, name: str) -> None:
        with self._lock:
            self._pinned.discard(name)

    def delete(self, name: str) -> None:
        with self._lock:
            e = self._entries.pop(name, None)
            if e is not None:
                self._drop_entry(name, e)
            self._lru.pop(name, None)
            self._pinned.discard(name)
            if e is not None and not any(o.data_id == e.data_id
                                         for o in self._entries.values()):
                self.backend.delete(e.data_id)
                if self._recent_host_ids.get(e.ns) == e.data_id:
                    self.backend.unpin(e.data_id)
                    del self._recent_host_ids[e.ns]

    def names(self):
        with self._lock:
            return list(self._entries)

    def tier_of(self, name: str) -> str:
        with self._lock:
            return self._entries[name].tier

    # -- checkpoint plumbing ----------------------------------------------------
    def sync_device_entries(self, ns: Optional[str] = None) -> None:
        """Write device-tier entries with no current host copy through to
        the backend (residency unchanged — the entry just becomes clean-
        with-host-copy, like after a promote). `ckpt.save_safs` calls this
        before snapshotting page files so the §3.4.4-pinned newest block
        is not silently missing from the snapshot."""
        with self._lock:
            for e in self._entries.values():
                if ns is not None and e.ns != ns:
                    continue
                if e.tier == DEVICE and (e.dirty or not e.has_host):
                    self.backend.store(e.data_id, np.asarray(e.device_val))
                    e.has_host, e.dirty = True, False

    def data_ids(self, ns: Optional[str] = None) -> list[str]:
        """Backend ids owned by this store (optionally one namespace) —
        the set `ckpt.save_safs` snapshots. On a shared backend this is
        deliberately NOT `backend.data_ids()`: a session's checkpoint must
        not capture other sessions' page files."""
        with self._lock:
            out, seen = [], set()
            for e in self._entries.values():
                if ns is not None and e.ns != ns:
                    continue
                if e.has_host and e.data_id not in seen:
                    seen.add(e.data_id)
                    out.append(e.data_id)
            return out

    def resolve_data_id(self, name: str) -> str:
        """Qualified backend id for a logical name (identity at root; the
        namespace facade prefixes). Checkpoint restore uses this to find a
        block's page file inside a snapshot."""
        return name

    # -- budget hooks -----------------------------------------------------------
    def compress_acc_bytes(self) -> Optional[int]:
        """Per-store override for the fused-compress transient-accumulator
        cap (`core.multivector.COMPRESS_PASS_ACC_BYTES`). None = keep the
        global default; namespaces under an arbiter allotment return a
        scaled cap so a small-budget session chunks its compress pass."""
        return None

    def account_read(self, nbytes: int, *, reads: int = 1) -> None:
        """Attribute an out-of-band slow-tier read (e.g. the operator's
        non-streamed matrix image) to this store's counters. Namespaced
        facades route it to their session split too — direct `stats.x +=`
        from callers would silently skip the parent/session dual books."""
        self._acct("", host_bytes_read=int(nbytes), host_reads=reads)

    # -- streaming helpers ------------------------------------------------------
    def begin_pass(self) -> int:
        """Mark the start of one streamed whole-subspace read (called by
        `core.stream.SubspacePass.run`). `stats.passes` then counts the
        §3.4.3 unit of cost — full passes over the on-SSD subspace.
        Returns the host_bytes_read watermark; hand it back to `end_pass`
        so `pass_bytes_read` attributes exactly the bytes the pass itself
        streamed (matrix-image reads sharing the store stay excluded)."""
        self.stats.add(passes=1)
        return self.stats.host_bytes_read

    def end_pass(self, read_watermark: int) -> None:
        """Close the pass opened by `begin_pass`, attributing the bytes
        read since the watermark to `stats.pass_bytes_read`."""
        self.stats.add(pass_bytes_read=(self.stats.host_bytes_read
                                        - read_watermark))

    def prefetch(self, names: Iterable[str]) -> None:
        """Hint the backend to stage host-tier entries' pages ahead of the
        next grouped pass (async; a no-op on the ram backend)."""
        with self._lock:
            ids = [self._entries[n].data_id for n in names
                   if n in self._entries and self._entries[n].tier == HOST]
        if ids:
            trace.event("store.prefetch", n=len(ids), first=ids[0])
            self.backend.prefetch(ids)

    def stream(self, names: Iterable[str], *, readahead: int = 2):
        """Yield `get(name)` for each name while keeping the next
        `readahead` entries' pages in flight on the backend's readahead
        pool — the generic sequential-scan driver (SSD-streamed SpMM
        walks the matrix-image chunks with it; grouped MultiVector passes
        use the same pattern via `prefetch`). On the ram backend it
        degenerates to a plain `get` loop."""
        names = list(names)
        for i, nm in enumerate(names):
            if readahead > 0:
                self.prefetch(names[i + 1:i + 1 + readahead])
            yield self.get(nm)

    def flush(self) -> None:
        """Force dirty host-tier pages down to the physical medium."""
        self.backend.flush()

    def close(self) -> None:
        self.backend.close()

    def reset_stats(self) -> IOStats:
        old, self.stats = self.stats, IOStats()
        return old


class StoreNamespace:
    """Session-scoped view of a shared `TieredStore`.

    Mirrors the full store duck-API (put/get/promote/demote/pin/host_pin/
    begin_pass/stream/...), prefixing every key with `"<sid>::"` and
    splitting IOStats per session, so `MultiVector`, `SubspacePass`,
    `GraphOperator` and every solver run unmodified inside a session.
    `close()` retires the whole namespace (entries + backend pages); the
    session's stats survive on the parent for post-mortem reporting.

    Pass accounting is namespace-local: `begin_pass` watermarks the
    *session's* host_bytes_read and `end_pass` attributes the delta to
    both the session and the parent — under concurrency a parent-level
    watermark would blame one session's pass for another's bytes.
    """

    def __init__(self, parent: TieredStore, session_id: str):
        self._parent = parent
        self.session_id = session_id
        self._prefix = session_id + NS_SEP
        with parent._lock:
            self._stats = parent._ns_io(session_id)

    # -- naming ----------------------------------------------------------------
    def _q(self, name: str) -> str:
        return self._prefix + name

    def resolve_data_id(self, name: str) -> str:
        return self._q(name)

    # -- shared-resource views ---------------------------------------------------
    @property
    def stats(self) -> IOStats:
        return self._stats

    @property
    def backend(self):
        return self._parent.backend

    @property
    def parent(self) -> TieredStore:
        return self._parent

    @property
    def device_budget(self) -> int:
        b = self._parent._ns_budget.get(self.session_id)
        return self._parent.device_budget if b is None else b

    # -- core API ----------------------------------------------------------------
    def put(self, name, value, *, tier=DEVICE, data_id=None,
            readonly=False) -> None:
        self._parent.put(self._q(name), value, tier=tier,
                         data_id=self._q(data_id) if data_id else None,
                         readonly=readonly)

    def get(self, name):
        return self._parent.get(self._q(name))

    def promote(self, name):
        return self._parent.promote(self._q(name))

    def demote(self, name) -> None:
        self._parent.demote(self._q(name))

    def host_pin(self, name) -> None:
        self._parent.host_pin(self._q(name))

    def pin(self, name) -> None:
        self._parent.pin(self._q(name))

    def unpin(self, name) -> None:
        self._parent.unpin(self._q(name))

    def delete(self, name) -> None:
        self._parent.delete(self._q(name))

    def names(self):
        with self._parent._lock:
            return [n[len(self._prefix):] for n, e in
                    self._parent._entries.items()
                    if e.ns == self.session_id]

    def tier_of(self, name) -> str:
        return self._parent.tier_of(self._q(name))

    def device_bytes(self) -> int:
        with self._parent._lock:
            return self._parent._ns_device.get(self.session_id, 0)

    def host_bytes(self) -> int:
        with self._parent._lock:
            return sum(e.nbytes for e in self._parent._entries.values()
                       if e.ns == self.session_id and e.has_host)

    # -- checkpoint plumbing ------------------------------------------------------
    def sync_device_entries(self) -> None:
        self._parent.sync_device_entries(ns=self.session_id)

    def data_ids(self) -> list[str]:
        return self._parent.data_ids(ns=self.session_id)

    # -- budget hooks --------------------------------------------------------------
    def compress_acc_bytes(self) -> Optional[int]:
        """Fused-compress transient cap scaled to this session's arbiter
        allotment (half the device allotment, floored at 1 MiB), so a
        small-budget session chunks its compress pass instead of blowing
        past its share. None (no cap set) keeps the global default."""
        budget = self._parent._ns_budget.get(self.session_id)
        if budget is None:
            return None
        return max(budget // 2, 1 << 20)

    def account_read(self, nbytes: int, *, reads: int = 1) -> None:
        self._parent._acct(self.session_id, host_bytes_read=int(nbytes),
                           host_reads=reads)

    # -- streaming helpers ---------------------------------------------------------
    def begin_pass(self) -> int:
        with self._parent._lock:
            self._stats.add(passes=1)
            self._parent.stats.add(passes=1)
            return self._stats.host_bytes_read

    def end_pass(self, read_watermark: int) -> None:
        delta = self._stats.host_bytes_read - read_watermark
        self._stats.add(pass_bytes_read=delta)
        self._parent.stats.add(pass_bytes_read=delta)

    def prefetch(self, names: Iterable[str]) -> None:
        self._parent.prefetch([self._q(n) for n in names])

    def stream(self, names: Iterable[str], *, readahead: int = 2):
        names = list(names)
        for i, nm in enumerate(names):
            if readahead > 0:
                self.prefetch(names[i + 1:i + 1 + readahead])
            yield self.get(nm)

    def flush(self) -> None:
        self._parent.flush()

    def close(self) -> None:
        """Session end: drop the namespace (entries + backend pages). The
        shared backend stays open — the parent owns its lifecycle."""
        self._parent.drop_namespace(self.session_id)

    def reset_stats(self) -> IOStats:
        with self._parent._lock:
            old = self._stats
            self._stats = IOStats()
            self._parent._ns_stats[self.session_id] = self._stats
            return old
