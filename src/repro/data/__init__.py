"""repro.data"""
