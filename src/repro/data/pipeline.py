"""Deterministic, shard-aware token pipeline.

Production posture: every batch is a pure function of (seed, step), so a
restarted / re-sharded job resumes mid-epoch exactly (skip-ahead = just pass
the restored step). File-backed mode memory-maps a token file; synthetic
mode generates a fixed pseudo-corpus (zipfian unigrams + short-range
repetition so a ~100M model actually has something to learn)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: str | None = None  # .npy int32 flat tokens


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.token_file:
            self._tokens = np.load(cfg.token_file, mmap_mode="r")
        else:
            self._tokens = None

    def _synthetic(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        shape = (cfg.global_batch, cfg.seq_len + 1)
        # zipf-ish unigram distribution over the vocab
        z = rng.zipf(1.3, size=shape).astype(np.int64)
        toks = (z - 1) % cfg.vocab_size
        # inject copy structure: second half repeats first half shifted
        half = cfg.seq_len // 2
        toks[:, half:half * 2] = toks[:, :half]
        return toks.astype(np.int32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Global batch for `step` → {tokens, targets} (targets shifted)."""
        cfg = self.cfg
        if self._tokens is None:
            full = self._synthetic(step)
        else:
            need = cfg.global_batch * (cfg.seq_len + 1)
            start = (step * need) % max(1, len(self._tokens) - need)
            full = np.asarray(self._tokens[start:start + need]).reshape(
                cfg.global_batch, cfg.seq_len + 1).astype(np.int32)
        return {"tokens": full[:, :-1], "targets": full[:, 1:]}

    def host_shard(self, batch: dict, host_id: int, n_hosts: int) -> dict:
        """Per-host slice of the global batch (data-parallel ingestion)."""
        b = self.cfg.global_batch
        assert b % n_hosts == 0
        lo = host_id * (b // n_hosts)
        hi = lo + b // n_hosts
        return {k: v[lo:hi] for k, v in batch.items()}
