"""Distributed sharded-SpMM eigensolver layer (paper §3: SEM-SpMM).

layout        — vertex -> (pod, data, model) mesh placement, padding, panels
dspmm         — packed edge panels, sharded SpMM, fused eigen expansion step
dist_operator — DistOperator: the core restart loop's fused-expand adapter
compress      — int8-scaled cross-pod reductions
"""
from repro.dist.layout import padded_n, vertex_permutation
from repro.dist.dspmm import (CHUNK, build_dspmm, build_eigen_step,
                              build_eigen_step_compressed, edge_spec,
                              pack_compressed_panels, pack_edge_panels,
                              vector_spec)
from repro.dist.dist_operator import (DistOperator, default_mesh, e2e_mesh,
                                      pod_compressed_deviation)
from repro.dist.compress import compressed_psum_pod

__all__ = [
    "padded_n", "vertex_permutation",
    "CHUNK", "build_dspmm", "build_eigen_step",
    "build_eigen_step_compressed", "edge_spec", "pack_compressed_panels",
    "pack_edge_panels", "vector_spec",
    "DistOperator", "default_mesh", "e2e_mesh", "pod_compressed_deviation",
    "compressed_psum_pod",
]
