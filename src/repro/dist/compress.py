"""Compressed cross-pod reduction (paper §3.4: trade a little precision
for a lot of slow-link I/O).

Inter-pod links are the "SSD" of the collective hierarchy — an order of
magnitude slower than in-pod ICI — so the small dense reductions of the
eigensolver (Gram matrices, projection coefficients) cross pods as scaled
int8 instead of f32: 4× fewer wire bytes for a bounded, tested error.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def compressed_psum_pod(v: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-scaled psum over `axis_name` (call inside shard_map/pmap).

    Every participant quantizes to round(v / scale) with the shared scale
    absmax/127 (absmax taken over the whole group, so no participant
    clips); the int8 payloads are summed exactly in int32 and rescaled.
    Per-element error is at most scale/2 per participant, i.e.
    n_pods · absmax / 254 total — the bound asserted by
    tests/test_distributed.py::test_compressed_pod_psum.
    """
    absmax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis_name)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round(v / safe).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(v.dtype) * safe


# ----------------------------------------------------- point compression
def int8_quantize(x: jnp.ndarray):
    """x -> (int8 codes, scalar scale), |dequantize - x| <= scale / 2."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    return jnp.round(x / safe).astype(jnp.int8), scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class TopKState(NamedTuple):
    """Error-feedback residual: mass not yet transmitted."""
    error: jnp.ndarray


def topk_init(g: jnp.ndarray) -> TopKState:
    return TopKState(error=jnp.zeros_like(g))


def topk_compress(g: jnp.ndarray, state: TopKState, *, k: int):
    """Top-k sparsification with error feedback (memory-compensated SGD).

    The untransmitted residual is folded into the next call, so a constant
    gradient is fully delivered over time even with k << n.
    Returns (values, indices, new_state).
    """
    corrected = g + state.error
    _, idx = jax.lax.top_k(jnp.abs(corrected), k)
    vals = corrected[idx]
    sent = jnp.zeros_like(corrected).at[idx].set(vals)
    return vals, idx, TopKState(error=corrected - sent)


def topk_decompress(vals: jnp.ndarray, idx: jnp.ndarray,
                    shape: tuple) -> jnp.ndarray:
    return jnp.zeros(shape, vals.dtype).at[idx].set(vals)
