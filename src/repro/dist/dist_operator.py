"""DistOperator — the sharded SEM-SpMM step driven by the core restart loop.

This is the end-to-end seam of the paper (§3 + §4): `core.eigsh` owns the
Krylov–Schur restart logic and the out-of-core subspace bookkeeping, while
the actual numerical work of one expansion — SpMM over the edge panels,
CGS2 block orthogonalization against V, CholQR2 — runs as ONE fused
`shard_map`ped program on the device mesh (`dspmm.build_eigen_step`).

The split of residencies mirrors the paper exactly:

  * the *edge panels* are packed once at construction
    (`pack_edge_panels`, optionally also the 6-byte/edge compressed stream
    via `pack_compressed_panels`) and live device-sharded, one (1,1,e_loc)
    panel per device — the streamed-from-SSD operand of §3.3;
  * the *subspace history* V is held device-sharded as a (nb_v, n_pad, b)
    stack (`vector_spec` rows over every device) and is consumed in place
    by the fused step — the paper's "recent matrix cached in fast memory";
  * the core loop's `MultiVector` remains the system of record: every
    appended block is also written to the TieredStore (spillable to the
    SAFS page files), and restart compression / eigenvector
    materialization stream it back — "subspace on SSD".

`eigsh` discovers the fused path through the declared `fused_expand`
capability (`core.operator.capabilities`; the legacy
`supports_fused_expand` attribute is kept for external callers) and calls
`fused_expand(v, q)` instead of separate
matmat/mv_trans_mv/mv_times_mat/cholqr calls; the device shard cache is
reconciled against `MultiVector.block_names()`, so restarts (which replace
every block) and fresh solves rebuild it transparently.

Options measured by `benchmarks/bench_dist_e2e.py`:

  * `pod_compressed=True` — int8-compressed cross-pod reductions inside
    CGS2/CholQR2 (`compress.compressed_psum_pod`); the bench records the
    per-restart eigenvalue deviation so error accumulation over full
    restart cycles is a number, not a guess;
  * `compressed=True` — the 6-byte/edge delta-encoded panel stream with
    bfloat16 values/operands (accumulation stays f32).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import layout
from repro.dist.dspmm import (CHUNK, _groups, build_dspmm, build_eigen_step,
                              build_eigen_step_compressed, edge_spec,
                              pack_compressed_panels, pack_edge_panels,
                              vector_spec)
from repro.obs import trace


def default_mesh(devices=None) -> jax.sharding.Mesh:
    """A (pod, data, model) mesh over the available devices: pod stays 1,
    model takes a factor of 2 when the device count is even. Explicit
    meshes (e.g. (2,2,2) in the forced-host tests) take precedence."""
    devices = list(jax.devices() if devices is None else devices)
    nd = len(devices)
    model = 2 if nd % 2 == 0 and nd > 1 else 1
    return jax.make_mesh((1, nd // model, model), ("pod", "data", "model"),
                         devices=devices)


class DistOperator:
    """LinearOperator over the shard_mapped panel SpMM, with the fused
    SpMM+CGS2/CholQR2 expansion hook that `core.eigsh` dispatches to.

    Vertices are permuted (`layout.vertex_permutation`) and padded
    (`layout.padded_n`); the operator works in *position* space of size
    `self.n = n_pad`. `nat_to_pad` / `pad_to_nat` map natural-vertex
    vectors in and out (padding rows are zero rows of A, contributing
    eigenvalue 0 — harmless for the paper's "LM"/"LA" workloads).
    """

    # legacy attribute kept for external callers; solvers dispatch on the
    # declared capability set below (core.operator.capabilities)
    supports_fused_expand = True

    def capabilities(self) -> frozenset:
        from repro.core.operator import CAP_FUSED_EXPAND
        return frozenset({CAP_FUSED_EXPAND})

    def __init__(self, n: int, rows, cols, vals, *, mesh=None,
                 compressed: bool = False, pod_compressed: bool = False,
                 chunk: int = CHUNK):
        self.mesh = mesh if mesh is not None else default_mesh()
        r_groups, m_groups = _groups(self.mesh)
        self.n_logical = int(n)
        self.n = layout.padded_n(n, r_groups, m_groups)
        self.perm = layout.vertex_permutation(self.n, r_groups, m_groups)
        self.compressed = bool(compressed)
        self.pod_compressed = bool(pod_compressed)

        rows = np.asarray(rows)
        cols = np.asarray(cols)
        pc, pr, pv, self.e_loc = pack_edge_panels(
            self.n, self.perm[rows], self.perm[cols], vals,
            r_groups=r_groups, m_groups=m_groups)
        edge_sh = NamedSharding(self.mesh, edge_spec(self.mesh))
        # uncompressed panels always live: matmat (residual checks, the
        # non-fused fallback) contracts them even when the fused step
        # streams the compressed format
        self._pc = jax.device_put(jnp.asarray(pc), edge_sh)
        self._pr = jax.device_put(jnp.asarray(pr), edge_sh)
        self._pv = jax.device_put(jnp.asarray(pv), edge_sh)
        self._packed = self._bases = self._vbf16 = None
        if self.compressed:
            packed, bases, vbf16 = pack_compressed_panels(pc, pr, pv,
                                                          chunk=chunk)
            self._packed = jax.device_put(jnp.asarray(packed), edge_sh)
            self._bases = jax.device_put(jnp.asarray(bases), edge_sh)
            self._vbf16 = jax.device_put(jnp.asarray(vbf16), edge_sh)
        self._vec_sh = NamedSharding(self.mesh, vector_spec(self.mesh))
        self._vstack_sh = NamedSharding(
            self.mesh, P(None, tuple(self.mesh.axis_names), None))
        self._spmm: Dict[int, object] = {}       # b -> jitted SpMM
        self._steps: Dict[tuple, object] = {}    # (nb_v, b) -> jitted step
        self._names: List[str] = []              # mirrored block names
        # (nb_v, n_pad, b) device-sharded subspace stack, in the dtype the
        # fused step consumes: f32, or bf16 for the compressed stream —
        # holding an f32 master alongside would triple the device bytes
        # the compressed mode exists to save
        self._vstack: Optional[jnp.ndarray] = None
        self.n_fused_steps = 0
        # per-compiled-program collective wire bytes (trace attribution;
        # computed lazily and only while tracing — lowering costs a
        # compile)
        self._coll_bytes: Dict[tuple, Optional[dict]] = {}

    # ------------------------------------------------------- vertex maps
    def nat_to_pad(self, x: np.ndarray) -> np.ndarray:
        """Scatter natural-vertex rows into permuted padded positions."""
        out = np.zeros((self.n,) + x.shape[1:], np.float32)
        out[self.perm[:self.n_logical]] = x
        return out

    def pad_to_nat(self, x) -> np.ndarray:
        """Gather natural-vertex rows out of a padded position vector."""
        return np.asarray(x)[self.perm[:self.n_logical]]

    # -------------------------------------------------- trace attribution
    def _collectives(self, key: tuple, fn, args) -> Optional[dict]:
        """Per-device collective wire bytes of one compiled program
        (`utils.hlo_analysis.collective_bytes` over the optimized HLO),
        cached per (kind, nb_v, b) key. Only consulted while tracing; any
        lowering/compile failure degrades to None, never to a solve
        error."""
        if key in self._coll_bytes:
            return self._coll_bytes[key]
        try:
            from repro.utils.hlo_analysis import collective_bytes
            txt = fn.lower(*args).compile().as_text()
            out = collective_bytes(txt, int(self.mesh.devices.size))
        except Exception:
            out = None
        self._coll_bytes[key] = out
        return out

    # ----------------------------------------------------------- matmat
    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        b = int(x.shape[1])
        fn = self._spmm.get(b)
        if fn is None:
            fn = self._spmm[b] = build_dspmm(self.mesh, n_pad=self.n,
                                             e_loc=self.e_loc, b=b)
        with trace.span("operator.matmat", op="DistOperator", k=b,
                        n=self.n) as sp:
            args = (self._pc, self._pr, self._pv,
                    jnp.asarray(x, jnp.float32))
            if trace.active() is not None:
                coll = self._collectives(("spmm", b), fn, args)
                if coll is not None:
                    sp.set(collective_bytes=coll.get("total", 0.0))
            return fn(*args)

    # ------------------------------------------------------- fused step
    def _step(self, nb_v: int, b: int):
        key = (nb_v, b)
        fn = self._steps.get(key)
        if fn is None:
            if self.compressed:
                fn, _, _ = build_eigen_step_compressed(
                    self.mesh, n_pad=self.n, e_loc=self.e_loc, b=b,
                    nb_v=nb_v, pod_compressed=self.pod_compressed)
            else:
                fn = build_eigen_step(self.mesh, n_pad=self.n,
                                      e_loc=self.e_loc, b=b, nb_v=nb_v,
                                      pod_compressed=self.pod_compressed)
            self._steps[key] = fn
        return fn

    def _sync_vstack(self, v, q: jnp.ndarray) -> None:
        """Reconcile the device-sharded subspace stack with the
        MultiVector's blocks. Common case (one append) extends the stack
        with q's shard; any other change (restart compression replaced
        every block, a fresh solve) rebuilds from the store — the only
        point where subspace bytes cross from the SSD tier back to the
        device mesh."""
        names = v.block_names()
        dt = jnp.bfloat16 if self.compressed else jnp.float32
        qs = jax.device_put(jnp.asarray(q, jnp.float32),
                            self._vec_sh).astype(dt)
        if (self._vstack is not None and len(names) >= 1
                and self._names == names[:-1]):
            stack = jnp.concatenate([self._vstack, qs[None]], axis=0)
        else:
            blocks = [jax.device_put(jnp.asarray(v.block(i), jnp.float32),
                                     self._vec_sh).astype(dt)
                      for i in range(v.nblocks - 1)] + [qs]
            stack = jnp.stack(blocks, axis=0)
        self._vstack = jax.device_put(stack, self._vstack_sh)
        self._names = names

    def fused_expand(self, v, q: jnp.ndarray):
        """One combined SpMM + CGS2 + CholQR2 expansion (q already appended
        to v by the caller). Returns (q_next, h_col, r_next) with the exact
        invariant A·q = V·h_col + q_next·r_next, V including q."""
        b = int(q.shape[1])
        with trace.span("operator.fused_expand", op="DistOperator",
                        k=b) as sp:
            self._sync_vstack(v, q)
            nb_v = self._vstack.shape[0]
            step = self._step(nb_v, b)
            panels = ((self._packed, self._bases, self._vbf16)
                      if self.compressed else (self._pc, self._pr, self._pv))
            args = panels + (self._vstack, self._vstack[-1])
            sp.set(nb_v=nb_v)
            if trace.active() is not None:
                coll = self._collectives(("step", nb_v, b), step, args)
                if coll is not None:
                    sp.set(collective_bytes=coll.get("total", 0.0))
            q_next, h, r = step(*args)
            self.n_fused_steps += 1
            return q_next, h, r

    def reset_subspace(self) -> None:
        """Drop the mirrored device shards (before reusing the operator
        for an unrelated solve)."""
        self._names = []
        self._vstack = None


def e2e_mesh() -> jax.sharding.Mesh:
    """Mesh for the end-to-end drivers (example + bench share it so the
    two cannot drift): a multi-pod (2, d, 2) layout when the device count
    allows one — exercising the pod axis the compressed reductions target
    — else whatever `default_mesh` can build (down to 1 device)."""
    nd = len(jax.devices())
    if nd % 4 == 0 and nd >= 4:
        return jax.make_mesh((2, nd // 4, 2), ("pod", "data", "model"))
    return default_mesh()


def pod_compressed_deviation(n: int, rows, cols, vals, w_reference, *,
                             mesh, nev: int, block_size: int,
                             max_restarts: int = 3, tol: float = 1e-9,
                             impl: str = "ref") -> list:
    """Per-restart eigenvalue deviation of the `pod_compressed=True` solve
    against a reference spectrum — the ROADMAP's "measure error
    accumulation over full Krylov iterations" number, shared by the bench,
    the e2e example and the parity tests so the methodology cannot drift.

    Deviation is compared by |λ|: "LM" keeps the top magnitudes, and a
    power-law graph's near-±pairs make the smallest kept magnitude's sign
    an arbitrary tie — a signed comparison would report the tie, not the
    compression error. `tol` defaults far below the int8 reduction floor
    so exactly `max_restarts` full cycles are measured.
    """
    from repro.core.krylov_schur import eigsh
    w_abs = np.sort(np.abs(np.asarray(w_reference)))
    devs: list = []

    def cb(k, theta, res):
        devs.append(float(np.abs(np.sort(np.abs(theta)) - w_abs).max()))

    dop = DistOperator(n, rows, cols, vals, mesh=mesh, pod_compressed=True)
    eigsh(dop, nev, block_size=block_size, tol=tol,
          max_restarts=max_restarts, impl=impl, callback=cb)
    return devs
