"""Sharded semi-external-memory SpMM + fused eigensolver expansion step.

This is the distributed layer of the paper's design (§3.2–3.4) mapped onto
a (pod, data, model) jax mesh:

  * The sparse graph is packed into a 2D grid of *edge panels*
    (`pack_edge_panels`): panel (g, m) holds the edges whose destination row
    lives in row group g and whose source column lives in column group m.
    Panels are the streamed operand — the paper's SSD-resident tiles; here
    they shard over every device, spec `edge_spec`.
  * The dense vector subspace X stays sharded over all devices
    (`vector_spec`) — the paper's in-fast-memory TAS. One SpMM gathers each
    column group's rows over the row axes (the panel's column working set),
    contracts the local panel, and reduce-scatters partial rows over the
    "model" axis. Per device that moves n_pad/M·b gathered + n_pad/R·b
    reduced floats — the minimized-vector-I/O discipline of §3.3.
  * `build_eigen_step` fuses SpMM -> CGS2 block orthogonalization against
    the cached subspace V -> CholQR2, returning (q_new, h, r) with
    A·x = V·h + q_new·r exactly (the Krylov expansion invariant).
  * `build_eigen_step_compressed` is the I/O-compressed variant (§3.4's
    "compact external format" theme): edge endpoints are delta-encoded
    against per-CHUNK bases and packed into one uint32 (16+16 bits), edge
    values and the dense operands travel as bfloat16 — 6 bytes/edge instead
    of 12 — while all accumulation stays float32.

The per-panel contraction is gather/scatter jnp (portable: CPU tests and
SPMD partitioning both handle it); `panel_to_blocks` bridges a packed panel
to the Pallas block-sparse kernel in `kernels/spmm_tile.py` for the
TPU-resident panel contraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.4.35 re-exports shard_map; fall back for older trees
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map

from repro.dist import layout
from repro.dist.compress import compressed_psum_pod

# Edge-stream chunk: compressed panels delta-encode endpoints against one
# (row, col) base per CHUNK edges, and panel lengths pad to a CHUNK multiple
# so the streaming grid is uniform. Consumed by launch/dryrun.py sizing.
CHUNK = 4096

_MASK16 = np.uint32(0xFFFF)


# ------------------------------------------------------------------ specs
def row_axes(mesh) -> tuple:
    """Mesh axes forming the R row groups (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def edge_spec(mesh) -> P:
    """Spec for (R, M, e_loc) panel arrays: one (1,1,e_loc) panel/device."""
    return P(row_axes(mesh), "model", None)


def vector_spec(mesh) -> P:
    """Spec for (n_pad, b) vector blocks: rows sharded over all devices."""
    return P(tuple(mesh.axis_names), None)


def _groups(mesh) -> tuple[int, int]:
    r = int(np.prod([mesh.shape[a] for a in row_axes(mesh)]))
    return r, int(mesh.shape["model"])


# ------------------------------------------------------------- panel pack
def pack_edge_panels(n_pad: int, rows, cols, vals, *, r_groups: int,
                     m_groups: int, e_loc: int | None = None):
    """Partition permuted COO edges into the (R, M) panel grid.

    rows/cols are *positions* (already through `vertex_permutation`).
    Returns (panel_cols, panel_rows, panel_vals, e_loc), each array of shape
    (r_groups, m_groups, e_loc):

      panel_rows: destination row local to the row group's contiguous block
      panel_cols: source row local to the column group's gathered buffer
      panel_vals: edge weights; padding slots carry value 0 (and repeat the
                  panel's last endpoint so compressed delta bases stay tight)

    Every edge lands in exactly one panel — edge count and value mass are
    conserved (asserted by tests/test_dist_layout.py). Panel interiors are
    sorted by (row, col) so output-tile revisits are consecutive (the
    paper's block-row-major stream order) and compressed chunk deltas small.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float32)
    assert rows.shape == cols.shape == vals.shape
    g = layout.row_group_of(rows, n_pad, r_groups)
    m = layout.col_group_of(cols, n_pad, r_groups, m_groups)
    r_loc = layout.local_row(rows, n_pad, r_groups)
    c_loc = layout.local_col(cols, n_pad, r_groups, m_groups)

    panel = g * m_groups + m
    order = np.lexsort((c_loc, r_loc, panel))
    panel, r_loc, c_loc, vals = (a[order] for a in (panel, r_loc, c_loc,
                                                    vals))
    counts = np.bincount(panel, minlength=r_groups * m_groups)
    need = int(counts.max()) if counts.size else 1
    if e_loc is None:
        e_loc = max(need, 1)
    assert need <= e_loc, f"panel overflow: {need} edges > e_loc={e_loc}"

    pr = np.zeros((r_groups * m_groups, e_loc), dtype=np.int32)
    pc = np.zeros_like(pr)
    pv = np.zeros((r_groups * m_groups, e_loc), dtype=np.float32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for p in range(r_groups * m_groups):
        lo, hi = starts[p], starts[p + 1]
        k = hi - lo
        pr[p, :k], pc[p, :k] = r_loc[lo:hi], c_loc[lo:hi]
        if 0 < k < e_loc:  # pad by repeating the last endpoint, weight 0
            pr[p, k:], pc[p, k:] = pr[p, k - 1], pc[p, k - 1]
        pv[p, :k] = vals[lo:hi]
    shape3 = (r_groups, m_groups, e_loc)
    return (pc.reshape(shape3), pr.reshape(shape3), pv.reshape(shape3),
            e_loc)


def pack_compressed_panels(pc: np.ndarray, pr: np.ndarray, pv: np.ndarray,
                           *, chunk: int = CHUNK):
    """Delta-encode packed panels into the 6-byte/edge streaming format.

    Per CHUNK-edge chunk, endpoints are stored as uint16 offsets from the
    chunk's (min row, min col) base: packed = row_off << 16 | col_off
    (uint32), bases interleave [r0, c0, r1, c1, ...] (int32), values cast
    to bfloat16. Returns (packed, bases, vals_bf16) with shapes
    (R, M, e_pad), (R, M, 2·n_chunks), (R, M, e_pad); e_pad rounds e_loc up
    to a chunk multiple (padding repeats each panel's last edge, weight 0).

    Size bound + sub-tile re-basing: sub-tile deltas must fit 16 bits, so a
    sub-tile's rows may span at most 65536 panel rows and its columns 65536
    panel columns. Panels are (row, col)-sorted, so the row span of `chunk`
    consecutive edges is small, but the column span of one dense row can
    reach the panel width n_pad/M, which exceeds 2^16 on sparse meshes.
    When the requested chunk overflows, the chunk is re-based at sub-tile
    granularity: each chunk splits into 2^k equal sub-tiles, each carrying
    its own (row, col) base, with k the smallest power that fits every
    delta (worst case sub-tile = 1 edge, which always fits). e_pad stays a
    multiple of `chunk` — only the bases array grows. The stream is
    self-describing: consumers recover the effective sub-tile length as
    `2 * e_pad // bases.shape[-1]` (see `_unpack_edges`), so the packed
    format needs no side channel.
    """
    import ml_dtypes
    r_groups, m_groups, e_loc = pc.shape
    e_pad = -(-e_loc // chunk) * chunk
    if e_pad != e_loc:
        reps = e_pad - e_loc
        pc = np.concatenate([pc, np.repeat(pc[..., -1:], reps, -1)], -1)
        pr = np.concatenate([pr, np.repeat(pr[..., -1:], reps, -1)], -1)
        pv = np.concatenate([pv, np.zeros(pc.shape[:2] + (reps,),
                                          pv.dtype)], -1)
    sub = chunk
    while True:
        n_sub = e_pad // sub
        rc = pr.reshape(r_groups, m_groups, n_sub, sub)
        cc = pc.reshape(r_groups, m_groups, n_sub, sub)
        base_r = rc.min(-1)
        base_c = cc.min(-1)
        off_r = (rc - base_r[..., None]).astype(np.int64)
        off_c = (cc - base_c[..., None]).astype(np.int64)
        if not off_r.size or max(off_r.max(), off_c.max()) <= 0xFFFF:
            break
        assert sub > 1, "1-edge sub-tile cannot overflow a 16-bit delta"
        # re-base at finer sub-tile granularity; an odd sub drops straight
        # to 1 so every sub in the sequence divides e_pad
        sub = sub // 2 if sub % 2 == 0 else 1
    packed = ((off_r.astype(np.uint32) << np.uint32(16))
              | off_c.astype(np.uint32)).reshape(r_groups, m_groups, e_pad)
    bases = np.stack([base_r, base_c], axis=-1).reshape(
        r_groups, m_groups, 2 * n_sub).astype(np.int32)
    return packed, bases, pv.astype(ml_dtypes.bfloat16)


def _unpack_edges(packed, bases):
    """Inverse of pack_compressed_panels for one device's (e_pad,) stream.

    The sub-tile length is recovered from the array shapes (the stream is
    self-describing), so sub-tiled re-based streams decode transparently.
    """
    n_sub = bases.shape[0] // 2
    sub = packed.shape[0] // n_sub
    b2 = bases.reshape(n_sub, 2)
    off = packed.reshape(n_sub, sub)
    pr = (off >> np.uint32(16)).astype(jnp.int32) + b2[:, :1]
    pc = (off & _MASK16).astype(jnp.int32) + b2[:, 1:]
    return pr.reshape(-1), pc.reshape(-1)


# ---------------------------------------------------------- local kernels
def _panel_spmm(pc, pr, pv, x_loc, *, mesh, n_pad: int, b: int):
    """Per-device SpMM body (inside shard_map): y_loc = (A @ x)_shard.

    1. all-gather this column group's x rows over the row axes (the panel's
       column working set, n_pad/M rows),
    2. contract the local edge panel with gather + segment scatter-add
       (f32 accumulation regardless of stream dtype),
    3. reduce-scatter partial output rows over the model axis so each
       device ends holding exactly its own n_pad/(R·M) shard.
    """
    r_groups, m_groups = _groups(mesh)
    x_m = jax.lax.all_gather(x_loc, row_axes(mesh), axis=0, tiled=True)
    contrib = pv.astype(jnp.float32)[:, None] * x_m[pc].astype(jnp.float32)
    y_g = jnp.zeros((n_pad // r_groups, b), jnp.float32).at[pr].add(contrib)
    return jax.lax.psum_scatter(y_g, "model", scatter_dimension=0,
                                tiled=True)


def _cgs2_cholqr2(w_loc, v_loc, axes, *, b: int, nb_v: int,
                  pod_compressed: bool = False):
    """Classical Gram-Schmidt (2 passes) against V + CholQR (2 passes).

    w_loc: (s, b) f32 shard of A·x. v_loc: (nb_v, s, b) shard of the cached
    subspace. Returns (q_loc, h, r) with the exact factorization
    w = V·h + q·r; h accumulates both CGS passes, r composes both CholQR
    triangles. All b×b / (nb_v·b)×b reductions psum over every mesh axis
    (optionally int8-compressed across the pod axis — the paper's
    compressed cross-rack reduction).
    """
    def allsum(z):
        if pod_compressed and "pod" in axes:
            rest = tuple(a for a in axes if a != "pod")
            z = jax.lax.psum(z, rest)
            shape = z.shape
            return compressed_psum_pod(z.reshape(-1), "pod").reshape(shape)
        return jax.lax.psum(z, axes)

    vf = v_loc.astype(jnp.float32)
    w = w_loc
    h = jnp.zeros((nb_v, b, b), jnp.float32)
    for _ in range(2):  # CGS2: the second pass scrubs f32 cancellation
        hi = allsum(jnp.einsum("jnk,nl->jkl", vf, w))
        w = w - jnp.einsum("jnk,jkl->nl", vf, hi)
        h = h + hi
    r = jnp.eye(b, dtype=jnp.float32)
    q = w
    for _ in range(2):  # CholQR2
        gram = allsum(q.T @ q)
        ell = jnp.linalg.cholesky(gram)
        q = jax.scipy.linalg.solve_triangular(ell, q.T, lower=True).T
        r = ell.T @ r
    return q, h.reshape(nb_v * b, b), r


# ------------------------------------------------------------------ build
def build_dspmm(mesh, *, n_pad: int, e_loc: int, b: int):
    """Jitted y = A @ x over packed panels: fn(pc, pr, pv, x) -> y.

    pc/pr/pv: (R, M, e_loc) from pack_edge_panels, x/y: (n_pad, b) f32.
    """
    del e_loc  # shapes carry it; kept in the signature as the panel contract

    def local(pc, pr, pv, x_loc):
        return _panel_spmm(pc[0, 0], pr[0, 0], pv[0, 0], x_loc,
                           mesh=mesh, n_pad=n_pad, b=b)

    es, vs = edge_spec(mesh), vector_spec(mesh)
    return jax.jit(shard_map(local, mesh=mesh, in_specs=(es, es, es, vs),
                             out_specs=vs, check_rep=False))


def build_eigen_step(mesh, *, n_pad: int, e_loc: int, b: int, nb_v: int,
                     pod_compressed: bool = False):
    """Fused Krylov expansion: fn(pc, pr, pv, vstack, x) -> (q_new, h, r).

    vstack: (nb_v, n_pad, b) — the cached subspace V as stacked blocks
    (V[:, j·b+k] = vstack[j, :, k]). Invariants (tested):
      q_newᵀ q_new = I,  Vᵀ q_new = 0,  A·x = V·h + q_new·r.
    """
    del e_loc
    axes = tuple(mesh.axis_names)

    def local(pc, pr, pv, v_loc, x_loc):
        w = _panel_spmm(pc[0, 0], pr[0, 0], pv[0, 0], x_loc,
                        mesh=mesh, n_pad=n_pad, b=b)
        return _cgs2_cholqr2(w, v_loc, axes, b=b, nb_v=nb_v,
                             pod_compressed=pod_compressed)

    es, vs = edge_spec(mesh), vector_spec(mesh)
    vstack_spec = P(None, axes, None)
    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=(es, es, es, vstack_spec, vs),
        out_specs=(vs, P(None, None), P(None, None)), check_rep=False))


def build_eigen_step_compressed(mesh, *, n_pad: int, e_loc: int, b: int,
                                nb_v: int, chunk: int = CHUNK,
                                pod_compressed: bool = False):
    """Compressed-stream expansion step (6 bytes/edge, bf16 vectors).

    Returns (fn, n_chunks, e_pad); fn(packed, bases, vals_bf16,
    vstack_bf16, x_bf16) -> (q_new, h, r) in f32. Matches the baseline step
    to bf16 input-rounding tolerance (accumulation stays f32). `chunk` here
    only sizes the declared shapes: if pack_compressed_panels re-based a
    stream at a finer sub-tile (wide panels), pass the effective sub-tile
    length `2 * e_pad // bases.shape[-1]` instead — the runtime unpack is
    shape-driven either way.
    """
    e_pad = -(-e_loc // chunk) * chunk
    n_chunks = e_pad // chunk
    axes = tuple(mesh.axis_names)

    def local(packed, bases, pv, v_loc, x_loc):
        pr, pc = _unpack_edges(packed[0, 0], bases[0, 0])
        w = _panel_spmm(pc, pr, pv[0, 0], x_loc, mesh=mesh, n_pad=n_pad,
                        b=b)
        return _cgs2_cholqr2(w, v_loc, axes, b=b, nb_v=nb_v,
                             pod_compressed=pod_compressed)

    es, vs = edge_spec(mesh), vector_spec(mesh)
    vstack_spec = P(None, axes, None)
    fn = jax.jit(shard_map(
        local, mesh=mesh, in_specs=(es, es, es, vstack_spec, vs),
        out_specs=(vs, P(None, None), P(None, None)), check_rep=False))
    return fn, n_chunks, e_pad


# ------------------------------------------- kernels-layer bridge (TPU)
def panel_to_blocks(pr, pc, pv, n_rows: int, n_cols: int, *, bm: int,
                    bn: int):
    """Re-tile one packed panel into the block-sparse stream that
    kernels/spmm_tile.py consumes on TPU.

    Returns (blocks, block_cols, block_rows): dense (bm, bn) images of the
    non-empty blocks in block-row-major order (block_rows non-decreasing —
    the revisiting-output contract of spmm_blocksparse).
    """
    pr = np.asarray(pr, np.int64)
    pc = np.asarray(pc, np.int64)
    pv = np.asarray(pv, np.float32)
    live = pv != 0
    pr, pc, pv = pr[live], pc[live], pv[live]
    assert n_rows % bm == 0 and n_cols % bn == 0
    br, bc = pr // bm, pc // bn
    key = br * (n_cols // bn) + bc
    uniq, inv = np.unique(key, return_inverse=True)
    blocks = np.zeros((max(len(uniq), 1), bm, bn), np.float32)
    np.add.at(blocks, (inv, pr % bm, pc % bn), pv)
    block_rows = (uniq // (n_cols // bn)).astype(np.int32)
    block_cols = (uniq % (n_cols // bn)).astype(np.int32)
    if not len(uniq):
        block_rows = np.zeros(1, np.int32)
        block_cols = np.zeros(1, np.int32)
    return blocks, block_cols, block_rows


def panel_spmm_blocksparse(pr, pc, pv, x_panel, n_rows: int, *, bm: int = 8,
                           bn: int = 8, interpret: bool = True):
    """Panel contraction through the Pallas tile kernel (reference bridge).

    x_panel: (n_cols, k) column working set for this panel. Used by tests
    to pin the panel format to the kernels layer; production TPU panels
    call spmm_blocksparse directly with pre-tiled streams.
    """
    from repro.kernels.spmm_tile import spmm_blocksparse
    n_cols = x_panel.shape[0]
    blocks, bcols, brows = panel_to_blocks(pr, pc, pv, n_rows, n_cols,
                                           bm=bm, bn=bn)
    y = spmm_blocksparse(jnp.asarray(blocks), jnp.asarray(bcols),
                         jnp.asarray(brows), jnp.asarray(x_panel),
                         n_block_rows=n_rows // bm, interpret=interpret)
    # rows in empty block rows are uninitialized by contract — mask them
    # (select, not multiply: uninitialized VMEM can be NaN/Inf on TPU)
    mask = np.zeros(n_rows // bm, bool)
    mask[brows] = True
    return np.where(np.repeat(mask, bm)[:, None], np.asarray(y), 0.0)
