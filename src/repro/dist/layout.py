"""Vertex layout for the sharded SpMM (paper §3.2–3.3, device-level analogue).

The paper partitions the graph into a 2D grid of edge *panels*: row panels
bound the working-set of the output ("TAS" rows held in fast memory), column
panels bound the rows of the dense subspace that one panel gathers from.
Here the grid is a (pod, data, model) device mesh:

  * the non-"model" axes (pod × data, or just data) form R row groups,
  * the "model" axis forms M column groups,
  * the n_pad vertex positions are split into R·M equal contiguous shards,
    shard index = g·M + m for the device with row coordinate g and model
    coordinate m (exactly jax's P(("pod","data","model")) layout order).

Row group g therefore owns the contiguous position range
[g·n_pad/R, (g+1)·n_pad/R); column group m owns the M-strided shard set
{g·M + m : g}. `vertex_permutation` assigns natural vertex ids to positions
round-robin over the shards so that the hub vertices of a power-law graph
(concentrated at low ids after R-MAT generation) spread evenly over devices
— the paper's load-balancing motivation for randomized vertex placement.
"""
from __future__ import annotations

import numpy as np

# Each per-device shard is padded to a multiple of this many vertex rows so
# panel tiles stay aligned for the kernels layer (VPU lane width).
SHARD_MULTIPLE = 8


def n_shards(r_groups: int, m_groups: int) -> int:
    return r_groups * m_groups


def padded_n(n: int, r_groups: int, m_groups: int,
             *, multiple: int = SHARD_MULTIPLE) -> int:
    """Smallest n_pad >= n divisible by r_groups·m_groups·multiple.

    Divisibility by R·M gives equal per-device shards; the extra `multiple`
    keeps every shard length a multiple of the tile row unit.
    """
    base = r_groups * m_groups * multiple
    return -(-n // base) * base


def shard_size(n_pad: int, r_groups: int, m_groups: int) -> int:
    """Per-device vertex rows s = n_pad / (R·M)."""
    s, rem = divmod(n_pad, r_groups * m_groups)
    assert rem == 0, (n_pad, r_groups, m_groups)
    return s


def vertex_permutation(n_pad: int, r_groups: int,
                       m_groups: int) -> np.ndarray:
    """Bijective map natural-vertex-id -> mesh position, length n_pad.

    Vertex i goes to shard i mod (R·M) at offset i // (R·M): round-robin
    over devices, so consecutive (and in R-MAT graphs, high-degree) vertices
    land on different devices. Padding ids n..n_pad-1 fill the remaining
    positions under the same rule, keeping the map a permutation.
    """
    nd = n_shards(r_groups, m_groups)
    s = shard_size(n_pad, r_groups, m_groups)
    i = np.arange(n_pad, dtype=np.int64)
    return (i % nd) * s + i // nd


def row_group_of(pos: np.ndarray, n_pad: int, r_groups: int) -> np.ndarray:
    """Row group (0..R-1) owning each position: contiguous n_pad/R blocks."""
    return pos // (n_pad // r_groups)


def col_group_of(pos: np.ndarray, n_pad: int, r_groups: int,
                 m_groups: int) -> np.ndarray:
    """Column group (0..M-1): the shard index mod M."""
    s = shard_size(n_pad, r_groups, m_groups)
    return (pos // s) % m_groups


def local_row(pos: np.ndarray, n_pad: int, r_groups: int) -> np.ndarray:
    """Offset of a position inside its row group's contiguous block."""
    return pos % (n_pad // r_groups)


def local_col(pos: np.ndarray, n_pad: int, r_groups: int,
              m_groups: int) -> np.ndarray:
    """Index of a position inside its column group's gathered buffer.

    A column group's positions are the M-strided shards {g·M + m : g}. The
    SpMM all-gathers them over the row axes in row-group order, so position
    q in shard g·M + m lands at g·s + (q mod s) of the (n_pad/M)-row buffer.
    """
    s = shard_size(n_pad, r_groups, m_groups)
    return (pos // s // m_groups) * s + pos % s


def unlocal_col(c_loc: np.ndarray, m: int, n_pad: int, r_groups: int,
                m_groups: int) -> np.ndarray:
    """Inverse of `local_col` for column group m (testing/debug helper)."""
    s = shard_size(n_pad, r_groups, m_groups)
    return (c_loc // s * m_groups + m) * s + c_loc % s
