"""repro.ft — fault-tolerance primitives (see README.md here).

Preemption-safe shutdown, file-based membership coordination, straggler
detection. The solver-side consumer is `ckpt.solver.SolveCheckpointer`
(pass a `PreemptionGuard` in its `CheckpointPolicy`).
"""
from repro.ft.coordinator import Coordinator
from repro.ft.preemption import PreemptionGuard
from repro.ft.straggler import StragglerDecision, StragglerTracker

__all__ = ["Coordinator", "PreemptionGuard", "StragglerDecision",
           "StragglerTracker"]
