"""repro.ft"""
