"""File-based coordination: heartbeats + generation-numbered membership.

Stands in for the control-plane (GCS / etcd / Borg) a real 1000-node job
uses. Each participant heartbeats a file; the coordinator computes live
membership; a membership change bumps the *generation*, which invalidates
in-flight collectives and tells every participant to restore from the last
checkpoint with the new mesh (elastic scaling). All logic is local-fs and
unit-testable.
"""
from __future__ import annotations

import json
import os
import time


class Coordinator:
    def __init__(self, root: str, *, timeout: float = 10.0):
        self.root = root
        self.timeout = timeout
        os.makedirs(os.path.join(root, "hb"), exist_ok=True)

    # -- participant side ----------------------------------------------------
    def heartbeat(self, participant: int, *, now: float | None = None) -> None:
        path = os.path.join(self.root, "hb", f"{participant}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"t": now if now is not None else time.time()}, f)
        os.replace(tmp, path)

    # -- coordinator side ----------------------------------------------------
    def live_members(self, *, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        out = []
        hb = os.path.join(self.root, "hb")
        for fn in os.listdir(hb):
            if not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(hb, fn)) as f:
                    t = json.load(f)["t"]
                member = int(fn.split(".")[0])
            except (json.JSONDecodeError, KeyError, TypeError, ValueError,
                    OSError):
                # a truncated/corrupt/vanished heartbeat is a DEAD member
                # (a node killed mid-write), not a coordinator crash — the
                # membership change is exactly what generation() must see
                continue
            if now - t <= self.timeout:
                out.append(member)
        return sorted(out)

    def generation(self) -> tuple[int, list[int]]:
        """Current (generation, membership); bumps generation on change."""
        gen_path = os.path.join(self.root, "gen.json")
        members = self.live_members()
        if os.path.exists(gen_path):
            with open(gen_path) as f:
                state = json.load(f)
        else:
            state = {"gen": 0, "members": []}
        if members != state["members"]:
            state = {"gen": state["gen"] + 1, "members": members}
            tmp = gen_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(state, f)
            os.replace(tmp, gen_path)
        return state["gen"], members
