"""Preemption-safe shutdown: catch SIGTERM/SIGINT, finish the step,
checkpoint, exit cleanly. TPU pods give a grace window on maintenance
events; the trainer polls `requested()` at step boundaries."""
from __future__ import annotations

import signal
import threading


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    def requested(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:  # for tests
        self._flag.set()
