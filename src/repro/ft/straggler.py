"""Straggler detection + mitigation decisions.

At pod scale the common straggler sources are a slow host NIC, a thermally
throttled chip, or skewed work (for the eigensolver: nnz imbalance between
edge panels). The tracker keeps an EWMA of step times per participant and
flags sustained outliers; mitigation is a *decision* the launcher acts on:

  * "rebalance"  — repack edge panels / re-LPT the tile rows (eigensolver)
                   or rebalance data shards (LM training) at the next
                   restart/checkpoint boundary;
  * "evict"      — drop the participant and trigger elastic re-shard
                   (ckpt.restore onto the smaller mesh).

Detection is trace-driven and unit-testable without hardware.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class StragglerDecision:
    participant: int
    action: str          # "none" | "rebalance" | "evict"
    slowdown: float      # participant_time / median_time


class StragglerTracker:
    def __init__(self, *, ewma: float = 0.3, rebalance_at: float = 1.3,
                 evict_at: float = 2.5, min_steps: int = 5):
        self.ewma = ewma
        self.rebalance_at = rebalance_at
        self.evict_at = evict_at
        self.min_steps = min_steps
        self._t = defaultdict(float)   # participant -> ewma step time
        self._n = defaultdict(int)

    def record(self, participant: int, step_time: float) -> None:
        a = self.ewma
        if self._n[participant] == 0:
            self._t[participant] = step_time
        else:
            self._t[participant] = (1 - a) * self._t[participant] + a * step_time
        self._n[participant] += 1

    def decisions(self) -> list[StragglerDecision]:
        ready = {p: t for p, t in self._t.items()
                 if self._n[p] >= self.min_steps}
        if len(ready) < 2:
            return []
        times = sorted(ready.values())
        median = times[len(times) // 2]
        out = []
        for p, t in ready.items():
            slow = t / max(median, 1e-12)
            if slow >= self.evict_at:
                out.append(StragglerDecision(p, "evict", slow))
            elif slow >= self.rebalance_at:
                out.append(StragglerDecision(p, "rebalance", slow))
        return out
