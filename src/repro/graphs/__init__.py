"""Graph substrate: synthetic generators, tile packing, partitioning, operators."""
from repro.graphs.synth import (rmat_graph, rmat_spectral, knn_band_graph,
                                clustered_web_graph, erdos_renyi)
from repro.graphs.tiles import TiledMatrix, pack_tiles, scsr_encode_tile, scsr_decode_tile
from repro.graphs.partition import balance_tile_rows
from repro.graphs.laplacian import normalized_adjacency, laplacian, degrees

__all__ = [
    "rmat_graph", "rmat_spectral", "knn_band_graph", "clustered_web_graph",
    "erdos_renyi",
    "TiledMatrix", "pack_tiles", "scsr_encode_tile", "scsr_decode_tile",
    "balance_tile_rows", "normalized_adjacency", "laplacian", "degrees",
]
