"""Matrix-image serialization + streaming loader (the on-"SSD" format).

save_image/load_image persist a TiledMatrix as an .npz + JSON manifest —
the analogue of the paper's sparse "matrix image" created ahead of time
(§3.3.1). stream_tile_rows yields one tile-row worth of blocks at a time,
emulating the semi-external-memory streaming read pattern; it is what the
single-host out-of-core SpMM consumes, and its byte counts feed the
TieredStore I/O accounting.
"""
from __future__ import annotations

import json
import os
from typing import Iterator, Tuple

import numpy as np

from repro.graphs.tiles import TiledMatrix


def save_image(path: str, tm: TiledMatrix) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(
        os.path.join(path, "image.npz"),
        blocks=tm.blocks, block_cols=tm.block_cols, row_ptr=tm.row_ptr,
        coo_rows=tm.coo_rows, coo_cols=tm.coo_cols, coo_vals=tm.coo_vals,
    )
    manifest = {
        "shape": list(tm.shape), "block_shape": list(tm.block_shape),
        "nblocks": tm.nblocks, "nnz": tm.nnz,
        "image_bytes": tm.nbytes_image(),
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_image(path: str) -> TiledMatrix:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "image.npz"))
    return TiledMatrix(
        shape=tuple(manifest["shape"]),
        block_shape=tuple(manifest["block_shape"]),
        blocks=z["blocks"], block_cols=z["block_cols"], row_ptr=z["row_ptr"],
        coo_rows=z["coo_rows"], coo_cols=z["coo_cols"], coo_vals=z["coo_vals"],
    )


def stream_tile_rows(tm: TiledMatrix) -> Iterator[Tuple[int, np.ndarray, np.ndarray, int]]:
    """Yield (block_row, blocks, block_cols, bytes_read) per tile row —
    the sequential streaming pattern of semi-external-memory SpMM."""
    for br in range(tm.n_block_rows):
        lo, hi = int(tm.row_ptr[br]), int(tm.row_ptr[br + 1])
        blocks = tm.blocks[lo:hi]
        cols = tm.block_cols[lo:hi]
        yield br, blocks, cols, blocks.nbytes + cols.nbytes
