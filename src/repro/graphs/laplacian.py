"""Graph operators: degrees, normalized adjacency, Laplacian (COO-level)."""
from __future__ import annotations

import numpy as np


def degrees(n: int, rows: np.ndarray, cols: np.ndarray,
            vals: np.ndarray | None = None) -> np.ndarray:
    d = np.zeros(n, dtype=np.float64)
    if vals is None:
        np.add.at(d, rows, 1.0)
    else:
        np.add.at(d, rows, vals.astype(np.float64))
    return d


def normalized_adjacency(n: int, rows: np.ndarray, cols: np.ndarray,
                         vals: np.ndarray):
    """D^{-1/2} A D^{-1/2} — the spectral-clustering operator [17, 22]."""
    d = degrees(n, rows, cols, vals)
    with np.errstate(divide="ignore"):
        dinv = np.where(d > 0, 1.0 / np.sqrt(np.maximum(d, 1e-300)), 0.0)
    return rows, cols, (vals * dinv[rows] * dinv[cols]).astype(np.float32)


def laplacian(n: int, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              *, normalized: bool = False):
    """L = D - A (or I - D^{-1/2} A D^{-1/2}); returns COO including diagonal."""
    if normalized:
        r, c, v = normalized_adjacency(n, rows, cols, vals)
        v = -v
        diag = np.ones(n, dtype=np.float32)
    else:
        r, c, v = rows, cols, -vals
        diag = degrees(n, rows, cols, vals).astype(np.float32)
    dr = np.arange(n, dtype=np.int32)
    keep = diag != 0
    return (np.concatenate([r, dr[keep]]).astype(np.int32),
            np.concatenate([c, dr[keep]]).astype(np.int32),
            np.concatenate([v, diag[keep]]).astype(np.float32))
