"""Tile-row partitioning with load balancing.

The paper balances power-law skew with runtime work stealing (§3.3.3). TPUs
are SPMD, so we move the balancing to pack time: tile rows are assigned to
shards by LPT (longest-processing-time) bin packing on nnz cost, then an
optional contiguous re-chunking keeps each shard a contiguous row range
(required for row-interval sharded TAS vectors).
"""
from __future__ import annotations

import numpy as np


def tile_row_costs(row_ptr: np.ndarray, blocks_nnz: np.ndarray | None = None,
                   block_cost: float = 1.0) -> np.ndarray:
    """Cost per tile row = number of blocks (or true nnz when provided)."""
    nb = np.diff(row_ptr).astype(np.float64)
    if blocks_nnz is None:
        return nb * block_cost
    costs = np.zeros(row_ptr.shape[0] - 1, dtype=np.float64)
    for br in range(costs.shape[0]):
        costs[br] = blocks_nnz[row_ptr[br]:row_ptr[br + 1]].sum()
    return costs


def balance_tile_rows(costs: np.ndarray, n_shards: int,
                      *, contiguous: bool = True) -> np.ndarray:
    """Assign tile rows to shards.

    contiguous=True (default): optimal contiguous partition via the
      classic binary-search-on-bottleneck algorithm — each shard gets a
      contiguous run of tile rows (needed for row-interval sharding).
    contiguous=False: LPT bin packing (lower imbalance, non-contiguous;
      usable by the standalone SpMM where output rows are permuted).

    Returns assignment (n_tile_rows,) int32 of shard ids.
    """
    n = costs.shape[0]
    if n_shards <= 1 or n == 0:
        return np.zeros(n, dtype=np.int32)
    if not contiguous:
        order = np.argsort(-costs)
        load = np.zeros(n_shards)
        assign = np.zeros(n, dtype=np.int32)
        for i in order:
            s = int(np.argmin(load))
            assign[i] = s
            load[s] += costs[i]
        return assign

    # binary search the bottleneck for contiguous partition
    prefix = np.concatenate([[0.0], np.cumsum(costs)])
    lo, hi = float(costs.max(initial=0.0)), float(prefix[-1])

    def n_parts_needed(cap: float) -> int:
        parts, start = 0, 0
        while start < n:
            end = int(np.searchsorted(prefix, prefix[start] + cap, side="right")) - 1
            end = max(end, start + 1)
            parts += 1
            start = end
        return parts

    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if n_parts_needed(mid) <= n_shards:
            hi = mid
        else:
            lo = mid
    cap = hi
    assign = np.zeros(n, dtype=np.int32)
    start, shard = 0, 0
    while start < n:
        end = int(np.searchsorted(prefix, prefix[start] + cap, side="right")) - 1
        end = max(end, start + 1)
        # reserve ≥1 row for each remaining shard (when rows suffice)
        reserve = min(n_shards - shard - 1, n - start - 1)
        end = min(end, n - reserve)
        end = max(end, start + 1)
        assign[start:end] = min(shard, n_shards - 1)
        start, shard = end, shard + 1
    return assign


def imbalance(costs: np.ndarray, assign: np.ndarray, n_shards: int) -> float:
    """max_load / mean_load — 1.0 is perfect."""
    loads = np.zeros(n_shards)
    np.add.at(loads, assign, costs)
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0
