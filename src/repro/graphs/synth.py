"""Synthetic graph generators mirroring the paper's evaluation datasets (Table 2).

All generators return COO arrays (rows, cols, vals) with deduplicated edges,
as numpy arrays. They are deliberately numpy-side: graph construction is the
"dataset" part of the system, the JAX side consumes packed tile images.

  - rmat_graph:          power-law social graphs (Twitter / Friendster analogue)
  - knn_band_graph:      near-banded KNN distance graph (Babel Tagalog analogue;
                         degree concentrated in 100..1000, NOT power law)
  - clustered_web_graph: domain-clustered page graph analogue (good locality)
  - erdos_renyi:         uniform random control
"""
from __future__ import annotations

import numpy as np


def _dedup(rows: np.ndarray, cols: np.ndarray, n: int,
           vals: np.ndarray | None = None):
    """Deduplicate COO entries; keep first value for duplicates."""
    key = rows.astype(np.int64) * n + cols.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    rows, cols = rows[idx], cols[idx]
    if vals is None:
        vals = np.ones(rows.shape[0], dtype=np.float32)
    else:
        vals = vals[idx]
    return rows.astype(np.int32), cols.astype(np.int32), vals.astype(np.float32)


def rmat_graph(n: int, nnz: int, *, seed: int = 0, symmetric: bool = False,
               a: float = 0.57, b: float = 0.19, c: float = 0.19):
    """R-MAT power-law graph (Twitter/Friendster stand-in).

    n must be a power of two is NOT required; we generate in the next pow2
    space and reject out-of-range vertices.
    """
    rng = np.random.default_rng(seed)
    levels = int(np.ceil(np.log2(max(n, 2))))
    # oversample to survive rejection + dedup
    m = int(nnz * 1.5) + 16
    rows = np.zeros(m, dtype=np.int64)
    cols = np.zeros(m, dtype=np.int64)
    pa, pb, pc = a, a + b, a + b + c
    for _ in range(levels):
        r = rng.random(m)
        quad_b = (r >= pa) & (r < pb)
        quad_c = (r >= pb) & (r < pc)
        quad_d = r >= pc
        rows = rows * 2 + (quad_c | quad_d)
        cols = cols * 2 + (quad_b | quad_d)
    ok = (rows < n) & (cols < n) & (rows != cols)
    rows, cols = rows[ok][:nnz], cols[ok][:nnz]
    if symmetric:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
    return _dedup(rows, cols, n)


def knn_band_graph(n: int, k: int = 8, *, bandwidth: int | None = None,
                   seed: int = 0):
    """Symmetrized KNN graph with near-banded structure and cosine-ish weights.

    Matches the paper's KNN distance graph: most degrees in a narrow range,
    no power law, weighted edges.
    """
    rng = np.random.default_rng(seed)
    bw = bandwidth if bandwidth is not None else max(4 * k, 16)
    rows = np.repeat(np.arange(n, dtype=np.int64), k)
    offs = rng.integers(1, bw + 1, size=n * k) * rng.choice([-1, 1], size=n * k)
    cols = np.clip(rows + offs, 0, n - 1)
    ok = rows != cols
    rows, cols = rows[ok], cols[ok]
    # symmetrize
    rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
    vals = (0.5 + 0.5 * rng.random(rows.shape[0])).astype(np.float32)
    r, c, v = _dedup(rows, cols, n, vals)
    # make weights symmetric: w(i,j) = w(j,i) by averaging with transpose
    key = r.astype(np.int64) * n + c.astype(np.int64)
    tkey = c.astype(np.int64) * n + r.astype(np.int64)
    order, torder = np.argsort(key), np.argsort(tkey)
    v_sym = np.empty_like(v)
    v_sym[order] = 0.5 * (v[order] + v[torder])
    return r, c, v_sym


def clustered_web_graph(n: int, nnz: int, *, n_domains: int = 64, seed: int = 0,
                        p_intra: float = 0.9):
    """Directed page graph analogue: vertices clustered by domain; most edges
    stay within a domain (the paper notes this gives good cache hit rates)."""
    rng = np.random.default_rng(seed)
    dom = np.sort(rng.integers(0, n_domains, size=n))  # clustered vertex ids
    dom_start = np.searchsorted(dom, np.arange(n_domains))
    dom_end = np.searchsorted(dom, np.arange(n_domains), side="right")
    rows = rng.integers(0, n, size=int(nnz * 1.3))
    intra = rng.random(rows.shape[0]) < p_intra
    d = dom[rows]
    lo, hi = dom_start[d], np.maximum(dom_end[d], dom_start[d] + 1)
    intra_cols = lo + (rng.random(rows.shape[0]) * (hi - lo)).astype(np.int64)
    inter_cols = rng.integers(0, n, size=rows.shape[0])
    cols = np.where(intra, intra_cols, inter_cols)
    ok = rows != cols
    rows, cols = rows[ok][:nnz], cols[ok][:nnz]
    return _dedup(rows, cols, n)


def erdos_renyi(n: int, nnz: int, *, seed: int = 0, symmetric: bool = True):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, size=int(nnz * 1.2))
    cols = rng.integers(0, n, size=int(nnz * 1.2))
    ok = rows != cols
    rows, cols = rows[ok][:nnz], cols[ok][:nnz]
    if symmetric:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
    return _dedup(rows, cols, n)


def rmat_spectral(n: int, nnz: int, *, seed: int = 0):
    """Symmetric normalized-adjacency R-MAT graph — the standard input of
    the end-to-end eigensolver drivers (examples/dist_eigen_e2e.py,
    benchmarks/bench_dist_e2e.py, the dist-vs-core parity tests). One
    shared constructor so every driver factorizes the *same* operator for
    a given (n, nnz, seed) and spectra are directly comparable."""
    from repro.graphs.laplacian import normalized_adjacency
    r, c, v = rmat_graph(n, nnz, seed=seed, symmetric=True)
    return normalized_adjacency(n, r, c, v)


def to_dense(n: int, rows, cols, vals) -> np.ndarray:
    d = np.zeros((n, n), dtype=np.float32)
    d[rows, cols] = vals
    return d
