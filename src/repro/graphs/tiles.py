"""Sparse-matrix tile formats.

Two layers, per DESIGN.md §2:

1. A *faithful* SCSR+COO byte codec (`scsr_encode_tile`/`scsr_decode_tile`)
   reproducing the paper's §3.3.1 format exactly: 2-byte entries, the MSB of
   a row-header set to 1 and of a column index set to 0, single-entry rows
   stored as COO pairs behind the SCSR row headers, max tile 32K×32K. This
   codec is the storage/wire format (what lives on "SSD") and the fidelity
   oracle; it is exercised by tests and the format benchmark.

2. The TPU-native compute format (`pack_tiles` → `TiledMatrix`): the paper's
   cache-blocking insight adapted to the MXU. Non-empty (bm×bn) blocks are
   materialized densely (bm,bn multiples of 8,128 for real TPU; arbitrary for
   tests), indexed by a CSR-over-block-rows "matrix index" (§3.3.1's tile-row
   index), which is scalar-prefetched by the Pallas SpMM kernel. Rows too
   sparse to justify a dense block go to a COO side-path (the paper's COO
   hybrid) consumed by a gather/segment-sum JAX kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

MAX_TILE = 32768  # 2-byte indices with MSB tag → max 32K×32K (paper §3.3.1)


# ---------------------------------------------------------------------------
# 1. Faithful SCSR + COO byte codec (paper fidelity layer)
# ---------------------------------------------------------------------------

def scsr_encode_tile(rows: np.ndarray, cols: np.ndarray,
                     tile_shape: Tuple[int, int]) -> bytes:
    """Encode one tile's COO entries (tile-local indices) into the paper's
    hybrid SCSR+COO byte format.

    Layout:  [SCSR section: for each multi-entry row, a row header
              (0x8000 | row) followed by its column indices (MSB=0)]
             [COO section: (row, col) pairs for single-entry rows]
             [footer: uint32 n_scsr_entries, uint32 n_coo_pairs]
    All index entries are uint16 little-endian.
    """
    tm, tn = tile_shape
    if tm > MAX_TILE or tn > MAX_TILE:
        raise ValueError(f"tile {tile_shape} exceeds SCSR max {MAX_TILE}")
    if rows.size == 0:
        return np.array([0, 0], dtype=np.uint32).tobytes()
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    urows, counts = np.unique(rows, return_counts=True)
    multi = set(urows[counts > 1].tolist())
    scsr: list[int] = []
    coo: list[int] = []
    i = 0
    while i < rows.size:
        r = int(rows[i])
        j = i
        while j < rows.size and rows[j] == r:
            j += 1
        if r in multi:
            scsr.append(0x8000 | r)          # row header, MSB=1
            scsr.extend(int(c) for c in cols[i:j])  # column entries, MSB=0
        else:
            coo.append(r)                     # single-entry rows → COO pairs
            coo.append(int(cols[i]))
        i = j
    body = np.array(scsr + coo, dtype=np.uint16).tobytes()
    footer = np.array([len(scsr), len(coo) // 2], dtype=np.uint32).tobytes()
    return body + footer


def scsr_decode_tile(buf: bytes) -> Tuple[np.ndarray, np.ndarray]:
    """Decode the hybrid format back to tile-local COO (rows, cols)."""
    n_scsr, n_coo = np.frombuffer(buf[-8:], dtype=np.uint32)
    body = np.frombuffer(buf[:-8], dtype=np.uint16)
    scsr, coo = body[:n_scsr], body[n_scsr:n_scsr + 2 * n_coo]
    rows: list[int] = []
    cols: list[int] = []
    cur = -1
    for e in scsr:
        if e & 0x8000:
            cur = int(e & 0x7FFF)
        else:
            rows.append(cur)
            cols.append(int(e))
    r = np.array(rows + coo[0::2].tolist(), dtype=np.int32)
    c = np.array(cols + coo[1::2].tolist(), dtype=np.int32)
    return r, c


def scsr_tile_nbytes(rows: np.ndarray) -> int:
    """Storage bytes of the hybrid format for a tile (excluding values),
    used by the format-size benchmark (paper: SCSR+COO vs CSR)."""
    if rows.size == 0:
        return 8
    _, counts = np.unique(rows, return_counts=True)
    multi_rows = int((counts > 1).sum())
    multi_entries = int(counts[counts > 1].sum())
    single = int((counts == 1).sum())
    return 2 * (multi_rows + multi_entries + 2 * single) + 8


# ---------------------------------------------------------------------------
# 2. TPU block-sparse compute format
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TiledMatrix:
    """Block-sparse matrix image (the TPU adaptation of the §3.3.1 format).

    blocks     (nblocks, bm, bn) float32/bf16 — dense non-empty blocks in
               block-row-major order (the streamed operand).
    block_cols (nblocks,) int32 — block-column index per block.
    row_ptr    (n_block_rows+1,) int32 — CSR over block rows ("matrix index",
               kept in fast memory per §3.3.1).
    coo_*      unstructured remainder handled by the segment-sum path.
    """
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]
    blocks: np.ndarray
    block_cols: np.ndarray
    row_ptr: np.ndarray
    coo_rows: np.ndarray
    coo_cols: np.ndarray
    coo_vals: np.ndarray

    @property
    def nblocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def n_block_rows(self) -> int:
        return int(self.row_ptr.shape[0] - 1)

    @property
    def nnz(self) -> int:
        return int((self.blocks != 0).sum()) + int(self.coo_vals.shape[0])

    def nbytes_image(self) -> int:
        """Bytes of the on-'SSD' matrix image (what SpMM streams)."""
        return (self.blocks.nbytes + self.block_cols.nbytes
                + self.coo_rows.nbytes + self.coo_cols.nbytes
                + self.coo_vals.nbytes)

    def chunk_block_rows(self, target_bytes: int
                         ) -> list[Tuple[int, int, int, int]]:
        """Split the image into contiguous block-row spans of dense blocks
        totalling ~target_bytes each: `[(br_lo, br_hi, blk_lo, blk_hi)]`
        with blocks[blk_lo:blk_hi] exactly the blocks of block rows
        [br_lo, br_hi). This is the unit the SSD-streamed SpMM reads per
        request (the paper's §3.3.3 sequential scan, page-store edition):
        each span becomes one page-store entry, loaded as coalesced
        vectored runs and prefetched one span ahead of the contraction.
        Never splits inside a block row, so per-span SpMM needs only a
        rebased row index. Returns [] for an image with no block rows.
        """
        if self.n_block_rows == 0:
            return []
        bm, bn = self.block_shape
        per_block = bm * bn * self.blocks.itemsize if self.nblocks else 0
        spans: list[Tuple[int, int, int, int]] = []
        br_lo, cur = 0, 0
        for br in range(self.n_block_rows):
            b = int(self.row_ptr[br + 1] - self.row_ptr[br]) * per_block
            if cur and cur + b > target_bytes:
                spans.append((br_lo, br, int(self.row_ptr[br_lo]),
                              int(self.row_ptr[br])))
                br_lo, cur = br, 0
            cur += b
        spans.append((br_lo, self.n_block_rows, int(self.row_ptr[br_lo]),
                      int(self.row_ptr[-1])))
        return spans

    def to_dense(self) -> np.ndarray:
        n, m = self.shape
        bm, bn = self.block_shape
        out = np.zeros((n, m), dtype=np.float32)
        for br in range(self.n_block_rows):
            for k in range(self.row_ptr[br], self.row_ptr[br + 1]):
                bc = int(self.block_cols[k])
                r0, c0 = br * bm, bc * bn
                out[r0:r0 + bm, c0:c0 + bn] += self.blocks[k]
        if self.coo_rows.size:
            np.add.at(out, (self.coo_rows, self.coo_cols), self.coo_vals)
        return out


def pack_tiles(n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray,
               vals: np.ndarray, *, block_shape: Tuple[int, int] = (128, 128),
               min_block_nnz: int = 1) -> TiledMatrix:
    """COO → block-sparse image.

    Blocks with >= min_block_nnz entries become dense blocks (MXU path);
    sparser blocks' entries fall through to the COO side-path — the hybrid
    of §3.3.1 re-targeted at the TPU's compute granularity. Dimensions are
    padded up to block multiples (padding rows/cols are zero and harmless:
    SpMM output is sliced back).
    """
    bm, bn = block_shape
    n_pad = -(-n_rows // bm) * bm
    m_pad = -(-n_cols // bn) * bn
    nbr, nbc = n_pad // bm, m_pad // bn

    br = rows // bm
    bc = cols // bn
    key = br.astype(np.int64) * nbc + bc.astype(np.int64)
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    ukey, start, counts = np.unique(key, return_index=True, return_counts=True)

    dense_mask_per_entry = np.repeat(counts >= min_block_nnz, counts)
    d_rows, d_cols, d_vals = (rows[dense_mask_per_entry],
                              cols[dense_mask_per_entry],
                              vals[dense_mask_per_entry])
    s_rows, s_cols, s_vals = (rows[~dense_mask_per_entry],
                              cols[~dense_mask_per_entry],
                              vals[~dense_mask_per_entry])

    dense_keys = ukey[counts >= min_block_nnz]
    nblocks = dense_keys.shape[0]
    blocks = np.zeros((max(nblocks, 1), bm, bn), dtype=np.float32)
    block_cols = np.zeros(max(nblocks, 1), dtype=np.int32)
    row_ptr = np.zeros(nbr + 1, dtype=np.int32)

    if nblocks:
        blk_of_entry = np.searchsorted(dense_keys, key[dense_mask_per_entry])
        blocks[blk_of_entry, d_rows % bm, d_cols % bn] = d_vals
        block_row_of = (dense_keys // nbc).astype(np.int32)
        block_cols[:nblocks] = (dense_keys % nbc).astype(np.int32)
        np.add.at(row_ptr, block_row_of + 1, 1)
        row_ptr = np.cumsum(row_ptr).astype(np.int32)
    if nblocks == 0:
        blocks = blocks[:0]
        block_cols = block_cols[:0]

    return TiledMatrix(
        shape=(n_pad, m_pad), block_shape=(bm, bn),
        blocks=blocks, block_cols=block_cols, row_ptr=row_ptr,
        coo_rows=s_rows.astype(np.int32), coo_cols=s_cols.astype(np.int32),
        coo_vals=s_vals.astype(np.float32),
    )


def csr_nbytes(rows: np.ndarray, n_rows: int, idx_bytes: int = 8) -> int:
    """Plain CSR storage (indices only) for the format-size comparison."""
    return idx_bytes * (rows.size + n_rows + 1)
