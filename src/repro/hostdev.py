"""Pre-jax bootstrap shared by the end-to-end drivers.

This module must stay free of jax (and jax-importing repro modules): its
one job is to set XLA_FLAGS before the jax backends initialize, and the
drivers (examples/dist_eigen_e2e.py, benchmarks/bench_dist_e2e.py) import
it before anything else touches jax.
"""
from __future__ import annotations

import os


def force_host_devices(n: int = 8) -> None:
    """Force a multi-device host platform before jax initializes.

    Honors an explicit XLA_FLAGS already carrying a device-count pin, and
    falls back to the scripts/run_tier1.sh subprocess pin
    (DIST_SUBPROCESS_XLA_FLAGS) so the tier-1 smoke runs and the manual
    drivers agree on the mesh.
    """
    flags = os.environ.get("XLA_FLAGS",
                           os.environ.get("DIST_SUBPROCESS_XLA_FLAGS", ""))
    if "xla_force_host_platform_device_count" not in flags:
        flags = f"{flags} --xla_force_host_platform_device_count={n}".strip()
    os.environ["XLA_FLAGS"] = flags
