"""Pallas TPU kernel: flash attention (online softmax), one head.

The §Roofline baseline's dominant memory term for prefill cells is the
(S×S) score traffic of unfused attention. This kernel never materializes
scores beyond a (bq × bk) VMEM tile: the classic running-max/denominator
recurrence (Rabe-Staats / FlashAttention), with the kv dimension as the
sequential ('arbitrary') grid axis and VMEM scratch carrying the state.

HBM traffic drops from O(S²) to O(S·d + S²/vmem-resident-tiles) — for
llama-vision prefill_32k this removes ~60 % of the memory term (the
projected §Perf endgame; the kernel is TPU-target, validated here in
interpret mode, while the portable q-chunked scan remains the default).
Heads/batch map via vmap in ops.flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, sm_scale: float, block_q: int,
                  block_k: int, n_kv_blocks: int):
    qi = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # whole kv block strictly in the future → skip work (masking keeps
        # correctness; pl.when keeps the flops/bytes off the hot path)
        run = qi * block_q + block_q - 1 >= kj * block_k

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
                + qi * block_q
            cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) \
                + kj * block_k
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_single(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """One head: q (Sq, d), k/v (Sk, d) → (Sq, d)."""
    sq, d = q.shape
    sk = k.shape[0]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    sm_scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, n_kv_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """Batched heads: q (B, H, Sq, d), k/v (B, H, Sk, d)."""
    fn = functools.partial(flash_attention_single, causal=causal,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
    return jax.vmap(jax.vmap(fn))(q, k, v)
