"""Pure-jnp oracle for flash attention: plain softmax attention, one head.

q (Sq, d), k/v (Sk, d) → (Sq, d); causal masks by absolute position with
q_offset (q block's global start) so chunked callers agree with the kernel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, q_offset: int = 0,
                  sm_scale: float | None = None) -> jnp.ndarray:
    d = q.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    s = (q @ k.T) * scale
    if causal:
        qi = jnp.arange(q.shape[0])[:, None] + q_offset
        ki = jnp.arange(k.shape[0])[None, :]
        s = jnp.where(qi >= ki, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v
