"""Pallas TPU kernel: Gram / projection  G = alpha * A^T @ B.

Anasazi's MvTransMv (Table 1, op3) — the reorthogonalization hot spot (the
paper: >90% of runtime when computing many eigenvalues). Both TAS operands
stream through VMEM one row interval per grid step; the (m×b) result tile is
grid-accumulated in VMEM and flushed once — the paper's two-phase
"per-row-interval partial + aggregate" parallelization (§3.4.2) collapses
into the revisited-output accumulation on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a_ref, b_ref, alpha_ref, out_ref):
    i = pl.program_id(0)
    acc = jnp.dot(a_ref[...].T, b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = alpha_ref[0] * acc

    @pl.when(i != 0)
    def _accum():
        out_ref[...] += alpha_ref[0] * acc


@functools.partial(jax.jit, static_argnames=("row_interval", "interpret"))
def gram(a: jnp.ndarray, b: jnp.ndarray, alpha: float | jnp.ndarray = 1.0,
         *, row_interval: int = 512, interpret: bool = False) -> jnp.ndarray:
    """G = alpha * A^T @ B with A:(n,m), B:(n,b); n % row_interval == 0."""
    n, m = a.shape
    bcols = b.shape[1]
    assert n % row_interval == 0, (n, row_interval)
    grid = (n // row_interval,)
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_interval, m), lambda i: (i, 0)),
            pl.BlockSpec((row_interval, bcols), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((m, bcols), lambda i: (0, 0)),
    )
    return pl.pallas_call(
        _gram_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, bcols), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="gram",
    )(a, b, alpha)
