"""Pure-jnp oracle for the Gram / projection op (Anasazi MvTransMv):

    G <- alpha * A^T @ B

A: (n, m) TAS, B: (n, b) TAS → G: (m, b) small (fits in fast memory).
"""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(a: jnp.ndarray, b: jnp.ndarray, *, alpha: float = 1.0) -> jnp.ndarray:
    return alpha * jnp.dot(a.T, b, preferred_element_type=jnp.float32)
