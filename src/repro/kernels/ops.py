"""jit'd public wrappers around the Pallas kernels, with CPU fallbacks.

Each op dispatches to the Pallas kernel on TPU (or in interpret mode when
forced) and to the pure-jnp oracle otherwise, so the rest of the framework
calls one function everywhere. `use_pallas()` picks the default from the
backend; tests override via the explicit `impl=` argument.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.tiles import TiledMatrix
from repro.kernels import spmm_ref as _spmm_ref
from repro.kernels import tsgemm_ref as _tsgemm_ref
from repro.kernels import gram_ref as _gram_ref
from repro.kernels.spmm_tile import spmm_blocksparse
from repro.kernels.tsgemm import tsgemm as _tsgemm_pallas
from repro.kernels.gram import gram as _gram_pallas

Impl = Literal["auto", "pallas", "interpret", "ref"]


def use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Impl) -> str:
    if impl == "auto":
        return "pallas" if use_pallas() else "ref"
    return impl


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------

def block_rows_from_ptr(row_ptr: np.ndarray) -> np.ndarray:
    """Flatten the CSR row_ptr into per-block block-row ids."""
    return np.repeat(np.arange(row_ptr.shape[0] - 1, dtype=np.int32),
                     np.diff(row_ptr))


def empty_row_mask(row_ptr: np.ndarray, bm: int) -> np.ndarray:
    """Boolean (n_rows,) mask — True where the block row has any blocks."""
    return np.repeat(np.diff(row_ptr) > 0, bm)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "impl"))
def spmm_blocks(blocks, block_cols, block_rows, row_mask, x,
                *, n_block_rows: int, impl: Impl = "auto"):
    """Block-sparse part of SpMM. row_mask zeroes never-visited output rows."""
    mode = _resolve(impl)
    if mode == "ref":
        y = _spmm_ref.spmm_ref(blocks, block_cols, block_rows, n_block_rows, x)
    else:
        y = spmm_blocksparse(blocks, block_cols, block_rows, x,
                             n_block_rows=n_block_rows,
                             interpret=(mode == "interpret"))
        y = jnp.where(row_mask[:, None], y, 0.0)
    return y


def spmm(tm: TiledMatrix, x: jnp.ndarray, *, impl: Impl = "auto") -> jnp.ndarray:
    """Full SpMM: block-sparse path + COO side-path. Host-side convenience
    (device arrays are created per call — the performance path keeps arrays
    resident and calls spmm_blocks/coo parts directly)."""
    brs = jnp.asarray(block_rows_from_ptr(np.asarray(tm.row_ptr)))
    mask = jnp.asarray(empty_row_mask(np.asarray(tm.row_ptr), tm.block_shape[0]))
    y = spmm_blocks(jnp.asarray(tm.blocks), jnp.asarray(tm.block_cols), brs,
                    mask, x, n_block_rows=tm.n_block_rows, impl=impl)
    if tm.coo_vals.size:
        y = y + _spmm_ref.coo_spmm_ref(jnp.asarray(tm.coo_rows),
                                       jnp.asarray(tm.coo_cols),
                                       jnp.asarray(tm.coo_vals), x, tm.shape[0])
    return y


# ---------------------------------------------------------------------------
# TAS dense ops
# ---------------------------------------------------------------------------

def _pick_row_interval(n: int, cap: int = 512) -> int:
    """Largest divisor of n that is <= cap (row intervals must tile n)."""
    for cand in range(min(cap, n), 0, -1):
        if n % cand == 0:
            return cand
    return n


def tsgemm(a, b, *, alpha=1.0, beta=0.0, c0=None, impl: Impl = "auto",
           row_interval: int | None = None):
    """C = alpha*A@B + beta*C0 (MvTimesMatAddMv)."""
    mode = _resolve(impl)
    if mode == "ref":
        return _tsgemm_ref.tsgemm_ref(a, b, alpha=alpha, beta=beta, c0=c0)
    n = a.shape[0]
    ri = row_interval or _pick_row_interval(n)
    if c0 is None:
        c0 = jnp.zeros((n, b.shape[1]), jnp.float32)
        beta = 0.0
    return _tsgemm_pallas(a, b, c0, alpha, beta, row_interval=ri,
                          interpret=(mode == "interpret"))


def gram(a, b, *, alpha=1.0, impl: Impl = "auto",
         row_interval: int | None = None):
    """G = alpha*A^T@B (MvTransMv)."""
    mode = _resolve(impl)
    if mode == "ref":
        return _gram_ref.gram_ref(a, b, alpha=alpha)
    ri = row_interval or _pick_row_interval(a.shape[0])
    return _gram_pallas(a, b, alpha, row_interval=ri,
                        interpret=(mode == "interpret"))
