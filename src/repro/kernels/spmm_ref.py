"""Pure-jnp oracle for block-sparse SpMM: Y = A @ X (+ beta*Y0).

A is a TiledMatrix-style block-sparse image. The oracle mirrors the kernel's
math exactly (block gather → dense dot → scatter-add) in plain jnp so it runs
anywhere and serves as the allclose reference for the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax


def spmm_ref(blocks: jnp.ndarray, block_cols: jnp.ndarray,
             block_rows: jnp.ndarray, n_block_rows: int,
             x: jnp.ndarray, *, beta: float = 0.0,
             y0: jnp.ndarray | None = None) -> jnp.ndarray:
    """Block-sparse SpMM oracle.

    blocks:     (nb, bm, bn)
    block_cols: (nb,) int32  — block-column per block
    block_rows: (nb,) int32  — block-row per block (flattened CSR)
    x:          (n_cols_padded, k)
    returns     (n_block_rows*bm, k)
    """
    nb, bm, bn = blocks.shape
    k = x.shape[1]
    xb = x.reshape(-1, bn, k)                      # (n_block_cols, bn, k)
    gathered = xb[block_cols]                      # (nb, bn, k)
    partial = jnp.einsum("bij,bjk->bik", blocks, gathered,
                         preferred_element_type=jnp.float32)  # (nb, bm, k)
    out = jnp.zeros((n_block_rows, bm, k), dtype=jnp.float32)
    out = out.at[block_rows].add(partial)
    y = out.reshape(n_block_rows * bm, k)
    if y0 is not None:
        y = y + beta * y0.astype(jnp.float32)
    return y


def coo_spmm_ref(coo_rows: jnp.ndarray, coo_cols: jnp.ndarray,
                 coo_vals: jnp.ndarray, x: jnp.ndarray,
                 n_rows: int) -> jnp.ndarray:
    """COO side-path oracle (single-entry-row remainder): segment-sum."""
    contrib = coo_vals[:, None] * x[coo_cols]      # (nnz, k)
    out = jnp.zeros((n_rows, x.shape[1]), dtype=jnp.float32)
    return out.at[coo_rows].add(contrib)


def spmm_dense_ref(a_dense: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """End-to-end dense oracle for whole-matrix comparisons."""
    return jnp.dot(a_dense, x, preferred_element_type=jnp.float32)
