"""Pallas TPU kernel: block-sparse SpMM  Y = A @ X.

TPU adaptation of the paper's §3.3 semi-external-memory SpMM. The sparse
matrix is a stream of dense (bm×bn) blocks living in slow memory (HBM — the
"SSD" of the chip-level hierarchy); the Pallas grid walks the block stream in
block-row-major order ("tile rows"), double-buffering block fetches into VMEM
(BlockSpec pipelining == the paper's async I/O + buffer pool), while the
dense TAS operand X is gathered per block via a *scalar-prefetched* block
index — the in-memory "matrix index" of §3.3.1.

Accumulation uses the revisiting-output trick: blocks of one block row are
contiguous in the stream, so the output tile stays resident in VMEM across
the whole row and is flushed exactly once (minimizing writes to slow memory —
the DWPD discipline, §3.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(rows_ref, cols_ref, a_ref, x_ref, y_ref):
    """One grid step: multiply one sparse block with its X block.

    rows_ref/cols_ref: scalar-prefetch (nb,) int32 — block row/col ids.
    a_ref: (1, bm, bn) VMEM — the streamed sparse block.
    x_ref: (bn, k)     VMEM — gathered rows of X for this block column.
    y_ref: (bm, k)     VMEM f32 — output tile, revisited across the row.
    """
    i = pl.program_id(0)
    prev = rows_ref[jnp.maximum(i - 1, 0)]
    is_first = jnp.logical_or(i == 0, rows_ref[i] != prev)

    acc = jnp.dot(a_ref[0], x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(is_first)
    def _init():
        y_ref[...] = acc

    @pl.when(jnp.logical_not(is_first))
    def _accum():
        y_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("n_block_rows", "interpret"))
def spmm_blocksparse(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                     block_rows: jnp.ndarray, x: jnp.ndarray,
                     *, n_block_rows: int, interpret: bool = False
                     ) -> jnp.ndarray:
    """Y = A @ X for a block-sparse A.

    blocks:     (nb, bm, bn)  — dense non-empty blocks, block-row-major.
    block_cols: (nb,) int32
    block_rows: (nb,) int32   — must be non-decreasing.
    x:          (n_block_cols*bn, k)
    returns     (n_block_rows*bm, k) float32. Output rows of *empty* block
    rows are garbage — callers mask them (see ops.empty_row_mask).
    """
    nb, bm, bn = blocks.shape
    k = x.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((bn, k), lambda i, rows, cols: (cols[i], 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, rows, cols: (rows[i], 0)),
    )
    return pl.pallas_call(
        _spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows * bm, k), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="spmm_blocksparse",
    )(block_rows, block_cols, blocks, x)
