"""Pallas TPU kernel: tall-skinny GEMM  C = alpha*A@B + beta*C0.

This is Anasazi's MvTimesMatAddMv (Table 1, op1) — the subspace-update GEMM.
The TAS operand A streams through VMEM one row interval (tm rows) per grid
step (the paper's §3.4.3 row-interval streaming); the small B matrix stays
VMEM-resident across the whole grid (the paper keeps it in RAM). The row
interval is the unit of parallelism and of I/O, exactly as in §3.4.2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tsgemm_kernel(a_ref, b_ref, c0_ref, alpha_ref, beta_ref, out_ref):
    alpha = alpha_ref[0]
    beta = beta_ref[0]
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = alpha * acc + beta * c0_ref[...].astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("row_interval", "interpret"))
def tsgemm(a: jnp.ndarray, b: jnp.ndarray, c0: jnp.ndarray,
           alpha: float | jnp.ndarray = 1.0, beta: float | jnp.ndarray = 0.0,
           *, row_interval: int = 512, interpret: bool = False) -> jnp.ndarray:
    """C = alpha*A@B + beta*C0 with A:(n,m), B:(m,b), C0:(n,b); n % row_interval == 0."""
    n, m = a.shape
    bcols = b.shape[1]
    assert n % row_interval == 0, (n, row_interval)
    grid = (n // row_interval,)
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    beta = jnp.asarray(beta, jnp.float32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_interval, m), lambda i: (i, 0)),
            pl.BlockSpec((m, bcols), lambda i: (0, 0)),
            pl.BlockSpec((row_interval, bcols), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((row_interval, bcols), lambda i: (i, 0)),
    )
    return pl.pallas_call(
        _tsgemm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, bcols), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="tsgemm",
    )(a, b, c0, alpha, beta)
