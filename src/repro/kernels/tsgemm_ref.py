"""Pure-jnp oracle for the tall-skinny GEMM (Anasazi MvTimesMatAddMv):

    C <- alpha * A @ B + beta * C0

A: (n, m) tall-and-skinny, B: (m, b) small, C: (n, b).
"""
from __future__ import annotations

import jax.numpy as jnp


def tsgemm_ref(a: jnp.ndarray, b: jnp.ndarray, *, alpha: float = 1.0,
               beta: float = 0.0, c0: jnp.ndarray | None = None) -> jnp.ndarray:
    out = alpha * jnp.dot(a, b, preferred_element_type=jnp.float32)
    if c0 is not None and beta != 0.0:
        out = out + beta * c0.astype(jnp.float32)
    return out
