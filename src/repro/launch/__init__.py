"""repro.launch"""
