import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("DRYRUN_XLA_EXTRA", ""))

# ^ MUST precede any jax-importing import: jax locks the device count at
# first init. The 512 placeholder host devices exist ONLY for this dry-run
# entry point (16×16 single pod / 2×16×16 multi-pod production meshes).
"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against the production mesh and record memory/cost/collective
analysis for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --arch flasheigen --graph page

Results append to a JSONL cache; existing (arch, shape, mesh) cells are
skipped, so the sweep is restartable (fault-tolerant by the same discipline
we preach).
"""
import argparse
import functools
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models import steps as S
from repro.models import transformer as tf
from repro.optim import adamw
from repro.utils.hlo_analysis import collective_bytes

# TPU v5e per-chip constants (DESIGN.md §8)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link


# ---------------------------------------------------------------- helpers
def n_row_devices(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a != "model"]))


def microbatch_policy(cfg, shape, mesh) -> int:
    """Smallest microbatch count whose activation + logits footprint fits a
    ~6 GB per-device budget (v5e leaves ~9 GB after params+opt)."""
    rows = n_row_devices(mesh)
    if shape.global_batch % rows:
        return 1
    b_loc = shape.global_batch // rows
    budget = 6e9
    s, d, v, l = shape.seq_len, cfg.d_model, cfg.vocab_size, cfg.n_layers
    for mb in [m for m in (1, 2, 4, 8, 16, 32) if b_loc % m == 0]:
        per = b_loc // mb
        act = l * per * s * d * 2          # saved layer inputs (bf16)
        logits = per * s * v * 4           # f32 CE materialization
        if act + logits <= budget:
            return mb
    return b_loc


# §Perf hillclimb variants (EXPERIMENTS.md §Perf): baseline = all off.
VARIANTS = {
    "opt-decode": {"moe_decode_regroup": True, "shard_cache_seq": True},
    "opt-prefill": {"prefill_last_only": True,
                    "bf16_residual": True},
    "opt-cache-seq": {"shard_cache_seq": True},
    "opt-moe-regroup": {"moe_decode_regroup": True},
    "opt-eigen": {"compressed": True},          # flasheigen cells only
    # inference params need no ZeRO/FSDP spreading: model-shard only, so no
    # per-layer weight all-gathers (pay ~11 GB/dev resident for 90B bf16)
    "opt-prefill-nofsdp": {"prefill_last_only": True, "bf16_residual": True,
                           "use_fsdp": False},
}


def _cfg_with(arch: str, variant: str | None):
    import dataclasses as dc
    cfg = configs.get(arch)
    if variant:
        ov = {k: v for k, v in VARIANTS[variant].items()
              if k != "compressed"}
        cfg = dc.replace(cfg, **ov)
    return cfg


def lm_cell(arch: str, shape_name: str, mesh, variant: str | None = None):
    """Build (jitted_fn, arg_specs) for one LM cell."""
    cfg = _cfg_with(arch, variant)
    shape = SHAPES[shape_name]
    rows = n_row_devices(mesh)

    params_opt = jax.eval_shape(
        functools.partial(S.init_all, jax.random.PRNGKey(0), cfg))
    params_sds, opt_sds = params_opt
    pspec = shd.param_specs(params_sds, cfg, mesh)
    pshard = shd.to_named(pspec, mesh)

    def opt_shard_leaf(spec, leaf):
        return NamedSharding(mesh, adamw.shard_opt_spec(spec, leaf.shape,
                                                        mesh))
    oshard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree_util.tree_map(opt_shard_leaf, pspec, params_sds,
                                 is_leaf=lambda x: isinstance(x, P)),
        v=jax.tree_util.tree_map(opt_shard_leaf, pspec, params_sds,
                                 is_leaf=lambda x: isinstance(x, P)))

    if shape.kind == "train":
        mb = microbatch_policy(cfg, shape, mesh)
        batch_sds = S.make_batch_specs(cfg, shape.global_batch,
                                       shape.seq_len)
        bshard = shd.to_named(
            shd.batch_specs(batch_sds, mesh, shape.global_batch), mesh)
        fn = S.build_train_step(cfg, num_microbatches=mb)
        jitted = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
        return jitted, (params_sds, opt_sds, batch_sds), {"microbatches": mb}

    if shape.kind == "prefill":
        batch_sds = S.make_batch_specs(cfg, shape.global_batch,
                                       shape.seq_len)
        batch_sds.pop("targets")
        bshard = shd.to_named(
            shd.batch_specs(batch_sds, mesh, shape.global_batch), mesh)
        fn = S.build_prefill_step(cfg)
        jitted = jax.jit(fn, in_shardings=(pshard, bshard))
        return jitted, (params_sds, batch_sds), {}

    # decode: one new token against a seq_len-deep cache
    cache_len = shape.seq_len
    cache_sds = jax.eval_shape(
        functools.partial(tf.init_cache, cfg, shape.global_batch,
                          cache_len))
    cshard = shd.to_named(
        shd.cache_specs(cache_sds, cfg, mesh, shape.global_batch,
                        shard_seq=cfg.shard_cache_seq), mesh)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    rows_ax = tuple(a for a in mesh.axis_names if a != "model")
    tok_spec = (P(rows_ax, None) if shape.global_batch % rows == 0
                else P(None, None))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = S.build_decode_step(cfg)
    jitted = jax.jit(fn, in_shardings=(pshard, cshard,
                                       NamedSharding(mesh, tok_spec),
                                       NamedSharding(mesh, P())),
                     out_shardings=(None, cshard))
    return jitted, (params_sds, cache_sds, tok_sds, pos_sds), {}


def eigen_cell(graph_name: str, mesh, variant: str | None = None):
    """The paper's own cells: one fused Krylov expansion at graph scale."""
    from repro.dist.dspmm import (CHUNK, build_eigen_step,
                                  build_eigen_step_compressed, edge_spec,
                                  vector_spec)
    from repro.dist.layout import padded_n

    g = configs.GRAPHS[graph_name]
    r_groups = n_row_devices(mesh)
    m_groups = mesh.shape["model"]
    n_pad = padded_n(g.n_vertices, r_groups, m_groups)
    n_dev = r_groups * m_groups
    e_loc = -(-g.n_edges // n_dev)
    b = g.block_size
    nb_v = g.num_blocks - 1

    espec = NamedSharding(mesh, edge_spec(mesh))
    vspec = NamedSharding(mesh, vector_spec(mesh))
    vstack = NamedSharding(mesh, P(None, tuple(mesh.axis_names), None))
    compressed = variant and VARIANTS[variant].get("compressed")
    if compressed:
        fn, n_chunks, e_pad = build_eigen_step_compressed(
            mesh, n_pad=n_pad, e_loc=e_loc, b=b, nb_v=nb_v)
        packed = jax.ShapeDtypeStruct((r_groups, m_groups, e_pad),
                                      jnp.uint32)
        bases = jax.ShapeDtypeStruct((r_groups, m_groups, n_chunks * 2),
                                     jnp.int32)
        vals = jax.ShapeDtypeStruct((r_groups, m_groups, e_pad),
                                    jnp.bfloat16)
        v = jax.ShapeDtypeStruct((nb_v, n_pad, b), jnp.bfloat16)
        x = jax.ShapeDtypeStruct((n_pad, b), jnp.bfloat16)
        jitted = jax.jit(fn, in_shardings=(espec, espec, espec, vstack,
                                           vspec))
        meta = {"n_pad": n_pad, "e_loc": e_loc, "b": b, "nb_v": nb_v,
                "bytes_per_edge": 6}
        return jitted, (packed, bases, vals, v, x), meta

    fn = build_eigen_step(mesh, n_pad=n_pad, e_loc=e_loc, b=b, nb_v=nb_v)
    cols = jax.ShapeDtypeStruct((r_groups, m_groups, e_loc), jnp.int32)
    rws = jax.ShapeDtypeStruct((r_groups, m_groups, e_loc), jnp.int32)
    vals = jax.ShapeDtypeStruct((r_groups, m_groups, e_loc), jnp.float32)
    v = jax.ShapeDtypeStruct((nb_v, n_pad, b), jnp.float32)
    x = jax.ShapeDtypeStruct((n_pad, b), jnp.float32)
    jitted = jax.jit(fn, in_shardings=(espec, espec, espec, vstack, vspec))
    meta = {"n_pad": n_pad, "e_loc": e_loc, "b": b, "nb_v": nb_v,
            "bytes_per_edge": 12}
    return jitted, (cols, rws, vals, v, x), meta


def model_flops_of(arch: str, shape_name: str) -> float:
    if arch == "flasheigen":
        g = configs.GRAPHS[shape_name]
        m = g.subspace
        # SpMM + two CGS passes (gram + update) + CholQR² per expansion
        return (2.0 * g.n_edges * g.block_size
                + 8.0 * g.n_vertices * m * g.block_size
                + 8.0 * g.n_vertices * g.block_size * g.block_size)
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch        # decode: 1 token/seq


# ------------------------------------------------- accounting lowering
def accounting_cost(arch: str, shape_name: str,
                    variant: str | None = None) -> dict:
    """Exact per-step FLOP/byte totals: 1-device lowering with scans fully
    unrolled (HloCostAnalysis counts a while body once — unrolling makes the
    counts exact, including remat recompute). Uses unoptimized-HLO cost
    analysis (lowered.cost_analysis), so no 1-device compile of a 123B graph
    is needed; bytes are therefore an upper bound (pre-fusion)."""
    import dataclasses as dc
    if arch == "flasheigen":
        g = configs.GRAPHS[shape_name]
        # closed-form (no scans in the eigen step): one SpMM + CGS2 + CholQR²
        n, m, b = g.n_vertices, g.subspace, g.block_size
        e = g.n_edges
        compressed = bool(variant and VARIANTS[variant].get("compressed"))
        flops = 2.0 * e * b + 8.0 * n * (m - b) * b + 8.0 * n * b * b
        edge_b = 6 if compressed else 12         # uint16-packed+bf16 vs raw
        panel_b = 2 * b if compressed else 4 * b  # bf16 vs f32 X gather
        v_b = 2 if compressed else 4              # bf16 vs f32 subspace
        bytes_ = (e * (edge_b + panel_b + 4 * b)  # stream + gather + scatter
                  + 4.0 * v_b * n * (m - b)       # 4 reads of V (CGS2)
                  + 40.0 * n * b)                 # w/x round trips
        return {"flops_total": flops, "bytes_total": bytes_}
    cfg = _cfg_with(arch, variant)
    shape = SHAPES[shape_name]
    cfg = dc.replace(cfg, scan_unroll=1 << 30)  # every scan fully unrolled
    if shape.kind == "train":
        fn = S.build_train_step(cfg, num_microbatches=1)
        params_sds, opt_sds = jax.eval_shape(
            functools.partial(S.init_all, jax.random.PRNGKey(0), cfg))
        batch_sds = S.make_batch_specs(cfg, shape.global_batch,
                                       shape.seq_len)
        lowered = jax.jit(fn).lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        fn = S.build_prefill_step(cfg)
        params_sds, _ = jax.eval_shape(
            functools.partial(S.init_all, jax.random.PRNGKey(0), cfg))
        batch_sds = S.make_batch_specs(cfg, shape.global_batch,
                                       shape.seq_len)
        batch_sds.pop("targets")
        lowered = jax.jit(fn).lower(params_sds, batch_sds)
    else:
        fn = S.build_decode_step(cfg)
        params_sds, _ = jax.eval_shape(
            functools.partial(S.init_all, jax.random.PRNGKey(0), cfg))
        cache_sds = jax.eval_shape(
            functools.partial(tf.init_cache, cfg, shape.global_batch,
                              shape.seq_len))
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(fn).lower(params_sds, cache_sds, tok, pos)
    ca = lowered.cost_analysis() or {}
    return {"flops_total": float(ca.get("flops", 0.0)),
            "bytes_total": float(ca.get("bytes accessed", 0.0))}


# ---------------------------------------------------------------- analyze
def analyze(jitted, arg_specs, mesh, model_flops: float,
            acct: dict) -> dict:
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered = jitted.lower(*arg_specs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # compiled (production-mesh) analysis: resident memory + collectives.
    # FLOP/byte totals come from the accounting lowering (acct) because
    # HloCostAnalysis counts while-loop (scan) bodies once.
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes"):
        mem[f] = int(getattr(ma, f, 0))
    coll = collective_bytes(compiled.as_text(), n_dev)

    hlo_total = acct["flops_total"]
    flops_dev = hlo_total / n_dev
    bytes_dev = acct["bytes_total"] / n_dev
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll.get("total", 0.0) / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "hlo_flops_total": hlo_total,
        "memory": mem,
        "per_device_bytes_resident": mem["argument_size_in_bytes"]
        + mem["temp_size_in_bytes"],
        "collective_per_device": coll,
        "model_flops": model_flops,
        "useful_ratio": (model_flops / hlo_total) if hlo_total else 0.0,
        **{k: v for k, v in terms.items()},
        "dominant": dominant,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (model_flops / (n_dev * PEAK_FLOPS))
        / max(max(terms.values()), 1e-30),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str | None = None) -> dict:
    acct = accounting_cost(arch, shape_name, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        if arch == "flasheigen":
            jitted, specs, meta = eigen_cell(shape_name, mesh, variant)
        else:
            jitted, specs, meta = lm_cell(arch, shape_name, mesh, variant)
        rec = analyze(jitted, specs, mesh,
                      model_flops_of(arch, shape_name), acct)
    rec.update({"arch": arch, "shape": shape_name,
                "variant": variant or "baseline",
                "xla_extra": os.environ.get("DRYRUN_XLA_EXTRA", ""),
                "mesh": "2x16x16" if multi_pod else "16x16", **meta})
    return rec


def all_cells(include_eigen: bool = True):
    cells = []
    for arch, cfg in configs.ARCHS.items():
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            if ok:
                cells.append((arch, shape_name))
    if include_eigen:
        for gname in configs.GRAPHS:
            cells.append(("flasheigen", gname))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--graph")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("variant", "baseline")))
                except json.JSONDecodeError:
                    pass

    if args.all:
        cells = all_cells()
    elif args.arch == "flasheigen":
        cells = [("flasheigen", args.graph or "twitter")]
    else:
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    vname = args.variant or "baseline"
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape, mesh_name, vname) in done:
                print(f"skip {arch} {shape} {mesh_name} {vname} (cached)")
                continue
            print(f"=== {arch} {shape} {mesh_name} {vname}", flush=True)
            try:
                rec = run_cell(arch, shape, mp, args.variant)
                print(json.dumps({k: rec[k] for k in
                                  ("compile_s", "dominant",
                                   "roofline_fraction", "useful_ratio")},
                                 default=str), flush=True)
            except Exception as e:  # record failures — they are bugs
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "variant": vname,
                       "error": f"{type(e).__name__}: {e}"}
                print("FAILED:", rec["error"], flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec, default=float) + "\n")


if __name__ == "__main__":
    main()
