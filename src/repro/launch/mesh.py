"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's forced 512-device
host platform to initialize first.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests / CPU)."""
    import numpy as np
    devs = jax.devices()
    n = n_devices or len(devs)
    if multi_pod:
        assert n % 2 == 0
        return jax.make_mesh((2, 1, n // 2), ("pod", "data", "model"),
                             devices=devs[:n])
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"), devices=devs[:1])
    d = int(np.floor(np.sqrt(n)))
    while n % d:
        d -= 1
    return jax.make_mesh((d, n // d), ("data", "model"), devices=devs[:n])


def data_axes(mesh) -> tuple:
    """Axes that shard the batch / vector rows (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)
