"""Render §Dry-run/§Roofline tables from results/dryrun.jsonl.

  PYTHONPATH=src python -m repro.launch.roofline [--jsonl results/dryrun.jsonl]

Emits GitHub-markdown tables consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.1f}us"


def load(path: str):
    recs = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "error" not in r:
                r.setdefault("variant", "baseline")
                recs.append(r)
    return recs


def roofline_table(recs, mesh="16x16", variant="baseline"):
    rows = [r for r in recs if r["mesh"] == mesh
            and r["variant"] == variant]
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | useful | roofline% | bytes/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.2f} | "
            f"{fmt_bytes(r['per_device_bytes_resident'])} |")
    return "\n".join(out)


def variant_compare(recs):
    """Baseline-vs-variant rows for every cell that has both."""
    by_cell = defaultdict(dict)
    for r in recs:
        by_cell[(r["arch"], r["shape"], r["mesh"])][r["variant"]] = r
    out = ["| arch | shape | mesh | variant | bound before | bound after | "
           "speedup | dominant after |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), d in sorted(by_cell.items()):
        if "baseline" not in d or len(d) < 2:
            continue
        base = d["baseline"]
        for vname, r in sorted(d.items()):
            if vname == "baseline":
                continue
            sp = base["step_time_bound_s"] / max(r["step_time_bound_s"],
                                                 1e-30)
            out.append(
                f"| {arch} | {shape} | {mesh} | {vname} | "
                f"{fmt_s(base['step_time_bound_s'])} | "
                f"{fmt_s(r['step_time_bound_s'])} | {sp:.2f}x | "
                f"{r['dominant'].replace('_s','')} |")
    return "\n".join(out)


def dryrun_table(recs, variant="baseline"):
    out = ["| arch | shape | mesh | compile s | bytes/dev | collectives/dev "
           "(AG/AR/RS/A2A/CP) |",
           "|---|---|---|---|---|---|"]
    for r in sorted((r for r in recs if r["variant"] == variant),
                    key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        c = r["collective_per_device"]
        cs = "/".join(fmt_bytes(c.get(k, 0)) for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"{r['compile_s']:.0f} | "
                   f"{fmt_bytes(r['per_device_bytes_resident'])} | {cs} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--section", default="all",
                    choices=("all", "roofline", "dryrun", "perf"))
    args = ap.parse_args()
    recs = load(args.jsonl)
    if args.section in ("all", "dryrun"):
        print("### Dry-run (both meshes, baseline)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline — single pod 16x16 (baseline)\n")
        print(roofline_table(recs, "16x16"))
        print()
        print("### Roofline — multi-pod 2x16x16 (baseline)\n")
        print(roofline_table(recs, "2x16x16"))
        print()
    if args.section in ("all", "perf"):
        print("### Perf — baseline vs optimized variants\n")
        print(variant_compare(recs))


if __name__ == "__main__":
    main()
