"""Serving launcher: a multi-tenant solve queue over one shared store.

  python -m repro.launch.serve --jobs jobs.json --out report.json \
      --backend safs --device-budget $((32<<20)) --max-concurrent 2

`jobs.json` is a list of JobSpec dicts (or `{"jobs": [...]}`):

  [{"job_id": "embed-a", "kind": "eigsh",  "n": 1200, "nev": 4},
   {"job_id": "clust-b", "kind": "cluster", "n": 1200, "priority": 2},
   {"job_id": "pcg-c",   "kind": "lobpcg", "n": 800,  "nev": 4}]

All jobs share ONE store (one SAFS page cache, one write-behind queue, one
device budget split by the arbiter); the scheduler runs them with priority
dispatch and checkpoint-based preemption. The run emits a machine-readable
serve report (per-job wall time, queue wait, preemption count, spectrum
digests, per-namespace I/O reconciliation) and exits nonzero if
`validate_report` finds any serve-invariant violation — tier-1 gates on
this.

`--demo` ignores --jobs and runs the staged preemption scenario: saturate
the slots with low-priority background solves, wait until one is mid-
flight, then submit a high-priority rush job — the scheduler suspends a
background job (checkpoint → requeue), runs the rush job, and resumes.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import numpy as np

from repro.serve import JobSpec, build_service, validate_report


def _demo_specs():
    background = [
        JobSpec("bg-embed", kind="eigsh", n=1500, nnz=15000, nev=6,
                priority=0, tol=1e-8, max_iters=150),
        JobSpec("bg-lobpcg", kind="lobpcg", n=800, nnz=8000, nev=4,
                priority=0, tol=1e-5, max_iters=60),
        JobSpec("bg-cluster", kind="cluster", n=1200, k_classes=4, nev=4,
                priority=1, tol=1e-6),
    ]
    rush = JobSpec("rush-eigsh", kind="eigsh", n=400, nnz=4000, nev=2,
                   priority=5, tol=1e-5, max_iters=60)
    return background, rush


def _run_demo(service, *, start_timeout: float = 60.0) -> None:
    """Submit background jobs, wait until one is actually iterating, then
    drop the rush job on the queue so the preemption path exercises."""
    background, rush = _demo_specs()
    for spec in background:
        service.submit(spec)
    deadline = time.monotonic() + start_timeout
    while time.monotonic() < deadline:
        service.scheduler.tick()
        running = service.scheduler.stats_dict()["running"]
        if any(p["steps"] >= 1 for p in running.values()):
            break
        time.sleep(0.02)
    service.submit(rush)


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="multi-tenant eigensolver service over one store")
    ap.add_argument("--jobs", help="JSON file of JobSpec dicts")
    ap.add_argument("--out", help="write the serve report here (JSON); "
                                  "default stdout")
    ap.add_argument("--backend", choices=("safs", "ram"), default="safs")
    ap.add_argument("--root", help="SAFS page-file root (default: tmp)")
    ap.add_argument("--device-budget", type=int, default=32 << 20,
                    help="global device budget the arbiter splits [bytes]")
    ap.add_argument("--cache-bytes", type=int, default=8 << 20,
                    help="shared SAFS page-cache capacity [bytes]")
    ap.add_argument("--max-concurrent", type=int, default=2)
    ap.add_argument("--max-queued", type=int, default=64)
    ap.add_argument("--ckpt-root",
                    help="checkpoint root for suspend/resume (default: "
                         "tmp; preemption needs one)")
    ap.add_argument("--job-deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="default per-job wall-clock deadline; the "
                         "watchdog suspends (then abandons) jobs past it")
    ap.add_argument("--deadline-grace", type=float, default=2.0,
                    metavar="SECONDS",
                    help="extra time a deadline-expired worker gets to "
                         "checkpoint-suspend before abandonment")
    ap.add_argument("--orphan-grace", type=float, default=3600.0,
                    metavar="SECONDS",
                    help="age gate for the startup orphan-namespace GC "
                         "(negative disables the sweep)")
    ap.add_argument("--demo", action="store_true",
                    help="run the staged preemption demo instead of --jobs")
    args = ap.parse_args(argv)
    if not args.demo and not args.jobs:
        ap.error("need --jobs FILE or --demo")

    ckpt_root = args.ckpt_root or tempfile.mkdtemp(prefix="serve_ckpt_")
    service = build_service(
        backend=args.backend, root=args.root,
        device_budget=args.device_budget, cache_bytes=args.cache_bytes,
        ckpt_root=ckpt_root, max_concurrent=args.max_concurrent,
        max_queued=args.max_queued,
        default_deadline_s=args.job_deadline,
        deadline_grace_s=args.deadline_grace,
        orphan_grace_s=(None if args.orphan_grace < 0
                        else args.orphan_grace))
    try:
        if args.demo:
            _run_demo(service)
        else:
            with open(args.jobs) as f:
                specs = json.load(f)
            if isinstance(specs, dict):
                specs = specs["jobs"]
            for d in specs:
                service.submit(d)
        t0 = time.monotonic()
        service.drain()
        report = service.report()
        report["queue_wall_s"] = time.monotonic() - t0
        errors = validate_report(report)
        report["valid"] = not errors
        report["errors"] = errors
        text = json.dumps(report, indent=2, default=_json_default)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text + "\n")
        else:
            print(text)
        for j in report["jobs"]:
            print(f"[{j['state']:>9s}] {j['job_id']:<12s} "
                  f"prio={j['priority']} wall={j['wall_s']:.2f}s "
                  f"wait={j['queue_wait_s']:.2f}s "
                  f"preempts={j['preemptions']} "
                  f"sha={(j['spectrum'] or {}).get('sha', '-')}",
                  file=sys.stderr)
        sched = report["scheduler"]
        print(f"queue drained in {report['queue_wall_s']:.2f}s; "
              f"{sched['completed']} jobs, "
              f"{sched['preempt_requests']} preempt requests, "
              f"{sched['requeues']} requeues, "
              f"{sched.get('timeouts', 0)} deadline timeouts, "
              f"{sched.get('abandoned', 0)} abandoned; "
              f"valid={report['valid']}", file=sys.stderr)
        integ = (report.get("backend") or {}).get("integrity")
        if integ:
            print(f"integrity: {integ['pages_verified']} pages verified, "
                  f"{integ['crc_failures']} corrupt "
                  f"({integ['quarantined']} quarantined), "
                  f"{integ['pages_repaired']} repaired, "
                  f"{integ['scrub_passes']} scrub passes", file=sys.stderr)
        if report.get("orphans_swept"):
            print(f"orphan namespaces swept at startup: "
                  f"{', '.join(report['orphans_swept'])}", file=sys.stderr)
        for e in errors:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1 if errors else 0
    finally:
        service.close()


if __name__ == "__main__":
    sys.exit(main())
