"""Serving launcher: batched prefill + decode loop.

  python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16

Demonstrates the full serving path (prefill_with_cache → decode_step ring
buffers) the decode_32k / long_500k dry-run cells lower at scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import steps as S
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    if not cfg.decoder:
        raise SystemExit(f"{cfg.name} is encoder-only — no decode path")
    rng = np.random.default_rng(args.seed)
    params = tf.init_model(jax.random.PRNGKey(args.seed), cfg)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    total_len = args.prompt_len + args.gen

    t0 = time.time()
    logits, cache = tf.prefill_with_cache(params, cfg, prompts,
                                          cache_len=total_len)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    decode = jax.jit(S.build_decode_step(cfg))
    out = [next_tok]
    t0 = time.time()
    for t in range(args.prompt_len, total_len - 1):
        logits, cache = decode(params, cache, next_tok, jnp.int32(t))
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms; "
          f"decode {len(out)} steps in {t_decode*1e3:.1f} ms "
          f"({t_decode/max(len(out),1)*1e3:.1f} ms/tok)")
    print("generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
