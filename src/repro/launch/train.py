"""Training launcher.

  python -m repro.launch.train --arch qwen2-1.5b --reduced --steps 100
  python -m repro.launch.train --arch yi-9b --reduced --steps 300 \
      --ckpt-dir /tmp/ck --resume

On a real pod this process runs per host (jax.distributed.initialize) with
the production mesh; on CPU it uses the debug mesh. Checkpoint/restart,
preemption handling and the deterministic pipeline come from
train.trainer.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_debug_mesh
from repro.train.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the arch family")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    if cfg.frontend is not None:
        raise SystemExit("frontend archs need the example drivers "
                         "(precomputed embeddings)")
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, peak_lr=args.lr,
                       num_microbatches=args.microbatches, seed=args.seed)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.seed)
    mesh = make_debug_mesh() if len(jax.devices()) > 1 else None
    summary = train(cfg, tcfg, dcfg, mesh=mesh)
    print("summary:", summary)


if __name__ == "__main__":
    main()
