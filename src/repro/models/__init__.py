"""repro.models"""
