"""Attention: GQA / MQA / sliding-window / cross, with q-chunked
online-softmax for long prefills and ring-buffer KV caches for decode.

Memory discipline mirrors the paper's streaming philosophy: for long
sequences the query dimension is scanned in chunks so the score matrix
never materializes beyond (chunk × S) — prefill_32k at 90B scale stays
within HBM without flash-attention hardware tricks (a Pallas flash kernel
is a later hillclimb option; the chunked scan is the portable baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import (init_linear, apply_linear, apply_rope,
                                  rope_freqs, dtype_of)

NEG_INF = -1e30
Q_CHUNK = 1024          # q-chunk scan kicks in above this seq length


def init_attn(key, cfg, *, cross: bool = False):
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, cfg, cfg.d_model, cfg.n_heads * hd,
                          bias=cfg.qkv_bias),
        "wk": init_linear(k2, cfg, cfg.d_model, cfg.n_kv_heads * hd,
                          bias=cfg.qkv_bias),
        "wv": init_linear(k3, cfg, cfg.d_model, cfg.n_kv_heads * hd,
                          bias=cfg.qkv_bias),
        "wo": init_linear(k4, cfg, cfg.n_heads * hd, cfg.d_model),
    }


def _split_heads(cfg, q, k, v):
    b, sq = q.shape[:2]
    sk = k.shape[1]
    hd, kh = cfg.hd, cfg.n_kv_heads
    g = cfg.n_heads // kh
    q = q.reshape(b, sq, kh, g, hd)
    k = k.reshape(b, sk, kh, hd)
    v = v.reshape(b, sk, kh, hd)
    return q, k, v


def _attend(q, k, v, mask):
    """q (B,Sq,K,G,hd), k/v (B,Sk,K,hd), mask (Sq,Sk) or (B,1,1,Sq,Sk)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    if mask is not None:
        scores = scores + mask
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v)


def _mask(kind: str, sq: int, sk: int, *, q_offset: int = 0,
          window: int = 0) -> jnp.ndarray | None:
    if kind == "none":
        return None
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = qi >= ki
    if kind == "swa":
        m = m & (qi - ki < window)
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def attn_forward(cfg, p, x, positions, *, kind: str = "causal",
                 encoder: jnp.ndarray | None = None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). kind: causal|swa|cross|none."""
    b, s, _ = x.shape
    src = encoder if kind == "cross" else x
    q = apply_linear(p["wq"], x)
    k = apply_linear(p["wk"], src)
    v = apply_linear(p["wv"], src)
    q, k, v = _split_heads(cfg, q, k, v)
    if kind != "cross":
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    sk = k.shape[1]
    mkind = {"causal": "causal", "swa": "swa",
             "cross": "none", "none": "none"}[kind]

    if s <= Q_CHUNK:
        out = _attend(q, k, v, _mask(mkind, s, sk, window=cfg.window))
    else:
        assert s % Q_CHUNK == 0
        nchunks = s // Q_CHUNK

        def body(_, qc_i):
            qc, i = qc_i
            m = _mask(mkind, Q_CHUNK, sk, q_offset=i * Q_CHUNK,
                      window=cfg.window)
            return None, _attend(qc, k, v, m)

        qs = q.reshape(b, nchunks, Q_CHUNK, *q.shape[2:]).swapaxes(0, 1)
        # scan_unroll: the dry-run accounting lowers with full unroll so
        # HloCostAnalysis sees every chunk (a while body is counted once)
        _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nchunks)),
                               unroll=min(cfg.scan_unroll, nchunks))
        out = outs.swapaxes(0, 1).reshape(b, s, *q.shape[2:])
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    return apply_linear(p["wo"], out)


# ---------------------------------------------------------------- decode
def init_kv_cache(cfg, batch: int, length: int, dtype) -> dict:
    """Ring-buffer KV cache. For SWA/local archs `length` is min(S, window)
    — long-context decode stores only the window (the sub-quadratic win)."""
    kh, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, length, kh, hd), dtype),
        "v": jnp.zeros((batch, length, kh, hd), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),   # absolute pos per slot
    }


def attn_decode(cfg, p, x, cache, pos, *, kind: str = "causal",
                encoder_kv: tuple | None = None):
    """One-token decode. x (B,1,D); pos scalar int32. Returns (out, cache)."""
    b = x.shape[0]
    q = apply_linear(p["wq"], x)
    if kind == "cross":
        k, v = encoder_kv                      # precomputed at prefill
        q, _, _ = _split_heads(cfg, q, k.reshape(b, k.shape[1], -1),
                               v.reshape(b, v.shape[1], -1))
        mask = None
    else:
        kn = apply_linear(p["wk"], x)
        vn = apply_linear(p["wv"], x)
        q, kn, vn = _split_heads(cfg, q, kn, vn)
        cos, sin = rope_freqs(cfg, pos[None].astype(jnp.float32))
        q = apply_rope(q, cos, sin)
        kn = apply_rope(kn, cos, sin)
        length = cache["k"].shape[1]
        slot = pos % length                     # ring buffer
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kn, slot, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vn, slot, 1),
            "pos": jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], pos[None], slot, 0),
        }
        k, v = cache["k"], cache["v"]
        valid = (cache["pos"] >= 0) & (cache["pos"] <= pos)
        if kind == "swa":
            valid &= cache["pos"] > pos - cfg.window
        mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    out = _attend(q, k, v, mask)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
    return apply_linear(p["wo"], out), cache


def precompute_cross_kv(cfg, p, encoder: jnp.ndarray):
    k = apply_linear(p["wk"], encoder)
    v = apply_linear(p["wv"], encoder)
    b, sk = k.shape[:2]
    return (k.reshape(b, sk, cfg.n_kv_heads, cfg.hd),
            v.reshape(b, sk, cfg.n_kv_heads, cfg.hd))
