"""Elementary model components (pure functions, params as nested dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------------- norms
def init_norm(cfg, d: int):
    p = {"scale": jnp.ones((d,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype_of(cfg))
    return p


def apply_norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- linear
def init_linear(key, cfg, d_in: int, d_out: int, *, bias: bool = False):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) / np.sqrt(d_in)
    p = {"w": w.astype(dtype_of(cfg))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype_of(cfg))
    return p


def apply_linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def act_fn(cfg):
    return jax.nn.silu if cfg.act == "silu" else jax.nn.gelu


# ----------------------------------------------------------------- mlp
def init_mlp(key, cfg, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": init_linear(k1, cfg, d, d_ff),
         "down": init_linear(k2, cfg, d_ff, d)}
    if cfg.glu:
        p["gate"] = init_linear(k3, cfg, d, d_ff)
    return p


def apply_mlp(cfg, p, x):
    h = apply_linear(p["up"], x)
    if cfg.glu:
        h = act_fn(cfg)(apply_linear(p["gate"], x)) * h
    else:
        h = act_fn(cfg)(h)
    return apply_linear(p["down"], h)


# ----------------------------------------------------------------- rope
def rope_freqs(cfg, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (…,) → (…, hd/2) cos/sin."""
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, hd, 2) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (B, S, …, hd); cos/sin: (S, hd/2) or (B, S, hd/2). Head dims
    between S and hd broadcast."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    mid = (1,) * (x1.ndim - 3)
    if cos.ndim == 2:                       # (S, hd/2)
        shape = (1, cos.shape[0]) + mid + (cos.shape[-1],)
    else:                                   # (B, S, hd/2)
        shape = cos.shape[:2] + mid + (cos.shape[-1],)
    cos, sin = cos.reshape(shape), sin.reshape(shape)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- embeds
def init_embedding(key, cfg):
    tok = jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                            jnp.float32) * 0.02
    return {"tok": tok.astype(dtype_of(cfg))}


def embed_tokens(p, tokens):
    return p["tok"][tokens]


def lm_logits(cfg, params, x):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["lm_head"]["w"]
    return (x @ w).astype(jnp.float32)


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean CE over (B, S) targets; logits (B, S, V) f32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
