"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch
(GShard/Switch style — one-hot dispatch/combine einsums, which is the
shardable TPU form: the expert dimension maps onto the 'model' mesh axis
when divisible — EP — else the expert hidden dim is tensor-sharded).

Covers grok-1 (8e top-2, TP-within-expert) and arctic (128e top-2 + dense
residual MLP, EP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import init_linear, apply_linear, init_mlp, \
    apply_mlp, act_fn, dtype_of


def init_moe(key, cfg):
    e = cfg.n_experts
    dff = cfg.moe_d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": init_linear(ks[0], cfg, d, e),
        "up": (jax.random.normal(ks[1], (e, d, dff), jnp.float32)
               * scale).astype(dtype_of(cfg)),
        "down": (jax.random.normal(ks[2], (e, dff, d), jnp.float32)
                 / np.sqrt(dff)).astype(dtype_of(cfg)),
    }
    if cfg.glu:
        p["gate"] = (jax.random.normal(ks[3], (e, d, dff), jnp.float32)
                     * scale).astype(dtype_of(cfg))
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[4], cfg, d, cfg.d_ff)
    return p


def capacity(cfg, tokens_per_group: int) -> int:
    cap = int(np.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(cap, 1)


def moe_forward(cfg, p, x):
    """x (B,S,D) → (B,S,D), GShard-style grouped dispatch.

    Tokens are dispatched within *groups* (one group per batch row), so the
    dispatch/combine einsum cost is g·s·E·cap·D with cap ∝ s/E — linear in
    total tokens — instead of the quadratic global-capacity form. Groups
    map onto the data-parallel mesh axes; experts onto 'model' (EP).
    Per-group over-capacity tokens are dropped (standard).

    decode regrouping (§Perf hillclimb): with S == 1 (decode), per-batch-row
    groups would run ALL experts on 1-token inputs (cap=1 each) — E/top_k ×
    wasted expert FLOPs. Regroup the whole batch into one group so the
    expert GEMM only sees ≈ B·top_k/E tokens per expert."""
    if getattr(cfg, "moe_decode_regroup", False) and x.shape[1] == 1:
        b0 = x.shape[0]
        out = moe_forward_grouped(cfg, p, x.reshape(1, b0, x.shape[2]))
        return out.reshape(b0, 1, x.shape[2])
    return moe_forward_grouped(cfg, p, x)


def moe_forward_grouped(cfg, p, x):
    g, s, d = x.shape
    e = cfg.n_experts
    cap = capacity(cfg, s)

    logits = apply_linear(p["router"], x).astype(jnp.float32)    # (g,s,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)        # (g,s,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position of each (token, k) routing within its per-group expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)        # (g,s,k,E)
    flatoh = onehot.reshape(g, s * cfg.top_k, e)
    pos_in_e = jnp.cumsum(flatoh, axis=1) * flatoh - 1
    pos = jnp.max(pos_in_e.reshape(g, s, cfg.top_k, e), axis=-1)  # (g,s,k)
    keep = pos < cap

    # over-capacity routings get pos=cap → one_hot yields the zero row
    oh_e = onehot.astype(x.dtype)                                # (g,s,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                          dtype=x.dtype)                         # (g,s,k,cap)
    dispatch = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)         # (g,s,E,cap)
    gv_e = jnp.einsum("gsk,gske->gse",
                      (gate_vals * keep).astype(jnp.float32),
                      onehot.astype(jnp.float32)).astype(x.dtype)
    combine = dispatch * gv_e[..., None]

    xin = jnp.einsum("gsec,gsd->gecd", dispatch, x)              # (g,E,cap,D)
    h = jnp.einsum("gecd,edf->gecf", xin, p["up"])
    if cfg.glu:
        h = act_fn(cfg)(jnp.einsum("gecd,edf->gecf", xin, p["gate"])) * h
    else:
        h = act_fn(cfg)(h)
    out_e = jnp.einsum("gecf,efd->gecd", h, p["down"])           # (g,E,cap,D)
    out = jnp.einsum("gsec,gecd->gsd", combine, out_e)

    if cfg.dense_residual:
        out = out + apply_mlp(cfg, p["dense"], x)
    return out


def aux_load_balance_loss(cfg, logits: jnp.ndarray) -> jnp.ndarray:
    """Switch-style load-balance auxiliary (fraction·probability)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
