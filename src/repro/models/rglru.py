"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t) with
a_t = exp(−c·softplus(Λ)·r_t). Sequence form uses an associative scan
(log-depth on TPU); decode is the O(1) per-token recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import init_linear, apply_linear, dtype_of

_C = 8.0


def _width(cfg):
    return cfg.rglru_width or cfg.d_model


def init_rglru(key, cfg):
    d, rw = cfg.d_model, _width(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_x": init_linear(ks[0], cfg, d, rw),
        "in_gate": init_linear(ks[1], cfg, d, rw),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, rw), jnp.float32)
                   * 0.1).astype(dtype_of(cfg)),
        "conv_b": jnp.zeros((rw,), dtype_of(cfg)),
        "w_a": init_linear(ks[3], cfg, rw, rw),        # recurrence gate r_t
        "w_i": init_linear(ks[4], cfg, rw, rw),        # input gate i_t
        "lam": jnp.full((rw,), 3.0, jnp.float32),      # Λ (a ≈ 0.95^c init)
        "out": init_linear(jax.random.fold_in(key, 9), cfg, rw, d),
    }


def _gates(p, xb):
    r = jax.nn.sigmoid(apply_linear(p["w_a"], xb).astype(jnp.float32))
    i = jax.nn.sigmoid(apply_linear(p["w_i"], xb).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # log a_t ≤ 0
    a = jnp.exp(log_a)
    gated_x = i * xb.astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12, None)) * gated_x
    return a, b


def _conv1d(w, b, x, *, state=None):
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        return (sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b,
                None)
    buf = jnp.concatenate([state, x], axis=1)
    return jnp.einsum("bkc,kc->bc", buf, w)[:, None] + b, buf[:, 1:]


def rglru_forward(cfg, p, x, *, return_state: bool = False):
    """x (B,L,D) → (B,L,D) via associative scan over the recurrence."""
    xb = apply_linear(p["in_x"], x)
    gate = jax.nn.gelu(apply_linear(p["in_gate"], x))
    xb, _ = _conv1d(p["conv_w"], p["conv_b"], xb)
    a, b = _gates(p, xb)                                  # (B,L,RW) f32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate)
    out = apply_linear(p["out"], y)
    if return_state:
        return out, h[:, -1]
    return out


def init_rglru_cache(cfg, batch: int, dtype):
    rw = _width(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, rw), dtype),
        "h": jnp.zeros((batch, rw), jnp.float32),
    }


def rglru_decode(cfg, p, x, cache):
    """x (B,1,D) → (y, cache) single-step."""
    xb = apply_linear(p["in_x"], x)
    gate = jax.nn.gelu(apply_linear(p["in_gate"], x))
    xb, conv_state = _conv1d(p["conv_w"], p["conv_b"], xb,
                             state=cache["conv"])
    a, b = _gates(p, xb)                                  # (B,1,RW)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = (h[:, None].astype(x.dtype) * gate)
    return apply_linear(p["out"], y), {"conv": conv_state, "h": h}
