"""Logical-axis sharding rule engine with divisibility fallback.

Maps parameter/activation/cache dimensions onto the fixed production mesh
(('pod',) 'data', 'model'):

  * batch-like dims shard over every non-'model' axis;
  * width-like dims (q/kv projections, ffn, experts, vocab) shard over
    'model' **iff divisible** — otherwise replicate (e.g. qwen2's 12 heads
    on a 16-way axis: the flat 1536 q-dim shards; kv 256-dim replicates);
  * with cfg.use_fsdp, the d_model ("embed") dim of big-arch params also
    shards over 'data' (FSDP: GSPMD all-gathers weights per layer);
  * optimizer moments get ZeRO-1 spreading (optim.adamw.shard_opt_spec).

Specs are produced per-path from the params pytree, so new layer types only
need a rule entry.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _rows(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def _div(size: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    ax = axes if isinstance(axes, tuple) else (axes,)
    total = int(np.prod([mesh.shape[a] for a in ax]))
    return size % total == 0 and size >= total


def _maybe(size: int, mesh: Mesh, axes):
    return axes if _div(size, mesh, axes) else None


# (path regex, [logical dim roles]) — roles consumed right-to-left so stacked
# leading layer dims fall through to None.
_PARAM_RULES: list[tuple[str, list[str]]] = [
    (r"embed/tok$",               ["vocab", "embed"]),
    (r"embed/in_proj/w$",         ["embed", "model_out"]),
    (r"lm_head/w$",               ["embed", "vocab"]),
    (r"attn/wq/w$",               ["embed", "model_out"]),
    (r"attn/w[kv]/w$",            ["embed", "model_out"]),
    (r"attn/wo/w$",               ["model_out", "embed"]),
    (r"attn/w[qkv]/b$",           ["model_out"]),
    (r"ffn/(up|gate)/w$",         ["embed", "model_out"]),
    (r"ffn/down/w$",              ["model_out", "embed"]),
    (r"ffn/router/w$",            ["embed", None]),
    (r"ffn/(up|gate)$",           ["experts", "embed", "model_out"]),
    (r"ffn/down$",                ["experts", "model_out", "embed"]),
    (r"ffn/dense/(up|gate)/w$",   ["embed", "model_out"]),
    (r"ffn/dense/down/w$",        ["model_out", "embed"]),
    (r"ssm/in_proj/w$",           ["embed", None]),
    (r"ssm/out_proj/w$",          ["model_out", "embed"]),
    (r"rec/(in_x|in_gate|w_a|w_i)/w$", ["embed", "model_out"]),
    (r"rec/out/w$",               ["model_out", "embed"]),
]


def _role_axis(role, size: int, cfg, mesh: Mesh):
    if role is None:
        return None
    if role == "vocab" or role == "model_out" or role == "experts":
        return _maybe(size, mesh, "model")
    if role == "embed":
        if cfg.use_fsdp:
            return _maybe(size, mesh, "data")
        return None
    return None


def param_specs(params: Any, cfg, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec mirroring params."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        shape = leaf.shape
        spec = [None] * len(shape)
        used: set = set()
        for pat, roles in _PARAM_RULES:
            if re.search(pat, pstr):
                # align roles to trailing dims (leading dims = layer stacking)
                for i, role in enumerate(roles):
                    dim = len(shape) - len(roles) + i
                    if dim < 0:
                        continue
                    ax = _role_axis(role, shape[dim], cfg, mesh)
                    # each mesh axis may appear once per spec: first role
                    # wins (e.g. arctic: experts take 'model' → EP, the
                    # within-expert ffn dim replicates; grok: 8 experts
                    # don't divide 16 → ffn dim takes 'model' → TP)
                    if ax is not None and ax in used:
                        ax = None
                    if ax is not None:
                        used.add(ax)
                    spec[dim] = ax
                break
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch: Any, mesh: Mesh, global_batch: int) -> Any:
    rows = _rows(mesh)
    nrows = int(np.prod([mesh.shape[a] for a in rows]))
    ax = rows if global_batch % nrows == 0 else None

    def spec(leaf):
        return P(ax, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map(spec, batch)


def cache_specs(cache: Any, cfg, mesh: Mesh, batch: int,
                *, shard_seq: bool = False) -> Any:
    """Decode caches: batch over row axes; kv-head/state dims over 'model'
    when divisible. Stacked leading layer dim stays unsharded.

    shard_seq=True (§Perf hillclimb): when the kv-head dim doesn't divide
    the model axis (every GQA arch with kv<16), shard the cache *sequence*
    dim over 'model' instead of replicating — attention over a seq-sharded
    ring buffer is a partial-softmax psum, tiny vs gathering the cache."""
    rows = _rows(mesh)
    nrows = int(np.prod([mesh.shape[a] for a in rows]))
    batch_ax = rows if batch % nrows == 0 else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        shape = leaf.shape
        stacked = "stack" in pstr
        off = 1 if stacked else 0
        spec = [None] * len(shape)
        name = pstr.rsplit("/", 1)[-1]
        if name in ("k", "v", "ck", "cv"):        # (B, S, K, hd)
            if len(shape) - off == 4:
                spec[off] = batch_ax
                spec[off + 2] = _maybe(shape[off + 2], mesh, "model")
                if spec[off + 2] is None and shard_seq:
                    spec[off + 1] = _maybe(shape[off + 1], mesh, "model")
        elif name == "state":                      # ssm (B, H, P, N)
            spec[off] = batch_ax
            spec[off + 1] = _maybe(shape[off + 1], mesh, "model")
        elif name == "conv":                       # (B, K-1, C)
            spec[off] = batch_ax
            spec[off + 2] = _maybe(shape[off + 2], mesh, "model")
        elif name == "h":                          # rglru (B, RW)
            spec[off] = batch_ax
            spec[off + 1] = _maybe(shape[off + 1], mesh, "model")
        elif name == "pos":
            if shard_seq:
                spec[off] = _maybe(shape[off], mesh, "model")
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  tree_specs,
                                  is_leaf=lambda x: isinstance(x, P))
