"""Mamba-2: state-space duality (SSD) layer [arXiv:2405.21060].

Chunked dual form for train/prefill (quadratic inside ssm_chunk-sized
chunks, linear recurrence across chunks) and the O(1)-state recurrent form
for decode — which is what makes the long_500k cell tractable for this
family (constant-size state instead of a 524288-token KV cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import init_linear, apply_linear, dtype_of


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg):
    d, (d_in, h, p, n) = cfg.d_model, _dims(cfg)
    ks = jax.random.split(key, 4)
    conv_dim = d_in + 2 * n                      # conv over (x, B, C)
    return {
        # in_proj → [z, x, B, C, dt]
        "in_proj": init_linear(ks[0], cfg, d, 2 * d_in + 2 * n + h),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype_of(cfg)),
        "conv_b": jnp.zeros((conv_dim,), dtype_of(cfg)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": init_linear(ks[2], cfg, d_in, d),
        "norm_scale": jnp.ones((d_in,), dtype_of(cfg)),
    }


def _segsum(x):
    """(… T) → (… T T) masked segment sums: sum_{j<i..} (lower-tri)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(x, a_dt, b_mat, c_mat, chunk: int):
    """SSD dual form.

    x    (B, L, H, P)   inputs per head
    a_dt (B, L, H)      log decay per step (dt * A, negative)
    b/c  (B, L, N)      shared across heads (ngroups = 1)
    returns y (B, L, H, P), final_state (B, H, P, N)
    """
    bsz, l_orig, h, p = x.shape
    n = b_mat.shape[-1]
    if l_orig % chunk:
        # pad with identity steps: x=0 adds nothing, a_dt=0 → decay=1
        # preserves the state, so y[:l] and final_state are exact.
        padlen = chunk - l_orig % chunk
        x = jnp.pad(x, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, padlen), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, padlen), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, padlen), (0, 0)))
    l = x.shape[1]
    c = l // chunk
    xc = x.reshape(bsz, c, chunk, h, p)
    ac = a_dt.reshape(bsz, c, chunk, h).transpose(0, 3, 1, 2)  # (B,H,C,L)
    bc = b_mat.reshape(bsz, c, chunk, n)
    cc = c_mat.reshape(bsz, c, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)
    # 1. intra-chunk (quadratic, "attention-like")
    l_mat = jnp.exp(_segsum(ac))                                # (B,H,C,L,L)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, l_mat, xc)
    # 2. chunk states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (B,H,C,L)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", bc, decay_states, xc)
    # 3. inter-chunk recurrence
    a_chunk = a_cum[..., -1]                                    # (B,H,C)
    pad = jnp.pad(a_chunk, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(pad))                         # (B,H,C+1,C+1)
    states0 = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states], axis=1)        # (B,C+1,H,P,N)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states0)
    prev_states = new_states[:, :-1]                            # state entering chunk
    final_state = new_states[:, -1]
    # 4. state → output contribution
    state_decay = jnp.exp(a_cum)                                # (B,H,C,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(bsz, l, h, p)[:, :l_orig]
    return y, final_state


def _conv1d(w, b, x, *, state=None):
    """Causal depthwise conv over time. x (B,L,C); w (K,C). With `state`
    (B,K-1,C) performs the single-step decode update."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
        return out + b, None
    buf = jnp.concatenate([state, x], axis=1)                  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", buf, w)[:, None] + b
    return out, buf[:, 1:]


def ssm_forward(cfg, p, x, *, return_state: bool = False):
    """Full-sequence SSD. x (B,L,D) → y (B,L,D)."""
    d_in, h, hp, n = _dims(cfg)
    bsz, l, _ = x.shape
    zxbcdt = apply_linear(p["in_proj"], x)
    z, xin, b_mat, c_mat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
    conv_out, _ = _conv1d(p["conv_w"], p["conv_b"], conv_in)
    conv_out = jax.nn.silu(conv_out)
    xin, b_mat, c_mat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    a = -jnp.exp(p["a_log"])                                      # (H,)
    a_dt = dt * a                                                  # (B,L,H)
    xh = xin.reshape(bsz, l, h, hp).astype(jnp.float32)
    xh_dt = xh * dt[..., None]
    y, state = _ssd_chunked(xh_dt, a_dt, b_mat.astype(jnp.float32),
                            c_mat.astype(jnp.float32), cfg.ssm_chunk)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # grouped rmsnorm
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = apply_linear(p["out_proj"], y)
    if return_state:
        return out, state
    return out


def init_ssm_cache(cfg, batch: int, dtype):
    d_in, h, hp, n = _dims(cfg)
    conv_dim = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, h, hp, n), jnp.float32),
    }


def ssm_decode(cfg, p, x, cache):
    """Single-step recurrence. x (B,1,D) → (y (B,1,D), cache)."""
    d_in, h, hp, n = _dims(cfg)
    bsz = x.shape[0]
    zxbcdt = apply_linear(p["in_proj"], x)
    z, xin, b_mat, c_mat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
    conv_out, conv_state = _conv1d(p["conv_w"], p["conv_b"], conv_in,
                                   state=cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, b_mat, c_mat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                              # (B,H)
    xh = xin.reshape(bsz, h, hp).astype(jnp.float32)
    bv = b_mat[:, 0].astype(jnp.float32)                              # (B,N)
    cv = c_mat[:, 0].astype(jnp.float32)
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bv)
    y = jnp.einsum("bhpn,bn->bhp", state, cv) + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    return apply_linear(p["out_proj"], y), {"conv": conv_state, "state": state}
