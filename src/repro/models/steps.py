"""train_step / prefill_step / decode_step builders (the dry-run units).

Each builder returns a pure function of explicit state — jit-able with
in_shardings/out_shardings at the launch layer. Microbatching (gradient
accumulation over a lax.scan) bounds activation memory for the train_4k
cells of the big dense archs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.modules import dtype_of
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine


def make_batch_specs(cfg: ArchConfig, batch: int, seq: int):
    """ShapeDtypeStructs for one global train batch."""
    specs: dict[str, Any] = {}
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.dtype(cfg.param_dtype))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.frontend == "patch":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.param_dtype))
    specs["targets"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return specs


def build_train_step(cfg: ArchConfig, *, num_microbatches: int = 1,
                     peak_lr: float = 3e-4, warmup: int = 100,
                     total_steps: int = 10000, max_grad_norm: float = 1.0):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def loss(params, mb):
        return tf.loss_fn(params, cfg, mb)

    def grads_of(params, batch):
        if num_microbatches == 1:
            return jax.value_and_grad(loss)(params, batch)

        def mb_slice(batch, i):
            return jax.tree_util.tree_map(
                lambda x: x.reshape(num_microbatches,
                                    x.shape[0] // num_microbatches,
                                    *x.shape[1:])[i], batch)

        def body(carry, i):
            acc_l, acc_g = carry
            l, g = jax.value_and_grad(loss)(params, mb_slice(batch, i))
            acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
            return (acc_l + l, acc_g), None

        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (l, g), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_g),
                                 jnp.arange(num_microbatches))
        scale = 1.0 / num_microbatches
        return l * scale, jax.tree_util.tree_map(lambda x: x * scale, g)

    def train_step(params, opt_state, batch):
        l, g = grads_of(params, batch)
        g, gnorm = adamw.global_norm_clip(g, max_grad_norm)
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        params, opt_state = adamw.update(opt_state, g, params, lr=lr)
        return params, opt_state, {"loss": l, "grad_norm": gnorm, "lr": lr}

    return train_step


def build_prefill_step(cfg: ArchConfig):
    """(params, batch) → logits f32: (B, S, V), or (B, 1, V) with
    cfg.prefill_last_only (§Perf: serving only samples the last position —
    projecting every position through a 100k+ vocab head dominates the
    prefill's memory/collective terms for nothing)."""

    def prefill_step(params, batch):
        enc = batch.get("image_embeds")
        inp = (batch["frames"] if cfg.frontend == "audio"
               else batch["tokens"])
        if cfg.prefill_last_only and cfg.decoder:
            from repro.models.modules import lm_logits
            h = tf.forward(params, cfg, inp, encoder=enc)
            return lm_logits(cfg, params, h[:, -1:])
        return tf.logits_fn(params, cfg, inp, encoder=enc)

    return prefill_step


def build_decode_step(cfg: ArchConfig):
    """(params, cache, token (B,1), pos ()) → (logits (B,1,V), cache)."""

    def decode(params, cache, token, pos):
        return tf.decode_step(params, cfg, cache, token, pos)

    return decode


def init_all(key, cfg: ArchConfig):
    params = tf.init_model(key, cfg)
    opt_state = adamw.init(params)
    return params, opt_state
