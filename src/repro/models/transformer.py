"""Config-driven model assembly.

Layers are grouped into *super-layers* (one repetition of cfg.pattern) and
scanned with stacked params — HLO size and therefore 512-way GSPMD compile
time is independent of depth. Remainder layers (depth % pattern) run
unscanned. Supports:

  pattern elements: attn | swa | cross | ssm | rglru
  families: dense GQA (yi, qwen2, mistral-large, h2o-danube-SWA),
            MoE (grok-1, arctic + dense residual), encoder-only audio
            (hubert), VLM cross-attn (llama-3.2-vision), hybrid RG-LRU
            (recurrentgemma), SSD (mamba2).

Modality frontends are stubs per the assignment: audio/vlm `input_specs`
provide precomputed frame/patch embeddings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models.modules import (apply_mlp, apply_norm, cross_entropy,
                                  dtype_of, embed_tokens, init_embedding,
                                  init_linear, init_mlp, init_norm, lm_logits)


# ---------------------------------------------------------------- layer init
def _init_layer(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": init_norm(cfg, cfg.d_model)}
    if kind in ("attn", "swa", "cross"):
        p["attn"] = att.init_attn(ks[0], cfg, cross=(kind == "cross"))
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
    elif kind == "rglru":
        p["rec"] = rg.init_rglru(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "ssm" and cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg, cfg.d_model)
        if cfg.n_experts and kind in ("attn", "swa"):
            p["ffn"] = moe_mod.init_moe(ks[1], cfg)
            p["ffn_kind"] = "moe"
        else:
            p["ffn"] = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
            p["ffn_kind"] = "mlp"
    return {k: v for k, v in p.items() if k != "ffn_kind"}


def _rcast(cfg, y):
    """§Perf: pin branch outputs to the param dtype before the residual
    add — otherwise f32 from attention's accumulation einsums leaks into
    the residual stream and doubles TP-psum + activation bytes."""
    return y.astype(dtype_of(cfg)) if cfg.bf16_residual else y


def _apply_ffn(cfg, p, kind, x):
    h = apply_norm(cfg, p["norm2"], x)
    if cfg.n_experts and kind in ("attn", "swa"):
        return x + _rcast(cfg, moe_mod.moe_forward(cfg, p["ffn"], h))
    return x + _rcast(cfg, apply_mlp(cfg, p["ffn"], h))


def _apply_layer(cfg, p, kind, x, positions, encoder):
    h = apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "swa"):
        a = att.attn_forward(cfg, p["attn"], h, positions,
                             kind=("swa" if kind == "swa" else
                                   ("causal" if cfg.causal else "none")))
        x = x + _rcast(cfg, a)
    elif kind == "cross":
        a = att.attn_forward(cfg, p["attn"], h, positions, kind="cross",
                             encoder=encoder)
        x = x + _rcast(cfg, a)
    elif kind == "ssm":
        return x + _rcast(cfg, ssm_mod.ssm_forward(cfg, p["ssm"], h))
    elif kind == "rglru":
        x = x + _rcast(cfg, rg.rglru_forward(cfg, p["rec"], h))
    if "ffn" in p:
        x = _apply_ffn(cfg, p, kind, x)
    return x


# ---------------------------------------------------------------- model init
def init_model(key, cfg: ArchConfig):
    k_embed, k_stack, k_rem, k_head = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    if cfg.frontend == "audio":
        # frame embeddings come in directly; a small input projection stands
        # in for the (stubbed) conv feature extractor's final proj
        params["embed"] = {"in_proj": init_linear(k_embed, cfg, cfg.d_model,
                                                  cfg.d_model)}
    else:
        params["embed"] = init_embedding(k_embed, cfg)

    def init_super(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return {f"l{i}": _init_layer(kk[i], cfg, kind)
                for i, kind in enumerate(cfg.pattern)}

    keys = jax.random.split(k_stack, cfg.n_super)
    params["stack"] = jax.vmap(init_super)(keys)
    params["rem"] = [
        _init_layer(jax.random.fold_in(k_rem, i), cfg, cfg.pattern[i])
        for i in range(cfg.n_remainder)]
    params["final_norm"] = init_norm(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, cfg, cfg.d_model,
                                        cfg.vocab_size)
    return params


# ---------------------------------------------------------------- forward
def forward(params, cfg: ArchConfig, inputs, *, encoder=None):
    """inputs: int tokens (B,S) or embeddings (B,S,D) for audio frontends.
    Returns final hidden states (B,S,D)."""
    if cfg.frontend == "audio":
        from repro.models.modules import apply_linear
        x = apply_linear(params["embed"]["in_proj"],
                         inputs.astype(dtype_of(cfg)))
    else:
        x = embed_tokens(params["embed"], inputs)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.float32)

    def super_body(x, layer_params):
        for i, kind in enumerate(cfg.pattern):
            x = _apply_layer(cfg, layer_params[f"l{i}"], kind, x,
                             positions, encoder)
        return x, None

    body = super_body
    if cfg.remat:
        body = jax.checkpoint(super_body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["stack"],
                        unroll=min(cfg.scan_unroll, cfg.n_super))
    for i, p in enumerate(params["rem"]):
        x = _apply_layer(cfg, p, cfg.pattern[i], x, positions, encoder)
    return apply_norm(cfg, params["final_norm"], x)


def logits_fn(params, cfg, inputs, *, encoder=None):
    return lm_logits(cfg, params, forward(params, cfg, inputs,
                                          encoder=encoder))


def loss_fn(params, cfg, batch):
    enc = batch.get("image_embeds")
    inp = batch.get("frames") if cfg.frontend == "audio" else batch["tokens"]
    logits = logits_fn(params, cfg, inp, encoder=enc)
    return cross_entropy(logits, batch["targets"])


# ---------------------------------------------------------------- decode
def _cache_len(cfg, kind: str, seq_len: int) -> int:
    if kind == "swa":
        return min(seq_len, cfg.window)
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, *,
               n_frontend_tokens: int | None = None):
    """Decode cache pytree: per pattern position, stacked over super-layers."""
    dt = dtype_of(cfg)
    nimg = (n_frontend_tokens if n_frontend_tokens is not None
            else cfg.n_frontend_tokens)

    def one(kind):
        if kind in ("attn",):
            return att.init_kv_cache(cfg, batch, _cache_len(cfg, "attn",
                                                            seq_len), dt)
        if kind == "swa":
            return att.init_kv_cache(cfg, batch, _cache_len(cfg, "swa",
                                                            seq_len), dt)
        if kind == "cross":
            kh, hd = cfg.n_kv_heads, cfg.hd
            return {"ck": jnp.zeros((batch, nimg, kh, hd), dt),
                    "cv": jnp.zeros((batch, nimg, kh, hd), dt)}
        if kind == "ssm":
            return ssm_mod.init_ssm_cache(cfg, batch, dt)
        if kind == "rglru":
            return rg.init_rglru_cache(cfg, batch, dt)
        raise ValueError(kind)

    def stacked(kind):
        c = one(kind)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_super,) + a.shape), c)

    return {
        "stack": {f"l{i}": stacked(kind)
                  for i, kind in enumerate(cfg.pattern)},
        "rem": [one(cfg.pattern[i]) for i in range(cfg.n_remainder)],
    }


def _apply_layer_decode(cfg, p, kind, x, cache, pos):
    h = apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "swa"):
        a, cache = att.attn_decode(cfg, p["attn"], h, cache, pos,
                                   kind=("swa" if kind == "swa" else "causal"))
        x = x + a
    elif kind == "cross":
        a, _ = att.attn_decode(cfg, p["attn"], h, None, pos, kind="cross",
                               encoder_kv=(cache["ck"], cache["cv"]))
        x = x + a
    elif kind == "ssm":
        y, cache = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache)
        return x + y, cache
    elif kind == "rglru":
        y, cache = rg.rglru_decode(cfg, p["rec"], h, cache)
        x = x + y
    if "ffn" in p:
        x = _apply_ffn(cfg, p, kind, x)
    return x, cache


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    """One new token against the cache. token (B,1) int32 (or (B,1,D) for
    audio — unused: encoder-only archs have no decode). Returns
    (logits (B,1,V) f32, new cache)."""
    x = embed_tokens(params["embed"], token)

    def super_body(x, scanned):
        layer_params, layer_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, c = _apply_layer_decode(cfg, layer_params[f"l{i}"], kind, x,
                                       layer_cache[f"l{i}"], pos)
            new_caches[f"l{i}"] = c
        return x, new_caches

    x, new_stack = jax.lax.scan(super_body, x,
                                (params["stack"], cache["stack"]),
                                unroll=min(cfg.scan_unroll, cfg.n_super))
    new_rem = []
    for i, p in enumerate(params["rem"]):
        x, c = _apply_layer_decode(cfg, p, cfg.pattern[i], x,
                                   cache["rem"][i], pos)
        new_rem.append(c)
    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params, x), {"stack": new_stack, "rem": new_rem}


# ------------------------------------------------------- prefill with cache
def prefill_with_cache(params, cfg: ArchConfig, tokens, *, encoder=None,
                       cache_len: int | None = None):
    """Forward pass that also builds the decode cache (small-scale serving
    path used by the examples; the dry-run lowers forward/decode only)."""
    b, s = tokens.shape[0], tokens.shape[1]
    cache_len = cache_len or s
    cache = init_cache(cfg, b, cache_len,
                       n_frontend_tokens=(encoder.shape[1]
                                          if encoder is not None else 0))
    x = embed_tokens(params["embed"], tokens)
    positions = jnp.arange(s, dtype=jnp.float32)
    dt = dtype_of(cfg)

    def fill_kv(p, h, kind):
        from repro.models.modules import apply_linear
        k = apply_linear(p["attn"]["wk"], h)
        v = apply_linear(p["attn"]["wv"], h)
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        cos, sin = att.rope_freqs(cfg, positions)
        k = att.apply_rope(k, cos, sin)
        length = _cache_len(cfg, kind, cache_len)
        keep = min(s, length)
        slots = (jnp.arange(s - keep, s) % length)
        ck = jnp.zeros((b, length, cfg.n_kv_heads, cfg.hd), dt)
        cv = jnp.zeros((b, length, cfg.n_kv_heads, cfg.hd), dt)
        cpos = jnp.full((length,), -1, jnp.int32)
        ck = ck.at[:, slots].set(k[:, s - keep:])
        cv = cv.at[:, slots].set(v[:, s - keep:])
        cpos = cpos.at[slots].set(jnp.arange(s - keep, s, dtype=jnp.int32))
        return {"k": ck, "v": cv, "pos": cpos}

    def layer_with_cache(p, kind, x):
        h = apply_norm(cfg, p["norm1"], x)
        if kind in ("attn", "swa"):
            c = fill_kv(p, h, kind)
            a = att.attn_forward(cfg, p["attn"], h, positions,
                                 kind=("swa" if kind == "swa" else "causal"))
            x = x + a
        elif kind == "cross":
            c = dict(zip(("ck", "cv"),
                         att.precompute_cross_kv(cfg, p["attn"], encoder)))
            a = att.attn_forward(cfg, p["attn"], h, positions, kind="cross",
                                 encoder=encoder)
            x = x + a
        elif kind == "ssm":
            y, st = ssm_mod.ssm_forward(cfg, p["ssm"], h, return_state=True)
            d_in, _, _, n = ssm_mod._dims(cfg)
            conv_in_full = None  # conv tail reconstructed below
            zx = ssm_mod.apply_linear(p["ssm"]["in_proj"], h)
            _, xin, b_mat, c_mat, _ = jnp.split(
                zx, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], -1)
            conv_in = jnp.concatenate([xin, b_mat, c_mat], -1)
            tail = conv_in[:, -(cfg.ssm_conv - 1):]
            c = {"conv": tail.astype(dt), "state": st}
            return x + y, c
        elif kind == "rglru":
            y, hstate = rg.rglru_forward(cfg, p["rec"], h, return_state=True)
            zx = rg.apply_linear(p["rec"]["in_x"], h)
            tail = zx[:, -(cfg.ssm_conv - 1):]
            c = {"conv": tail.astype(dt), "h": hstate}
            x = x + y
        if "ffn" in p:
            x = _apply_ffn(cfg, p, kind, x)
        return x, c

    def super_body(x, layer_params):
        cs = {}
        for i, kind in enumerate(cfg.pattern):
            x, cs[f"l{i}"] = layer_with_cache(layer_params[f"l{i}"], kind, x)
        return x, cs

    x, stack_caches = jax.lax.scan(super_body, x, params["stack"])
    rem_caches = []
    for i, p in enumerate(params["rem"]):
        x, c = layer_with_cache(p, cfg.pattern[i], x)
        rem_caches.append(c)
    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params, x), {"stack": stack_caches,
                                       "rem": rem_caches}
