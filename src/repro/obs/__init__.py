"""Solve-wide observability: span tracer + unified metrics registry.

The paper's whole argument is I/O accounting — Table 3's 145 TB read /
4 TB written, and the §3.4.2 claim that SEM-SpMM hides SSD reads behind
compute. This package puts every layer's counters and timings on ONE
timeline:

  trace     nestable `span("operator.matmat")` context managers with a
            thread-safe in-process collector; exporters to JSONL and
            Chrome trace-event format (open in Perfetto / chrome://tracing);
  metrics   pull-based registry snapshotting the existing counter objects
            (`IOStats`, `PageCache`, `Prefetcher`, `WriteBehind`)
            uniformly, plus derived gauges (hit rate, overlap fraction,
            bytes/pass, write-behind backlog);
  progress  per-restart convergence events + an ETA estimator from
            restart-over-restart residual decay, fed through the solver
            `callback` seam;
  report    `python -m repro.obs.report TRACE` renders a human solve
            report; `--validate` gates the schema for CI.

Entry point: `core.solve(op, nev, method=..., trace=...)` installs a
tracer for the solve's duration and emits the full timeline with zero
solver-code changes. With tracing disabled every instrumentation point is
a no-op guard (a module-global None check), not a dropped feature.
"""
from repro.obs.trace import (NULL_SPAN, SCHEMA, Span, Tracer, active, event,
                             span, tracing)
from repro.obs.metrics import (MetricsRegistry, delta, derive, gauges,
                               snapshot_counters, snapshot_store)
from repro.obs.progress import ConvergenceTracker

__all__ = [
    "NULL_SPAN", "SCHEMA", "Span", "Tracer", "active", "event", "span",
    "tracing",
    "MetricsRegistry", "delta", "derive", "gauges", "snapshot_counters",
    "snapshot_store",
    "ConvergenceTracker",
]
