"""Pull-based metrics registry — one snapshot shape over every counter.

The repo accumulated four counter surfaces with four spellings:
`IOStats.as_dict()`, `Prefetcher.stats()`, `WriteBehind.stats_dict()`,
and the merged `SafsBackend.stats_dict()`. `snapshot_counters` reads any
of them (duck-typed — this module imports nothing from core/safs, so it
can sit below every layer without cycles); `MetricsRegistry` names a set
of sources and snapshots them all at once; `gauges` computes the derived
figures the paper argues with: cache hit rate, prefetch overlap fraction,
bytes/pass, write-behind backlog depth, write/read ratio (Table 3: 0.028).

`delta(before, after)` subtracts two snapshots recursively so a solve's
own traffic can be reported even on a shared, long-lived store; apply
`derive` to a delta to recompute ratio fields (a subtracted hit_rate is
meaningless — recompute it from the subtracted counts).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

# IOStats fields that are derived ratios, not raw counters: delta() must
# recompute them, never subtract them.
_DERIVED_FIELDS = ("hit_rate", "bytes_per_pass")


def snapshot_counters(obj: Any) -> Optional[dict]:
    """Uniform counter snapshot of any stats-bearing object: dicts pass
    through (copied); `stats_dict()` / `as_dict()` / callable `stats()`
    are tried in that order; an object exposing a `stats` attribute
    (TieredStore, PageCache) snapshots that attribute."""
    if obj is None:
        return None
    if isinstance(obj, dict):
        return dict(obj)
    for meth in ("stats_dict", "as_dict"):
        fn = getattr(obj, meth, None)
        if callable(fn):
            return fn()
    st = getattr(obj, "stats", None)
    if callable(st):
        return st()
    if st is not None and st is not obj:
        return snapshot_counters(st)
    raise TypeError(f"no counter surface on {type(obj).__name__!r} "
                    f"(need stats_dict/as_dict/stats)")


def snapshot_store(store) -> dict:
    """The standard per-store snapshot: logical tier traffic + the
    backend's merged physical-side counters."""
    return {"logical": snapshot_counters(store.stats),
            "device_bytes": store.device_bytes(),
            "backend": snapshot_counters(store.backend)}


def delta(before: Any, after: Any) -> Any:
    """Recursive numeric `after - before`. Non-numeric leaves (and leaves
    missing from `before`) keep `after`'s value; derived ratio fields are
    recomputed from the subtracted counters via `derive`."""
    if isinstance(before, dict) and isinstance(after, dict):
        out = {k: delta(before.get(k), v) for k, v in after.items()}
        if any(k in out for k in _DERIVED_FIELDS):
            out = derive(out)
        return out
    if (isinstance(before, (int, float)) and isinstance(after, (int, float))
            and not isinstance(before, bool) and not isinstance(after, bool)):
        return after - before
    return after


def derive(flat: dict) -> dict:
    """Recompute the derived gauges of an IOStats-shaped dict from its raw
    counters (use on `delta` output, where subtracted ratios are garbage).
    Only touches the fields whose inputs are present."""
    out = dict(flat)
    if "cache_hits" in out and "cache_misses" in out:
        hits, misses = out["cache_hits"], out["cache_misses"]
        out["hit_rate"] = hits / max(hits + misses, 1)
    if "pass_bytes_read" in out and "passes" in out:
        out["bytes_per_pass"] = (out["pass_bytes_read"]
                                 / max(out["passes"], 1))
    return out


def gauges(snap: dict) -> dict:
    """Derived figures off a `snapshot_store` snapshot (or a delta of
    two): the numbers the paper's Table 3 / §3.4.2 argue with."""
    logical = derive(snap.get("logical") or {})
    backend = snap.get("backend") or {}
    io = derive(backend.get("io") or {}) if isinstance(backend, dict) else {}
    pf = backend.get("prefetch") if isinstance(backend, dict) else None
    wb = backend.get("write_behind") if isinstance(backend, dict) else None
    busy = (pf or {}).get("busy_seconds", 0.0)
    return {
        "logical_hit_rate": logical.get("hit_rate"),
        "page_hit_rate": io.get("hit_rate"),
        "bytes_per_pass": logical.get("bytes_per_pass"),
        "passes": logical.get("passes"),
        "overlap_fraction": ((pf or {}).get("overlap_seconds", 0.0)
                             / busy if busy > 0 else 0.0),
        "wb_backlog_pages": (wb or {}).get("pending_pages", 0),
        "wb_peak_depth_pages": (wb or {}).get("max_depth_pages", 0),
        "write_read_ratio": (logical.get("host_bytes_written", 0)
                             / max(logical.get("host_bytes_read", 0), 1)),
    }


class MetricsRegistry:
    """Named pull-based sources snapshotted together.

    `register(name, obj_or_fn)` accepts either a zero-arg callable
    returning a dict or any object `snapshot_counters` understands.
    `snapshot()` never raises — a failing source reports its error in
    place so one dead counter cannot take down a solve epilogue."""

    def __init__(self):
        self._sources: Dict[str, Callable[[], Optional[dict]]] = {}

    def register(self, name: str, source: Any) -> None:
        if not callable(source):
            obj = source
            source = lambda: snapshot_counters(obj)  # noqa: E731
        self._sources[name] = source

    def unregister(self, name: str) -> None:
        self._sources.pop(name, None)

    def names(self) -> list:
        return sorted(self._sources)

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name in self.names():
            try:
                out[name] = self._sources[name]()
            except Exception as e:
                out[name] = {"error": f"{type(e).__name__}: {e}"}
        return out
