"""Restart telemetry → convergence events + ETA from residual decay.

Every solver in the family already exposes `callback(step, theta, res)`
(per restart for Krylov–Schur/svd, per iteration for LOBPCG, per
expansion for the Lanczos baseline). `ConvergenceTracker` consumes that
stream, records the theta/residual history, and emits one
"convergence.step" instant event per call into the installed tracer —
giving the exported timeline the third axis the ROADMAP's serving layer
needs: not just *where the time went* but *how far along the solve is*.

The ETA estimator assumes geometric residual decay — the right model for
a restarted Krylov method past its initial transient: the worst relative
residual r_k shrinks by a roughly constant factor per restart, so

    steps_remaining ≈ log(tol / r_k) / log(rho),

with rho the geometric-mean decay of the last `window` steps. Stagnation
(rho >= 1) and the pre-transient phase report no estimate rather than a
wrong one.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np


class ConvergenceTracker:
    """Feed `update(step, theta, res)` (the solver callback signature);
    reads back `history`, `eta_steps()`, and emits tracer events."""

    def __init__(self, tracer=None, *, tol: float = 1e-6, nev: int = 0,
                 method: str = "", window: int = 4):
        self.tracer = tracer
        self.tol = float(tol)
        self.nev = int(nev)
        self.method = method
        self.window = max(2, int(window))
        self.history: List[Tuple[int, float]] = []   # (step, worst rel res)
        self.theta_history: List[np.ndarray] = []

    # ------------------------------------------------------------- intake
    def update(self, step: int, theta, res) -> None:
        theta = np.asarray(theta, dtype=np.float64)
        res = np.asarray(res, dtype=np.float64)
        scale = np.maximum(1.0, np.abs(theta))
        finite = np.isfinite(res)
        rel = np.where(finite, res / scale, np.inf)
        r = float(np.max(rel)) if rel.size else math.inf
        self.history.append((int(step), r))
        self.theta_history.append(theta.copy())
        if self.tracer is not None:
            eta = self.eta_steps()
            self.tracer.event(
                "convergence.step", step=int(step), method=self.method,
                nev=self.nev, theta=theta.tolist(),
                res=[None if not np.isfinite(x) else float(x)
                     for x in res.tolist()],
                res_max_rel=None if math.isinf(r) else r,
                tol=self.tol, eta_steps=eta)

    # ---------------------------------------------------------- estimator
    def decay_rate(self) -> Optional[float]:
        """Geometric-mean per-step decay of the worst relative residual
        over the trailing window; None until two finite points exist."""
        pts = [(s, r) for s, r in self.history
               if math.isfinite(r) and r > 0.0]
        if len(pts) < 2:
            return None
        tail = pts[-self.window:]
        (s0, r0), (s1, r1) = tail[0], tail[-1]
        if s1 <= s0 or r0 <= 0.0:
            return None
        return (r1 / r0) ** (1.0 / (s1 - s0))

    def eta_steps(self) -> Optional[int]:
        """Estimated steps until the worst residual crosses tol, or None
        when no defensible estimate exists (stagnation, transient)."""
        if not self.history:
            return None
        r = self.history[-1][1]
        if not math.isfinite(r):
            return None
        if r <= self.tol:
            return 0
        rho = self.decay_rate()
        if rho is None or rho >= 1.0 or rho <= 0.0:
            return None
        return int(math.ceil(math.log(self.tol / r) / math.log(rho)))

    def chain(self, user_callback=None):
        """The callback to hand a solver: updates this tracker, then
        forwards to `user_callback` unchanged."""
        def cb(step, theta, res):
            self.update(step, theta, res)
            if user_callback is not None:
                user_callback(step, theta, res)
        return cb
