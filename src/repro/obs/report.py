"""Render a solve trace into a human report; `--validate` gates it for CI.

    python -m repro.obs.report TRACE.jsonl [--validate] [--chrome OUT.json]

Sections:

  phase breakdown   wall time / count / bytes per span name — where the
                    solve went (operator applies vs subspace passes vs
                    SAFS fill/evict/retire);
  I/O vs compute    the §3.4.2 overlap story: prefetch busy/wait/overlap
                    seconds and the overlap fraction, plus the summed
                    prefetch-wait spans (the *un*-hidden remainder);
  convergence       the per-restart theta/residual table with the decay
                    ETA ("convergence.step" events);
  reconciliation    the summed bytes of every `pass.subspace` span
                    checked byte-exactly against the solve's
                    `IOStats.pass_bytes_read` delta — the tracer and the
                    counters are two independent accountants of the same
                    traffic and must agree to the byte.

  integrity         checksum-verification counters vs their trace
                    events: `crc_failures` must equal the number of
                    `safs.corrupt` events, `scrub_passes` the number of
                    `safs.scrub` events and `pages_repaired` the number
                    of `safs.repair` events — every detection, pass and
                    repair is both counted and announced, exactly once.

`--validate` exits non-zero on: schema mismatch, zero spans, an overlap
fraction outside [0, 1], or (on a lossless trace with a metrics record) a
failed byte or integrity reconciliation.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Optional

from repro.obs.trace import SCHEMA, chrome_trace

PASS_SPAN = "pass.subspace"


def load(path: str) -> List[dict]:
    records = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: not JSON ({e})") from e
    return records


# ------------------------------------------------------------- accessors
def spans(records: List[dict]) -> List[dict]:
    return [r for r in records if r.get("type") == "span"]

def events(records: List[dict], name: str) -> List[dict]:
    return [r for r in records
            if r.get("type") == "event" and r.get("name") == name]

def metrics_records(records: List[dict]) -> List[dict]:
    return [r for r in records if r.get("type") == "metrics"]

def summary_record(records: List[dict]) -> Optional[dict]:
    for r in reversed(records):
        if r.get("type") == "summary":
            return r
    return None


def overlap_fractions(records: List[dict]) -> Dict[str, float]:
    """Every overlap fraction computable from the trace's metrics records
    (delta-of-solve preferred, end snapshot as fallback)."""
    out: Dict[str, float] = {}
    for i, m in enumerate(metrics_records(records)):
        data = m.get("data", {})
        for key in ("delta", "end"):
            snap = data.get(key)
            pf = ((snap or {}).get("backend") or {}).get("prefetch")
            if not pf:
                continue
            busy = pf.get("busy_seconds", 0.0)
            frac = (pf.get("overlap_seconds", 0.0) / busy) if busy > 0 else 0.0
            out[f"{m.get('name', 'metrics')}[{i}].{key}"] = frac
    return out


def reconcile(records: List[dict]) -> Optional[dict]:
    """Span-vs-IOStats pass accounting. Returns None when the trace has no
    solve metrics record to reconcile against."""
    delta_logical = None
    for m in metrics_records(records):
        d = m.get("data", {}).get("delta", {})
        if isinstance(d, dict) and "logical" in d:
            delta_logical = d["logical"]
    if delta_logical is None:
        return None
    span_bytes = 0
    span_count = 0
    for s in spans(records):
        if s["name"] == PASS_SPAN:
            span_count += 1
            span_bytes += int(s.get("args", {}).get("bytes", 0))
    summ = summary_record(records)
    lossless = summ is None or summ.get("dropped", 0) == 0
    return {
        "span_pass_count": span_count,
        "span_pass_bytes": span_bytes,
        "iostats_passes": delta_logical.get("passes"),
        "iostats_pass_bytes_read": delta_logical.get("pass_bytes_read"),
        "lossless": lossless,
        "exact": (span_count == delta_logical.get("passes")
                  and span_bytes == delta_logical.get("pass_bytes_read")),
    }


def integrity_reconcile(records: List[dict]) -> Optional[dict]:
    """Integrity counters vs corruption/scrub/repair trace events. Returns
    None when no metrics record carries a backend integrity block (ram
    backend, or a store without verify-on-read)."""
    integ = None
    for m in metrics_records(records):
        data = m.get("data", {})
        # prefer the absolute end snapshot: events count from process
        # start, and the backend is created inside the traced process
        for key in ("end", "delta"):
            cand = ((data.get(key) or {}).get("backend")
                    or {}).get("integrity")
            if isinstance(cand, dict):
                integ = cand
                break
    if integ is None:
        return None
    summ = summary_record(records)
    pairs = (("crc_failures", "safs.corrupt"),
             ("scrub_passes", "safs.scrub"),
             ("pages_repaired", "safs.repair"))
    out = {"lossless": summ is None or summ.get("dropped", 0) == 0}
    exact = True
    for counter, ev in pairs:
        got, want = integ.get(counter, 0), len(events(records, ev))
        out[counter] = got
        out[ev] = want
        exact = exact and got == want
    out["exact"] = exact
    return out


# ------------------------------------------------------------- validation
def validate(records: List[dict]) -> List[str]:
    """Schema/consistency problems, empty when the trace is good."""
    problems: List[str] = []
    if not records:
        return ["empty trace"]
    meta = records[0]
    if meta.get("type") != "meta":
        problems.append("first record is not a meta header")
    elif meta.get("schema") != SCHEMA:
        problems.append(f"schema {meta.get('schema')!r} != {SCHEMA!r}")
    n_spans = len(spans(records))
    if n_spans == 0:
        problems.append("no spans recorded")
    for s in spans(records):
        if s.get("dur", 0) < 0:
            problems.append(f"negative duration span {s['name']!r}")
            break
    for key, frac in overlap_fractions(records).items():
        if not (0.0 <= frac <= 1.0):
            problems.append(f"overlap fraction {key}={frac} outside [0, 1]")
    rec = reconcile(records)
    if rec is not None and rec["lossless"] and not rec["exact"]:
        problems.append(
            f"pass accounting mismatch: {rec['span_pass_count']} spans / "
            f"{rec['span_pass_bytes']} B vs IOStats "
            f"{rec['iostats_passes']} passes / "
            f"{rec['iostats_pass_bytes_read']} B")
    integ = integrity_reconcile(records)
    if integ is not None and integ["lossless"] and not integ["exact"]:
        problems.append(
            "integrity accounting mismatch: counters "
            f"crc_failures={integ['crc_failures']}/"
            f"scrub_passes={integ['scrub_passes']}/"
            f"pages_repaired={integ['pages_repaired']} vs events "
            f"safs.corrupt={integ['safs.corrupt']}/"
            f"safs.scrub={integ['safs.scrub']}/"
            f"safs.repair={integ['safs.repair']}")
    return problems


# --------------------------------------------------------------- rendering
def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def phase_table(records: List[dict]) -> List[tuple]:
    """(name, count, total_ms, total_bytes) per span name, by time desc."""
    agg: Dict[str, List[float]] = defaultdict(lambda: [0, 0.0, 0])
    for s in spans(records):
        a = agg[s["name"]]
        a[0] += 1
        a[1] += s.get("dur", 0.0) / 1e3
        b = s.get("args", {}).get("bytes")
        if isinstance(b, (int, float)):
            a[2] += b
    return sorted(((k, int(v[0]), v[1], int(v[2]))
                   for k, v in agg.items()), key=lambda r: -r[2])


def render(records: List[dict]) -> str:
    lines: List[str] = []
    meta = records[0] if records else {}
    summ = summary_record(records) or {}
    lines.append("== solve report ==")
    lines.append(f"schema {meta.get('schema')} · "
                 f"{summ.get('spans', len(spans(records)))} spans · "
                 f"{summ.get('events', 0)} events · "
                 f"{summ.get('dropped', 0)} dropped")

    lines.append("")
    lines.append("-- phase breakdown (by wall time) --")
    lines.append(f"{'span':<24} {'count':>7} {'total ms':>10} {'bytes':>12}")
    for name, count, ms, nbytes in phase_table(records):
        lines.append(f"{name:<24} {count:>7} {ms:>10.2f} "
                     f"{_fmt_bytes(nbytes) if nbytes else '-':>12}")

    fracs = overlap_fractions(records)
    wait_ms = sum(s.get("dur", 0.0) for s in spans(records)
                  if s["name"] == "safs.prefetch_wait") / 1e3
    fill_ms = sum(s.get("dur", 0.0) for s in spans(records)
                  if s["name"] == "safs.fill") / 1e3
    lines.append("")
    lines.append("-- I/O vs compute (§3.4.2) --")
    if fracs:
        for key, frac in fracs.items():
            lines.append(f"overlap fraction {key}: {frac:.3f}")
    else:
        lines.append("no prefetch metrics in trace")
    lines.append(f"prefetch fill time {fill_ms:.2f} ms on workers; "
                 f"un-hidden wait {wait_ms:.2f} ms on the consumer")

    conv = events(records, "convergence.step")
    lines.append("")
    lines.append("-- convergence --")
    if conv:
        lines.append(f"{'step':>5} {'worst rel res':>14} {'theta[0]':>12} "
                     f"{'eta steps':>10}")
        for e in conv:
            a = e.get("args", {})
            r = a.get("res_max_rel")
            th = (a.get("theta") or [None])[0]
            eta = a.get("eta_steps")
            lines.append(
                f"{a.get('step', '?'):>5} "
                f"{('%.3e' % r) if r is not None else 'inf':>14} "
                f"{('%.6f' % th) if th is not None else '-':>12} "
                f"{eta if eta is not None else '-':>10}")
    else:
        lines.append("no convergence events in trace")

    rec = reconcile(records)
    lines.append("")
    lines.append("-- pass-byte reconciliation (spans vs IOStats) --")
    if rec is None:
        lines.append("no solve metrics record in trace")
    else:
        lines.append(
            f"spans: {rec['span_pass_count']} passes / "
            f"{_fmt_bytes(rec['span_pass_bytes'])}; IOStats: "
            f"{rec['iostats_passes']} passes / "
            f"{_fmt_bytes(rec['iostats_pass_bytes_read'] or 0)} → "
            + ("EXACT" if rec["exact"] else
               ("MISMATCH" if rec["lossless"] else "lossy trace, skipped")))

    integ = integrity_reconcile(records)
    lines.append("")
    lines.append("-- integrity (counters vs trace events) --")
    if integ is None:
        lines.append("no integrity metrics in trace")
    else:
        lines.append(
            f"corrupt {integ['crc_failures']}/{integ['safs.corrupt']} · "
            f"scrub passes {integ['scrub_passes']}/{integ['safs.scrub']} · "
            f"repairs {integ['pages_repaired']}/{integ['safs.repair']} → "
            + ("EXACT" if integ["exact"] else
               ("MISMATCH" if integ["lossless"] else
                "lossy trace, skipped")))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render/validate a repro.obs JSONL trace")
    ap.add_argument("trace", help="JSONL trace (Tracer.write_jsonl)")
    ap.add_argument("--validate", action="store_true",
                    help="exit non-zero on schema/consistency problems")
    ap.add_argument("--chrome", default=None, metavar="OUT.json",
                    help="also write a Chrome trace-event conversion")
    args = ap.parse_args(argv)
    records = load(args.trace)
    print(render(records))
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(chrome_trace(records), f)
        print(f"\nchrome trace written to {args.chrome} "
              f"(open in https://ui.perfetto.dev)")
    if args.validate:
        problems = validate(records)
        if problems:
            print("\nVALIDATION FAILED:", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print("\nvalidation OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
