"""Low-overhead span tracer — one timeline across solver, store and SAFS.

Design constraints, in order:

  1. *Disabled must be free.* Every instrumentation point in the hot paths
     (`TieredStore.get`, `SubspacePass.run`, SAFS fill/evict/retire) calls
     the module-level `span()` / `event()`; with no tracer installed these
     are a global None-check returning a shared no-op singleton — no
     allocation beyond the kwargs dict, no locking, no clock reads.
  2. *Threads are first-class.* SAFS does its real work off-thread (the
     readahead pool fills pages, the write-behind drain retires batches);
     spans record which thread they ran on so the exported timeline shows
     disk work genuinely overlapping foreground compute. One lock guards
     the record list; thread idents map to small stable tids.
  3. *Machine-readable first.* Records are plain dicts with a stable
     schema (`repro.obs/v1`); `write_jsonl` is the system-of-record
     export (validated by `repro.obs.report --validate`), `write_chrome`
     converts the same records to Chrome trace-event JSON for Perfetto /
     chrome://tracing.

Timestamps are microseconds from the tracer's construction
(`time.perf_counter` deltas — monotonic, sub-µs); the meta record carries
the wall-clock epoch for humans.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Any, Dict, List, Optional

SCHEMA = "repro.obs/v1"


def _jsonable(o: Any):
    """json.dumps default hook: numpy scalars/arrays → python, else str."""
    item = getattr(o, "item", None)
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    tolist = getattr(o, "tolist", None)
    if callable(tolist):
        return tolist()
    return str(o)


class _NullSpan:
    """Shared no-op span returned when no tracer is installed."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One timed region. Use as a context manager; `set(**attrs)` attaches
    attributes discovered during the region (bytes read, pages evicted)."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self._tracer._record_span(self.name, self._t0, t1, self.args)
        return False


class Tracer:
    """Thread-safe in-process collector of spans / events / metric dumps.

    `max_records` bounds memory: past it, new records are counted in
    `dropped` instead of stored (the summary record reports the count, and
    the report's byte-exact reconciliation refuses to run on a lossy
    trace).
    """

    def __init__(self, *, max_records: int = 1_000_000):
        self.max_records = int(max_records)
        self.dropped = 0
        self._lock = threading.Lock()
        self._records: List[dict] = []
        self._tids: Dict[int, int] = {}     # thread ident -> small tid
        self._tnames: Dict[int, str] = {}   # tid -> thread name
        self._epoch_perf = time.perf_counter()
        self._epoch_unix = time.time()

    # ---------------------------------------------------------- recording
    def _us(self, t: float) -> float:
        return (t - self._epoch_perf) * 1e6

    def _append(self, rec: dict) -> None:
        with self._lock:
            if len(self._records) >= self.max_records:
                self.dropped += 1
                return
            ident = threading.get_ident()
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
                self._tnames[tid] = threading.current_thread().name
            rec["tid"] = tid
            self._records.append(rec)

    def _record_span(self, name: str, t0: float, t1: float,
                     args: dict) -> None:
        self._append({"type": "span", "name": name, "ts": self._us(t0),
                      "dur": (t1 - t0) * 1e6, "args": args})

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """Zero-duration instant (announcements, convergence points)."""
        self._append({"type": "event", "name": name,
                      "ts": self._us(time.perf_counter()), "args": attrs})

    def metric(self, name: str, data: dict) -> None:
        """A structured counter snapshot pinned to the timeline (the solve
        epilogue records the store/backend deltas here; the report's
        reconciliation reads it back)."""
        self._append({"type": "metrics", "name": name,
                      "ts": self._us(time.perf_counter()), "data": data})

    # ------------------------------------------------------------- export
    def records(self) -> List[dict]:
        with self._lock:
            return list(self._records)

    def counts(self) -> dict:
        with self._lock:
            by_type: Dict[str, int] = {}
            for r in self._records:
                by_type[r["type"]] = by_type.get(r["type"], 0) + 1
            return {"spans": by_type.get("span", 0),
                    "events": by_type.get("event", 0),
                    "metrics": by_type.get("metrics", 0),
                    "dropped": self.dropped}

    def export_records(self) -> List[dict]:
        """meta header + records + summary footer — the JSONL layout."""
        with self._lock:
            recs = list(self._records)
            threads = {str(t): n for t, n in self._tnames.items()}
            dropped = self.dropped
        by_type: Dict[str, int] = {}
        for r in recs:
            by_type[r["type"]] = by_type.get(r["type"], 0) + 1
        meta = {"type": "meta", "schema": SCHEMA, "unit": "us",
                "epoch_unix": self._epoch_unix, "threads": threads}
        summary = {"type": "summary", "spans": by_type.get("span", 0),
                   "events": by_type.get("event", 0),
                   "metrics": by_type.get("metrics", 0), "dropped": dropped}
        return [meta] + recs + [summary]

    def write_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for rec in self.export_records():
                f.write(json.dumps(rec, default=_jsonable) + "\n")
        return path

    def write_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(chrome_trace(self.export_records()), f,
                      default=_jsonable)
        return path


def chrome_trace(records: List[dict]) -> dict:
    """Convert exported records to the Chrome trace-event format (load the
    file in https://ui.perfetto.dev or chrome://tracing). Spans become
    complete ("X") events, events instants ("i"), metric snapshots ride as
    instants with their data in args; thread names come from the meta
    record."""
    evs: List[dict] = []
    threads: Dict[str, str] = {}
    for r in records:
        t = r.get("type")
        if t == "meta":
            threads = r.get("threads", {})
        elif t == "span":
            evs.append({"name": r["name"], "ph": "X", "ts": r["ts"],
                        "dur": r["dur"], "pid": 0, "tid": r.get("tid", 0),
                        "args": r.get("args", {})})
        elif t == "event":
            evs.append({"name": r["name"], "ph": "i", "s": "t",
                        "ts": r["ts"], "pid": 0, "tid": r.get("tid", 0),
                        "args": r.get("args", {})})
        elif t == "metrics":
            evs.append({"name": r["name"], "ph": "i", "s": "p",
                        "ts": r["ts"], "pid": 0, "tid": r.get("tid", 0),
                        "args": r.get("data", {})})
    for tid, name in threads.items():
        evs.append({"name": "thread_name", "ph": "M", "pid": 0,
                    "tid": int(tid), "args": {"name": name}})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


# ------------------------------------------------------------ module state
# One installed tracer per process. Instrumentation points call the
# module-level span()/event(); the None fast path is the whole cost of a
# disabled build.
_TRACER: Optional[Tracer] = None


def install(tracer: Tracer) -> Tracer:
    global _TRACER
    _TRACER = tracer
    return tracer


def uninstall() -> None:
    global _TRACER
    _TRACER = None


def active() -> Optional[Tracer]:
    return _TRACER


@contextlib.contextmanager
def tracing(tracer: Tracer):
    """Install `tracer` for the block's duration, restoring whatever was
    installed before (solves nest; background threads started inside the
    block record into the same tracer)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    try:
        yield tracer
    finally:
        _TRACER = prev


def span(name: str, **attrs):
    """A span against the installed tracer, or the shared no-op when
    tracing is disabled."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, **attrs)


def event(name: str, **attrs) -> None:
    t = _TRACER
    if t is not None:
        t.event(name, **attrs)
