"""repro.optim"""
