"""AdamW in pure JAX with ZeRO-1-style sharding hooks.

Optimizer state shardings are derived from param shardings but spread over
the 'data' axis too (`zero1_sharding`) so the m/v moments never replicate —
the LM-side application of the paper's tiering discipline (big read-mostly
state lives spread out / offloaded; see train/trainer.py host_offload)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def update(state: AdamWState, grads, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def zero1_sharding(param_sharding: NamedSharding, mesh) -> NamedSharding:
    """Spread an optimizer-state tensor over the 'data' axis on top of the
    param's spec: the first dimension not already sharded that divides the
    data axis gets it. Falls back to the param's sharding."""
    spec = list(param_sharding.spec) if param_sharding.spec else []
    return NamedSharding(mesh, P(*spec))  # conservative default; the
    # trainer calls shard_opt_specs() below for the real spreading.


def shard_opt_spec(param_spec: P, shape, mesh, data_axis: str = "data") -> P:
    """ZeRO-1: add the data axis to the first unsharded, divisible dim
    (or stack it onto a model-sharded dim). No-op if the param's spec
    already consumes the data axis (FSDP archs)."""
    spec = list(param_spec) + [None] * (len(shape) - len(param_spec))

    def axes_of(s):
        if s is None:
            return ()
        return s if isinstance(s, tuple) else (s,)
    used = {a for s in spec for a in axes_of(s)}
    if data_axis in used:
        return P(*spec)
    dsize = mesh.shape[data_axis]
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % dsize == 0 and dim >= dsize:
            spec[i] = data_axis
            return P(*spec)
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is not None and not isinstance(s, tuple):
            total = dsize * mesh.shape[s]
            if dim % total == 0:
                spec[i] = (s, data_axis)
                return P(*spec)
    return P(*spec)


def global_norm_clip(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm
