"""repro.safs — file-backed SAFS page store (paper §3.4.1–§3.4.4).

See README.md in this directory for the paper mapping.
"""
from repro.safs.pagefile import PAGE_SIZE, CrashPoint, PageFile
from repro.safs.cache import PageCache
from repro.safs.prefetch import Prefetcher
from repro.safs.backend import (RamBackend, SafsBackend, StorageBackend,
                                make_backend)

__all__ = [
    "PAGE_SIZE", "CrashPoint", "PageFile", "PageCache", "Prefetcher",
    "RamBackend", "SafsBackend", "StorageBackend", "make_backend",
]
