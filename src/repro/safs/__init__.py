"""repro.safs — file-backed SAFS page store (paper §3.4.1–§3.4.4).

See README.md in this directory for the paper mapping.
"""
from repro.safs.pagefile import (PAGE_SIZE, CrashPoint, PageFile,
                                 coalesce_runs, flip_bit, page_crc)
from repro.safs.cache import PageCache, WriteBehind, WriteBehindError
from repro.safs.prefetch import PrefetchError, Prefetcher
from repro.safs.faults import (DEFAULT_RETRY, CorruptPageError, FaultPlan,
                               FaultRule, IntegrityCounters, RetryPolicy,
                               SafsIOError, TransientIOError,
                               is_transient, with_retries)
from repro.safs.backend import (RamBackend, SafsBackend, StorageBackend,
                                make_backend)
from repro.safs.scrub import (Scrubber, newest_verified_step,
                              repair_from_checkpoint)

__all__ = [
    "PAGE_SIZE", "CrashPoint", "PageFile", "coalesce_runs",
    "flip_bit", "page_crc",
    "PageCache", "WriteBehind", "WriteBehindError",
    "PrefetchError", "Prefetcher",
    "DEFAULT_RETRY", "CorruptPageError", "FaultPlan", "FaultRule",
    "IntegrityCounters", "RetryPolicy",
    "SafsIOError", "TransientIOError", "is_transient", "with_retries",
    "RamBackend", "SafsBackend", "StorageBackend", "make_backend",
    "Scrubber", "newest_verified_step", "repair_from_checkpoint",
]
