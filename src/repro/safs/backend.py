"""Storage backends for the slow tier — `ram` (emulated) or `safs` (files).

`TieredStore` owns *policy* (tier residency, LRU demotion, write-avoidance,
logical byte accounting); a `StorageBackend` owns *mechanism* — where the
slow-tier bytes physically live. Two implementations:

  * `RamBackend` — numpy buffers in host memory: exactly the seed repo's
    emulation, still the default for tier-1 tests (fast, no filesystem);
  * `SafsBackend` — the paper's layer: one PageFile per data_id under a
    root directory, fronted by a shared LRU `PageCache` with async
    write-behind demotions, and a multi-worker readahead `Prefetcher`
    that overlaps page reads with compute. All disk reads go through the
    batched vectored engine (`PageFile.read_pages_batch`: coalesced
    preadv runs — one syscall per run, not per 4 KiB page). Its `stats`
    count *actual disk traffic* (endurance), which is ≤ the logical tier
    traffic TieredStore counts whenever the page cache absorbs re-reads —
    the paper's Table-3 gap, measurable.

Select per store:  `TieredStore(backend="safs", backend_opts={"root": dir})`
or pass a constructed backend instance (shared across stores if desired).
Throughput knobs (see bench_safs.py / BENCH_safs.json for their effect):
`io_workers` (readahead pool size), `readahead_depth` (files queued ahead),
`write_behind` (async demotions; `wb_max_pages` bounds the queue).
"""
from __future__ import annotations

import os
import threading
import urllib.parse
from typing import Dict, Iterable, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.tiered import IOStats, ns_of
from repro.obs import trace
from repro.safs.cache import PageCache, WriteBehind
from repro.safs.faults import (DEFAULT_RETRY, FaultPlan, IntegrityCounters,
                               RetryPolicy)
from repro.safs.pagefile import PAGE_SIZE, PageFile
from repro.safs.prefetch import PrefetchError, Prefetcher


@runtime_checkable
class StorageBackend(Protocol):
    """Mechanism interface for the slow tier (see module docstring)."""

    stats: IOStats

    def store(self, data_id: str, arr: np.ndarray) -> None: ...
    def load(self, data_id: str) -> np.ndarray: ...
    def delete(self, data_id: str) -> None: ...
    def has(self, data_id: str) -> bool: ...
    def pin(self, data_id: str) -> None: ...
    def unpin(self, data_id: str) -> None: ...
    def prefetch(self, data_ids: Iterable[str]) -> None: ...
    def flush(self) -> None: ...
    def close(self) -> None: ...
    def stats_dict(self) -> dict: ...


# ------------------------------------------------------------ ns accounting
class _NsIO:
    """Per-namespace physical-I/O splits for a shared backend. Every byte
    the backend reads from / writes to the medium is attributed to the
    owning session (`ns_of(data_id)`; un-namespaced ids bucket under
    "_shared"), so per-namespace sums reconcile exactly against the
    backend's global IOStats — the invariant the serve report asserts."""

    SHARED = "_shared"

    def __init__(self):
        self._lock = threading.Lock()
        self._stats: Dict[str, IOStats] = {}

    def add(self, data_id: str, **deltas: int) -> None:
        ns = ns_of(data_id) or self.SHARED
        with self._lock:
            st = self._stats.get(ns)
            if st is None:
                st = self._stats[ns] = IOStats()
        st.add(**deltas)

    def as_dict(self) -> Dict[str, dict]:
        with self._lock:
            return {ns: st.as_dict() for ns, st in self._stats.items()}


# ---------------------------------------------------------------- ram
class RamBackend:
    """Host-DRAM slow tier — the seed emulation, byte-accounted."""

    def __init__(self):
        self.stats = IOStats()
        self.ns_io = _NsIO()
        self._bufs: Dict[str, np.ndarray] = {}

    def store(self, data_id: str, arr: np.ndarray) -> None:
        a = np.asarray(arr)
        self._bufs[data_id] = a
        self.stats.add(host_bytes_written=a.nbytes, host_writes=1)
        self.ns_io.add(data_id, host_bytes_written=a.nbytes, host_writes=1)

    def load(self, data_id: str) -> np.ndarray:
        a = self._bufs[data_id]
        self.stats.add(host_bytes_read=a.nbytes, host_reads=1)
        self.ns_io.add(data_id, host_bytes_read=a.nbytes, host_reads=1)
        return a

    def delete(self, data_id: str) -> None:
        self._bufs.pop(data_id, None)

    def drop_namespace(self, session_id: str) -> None:
        # entries are deleted per-id by the store; nothing else to reclaim
        pass

    def has(self, data_id: str) -> bool:
        return data_id in self._bufs

    def pin(self, data_id: str) -> None:        # no cache to pin in
        pass

    def unpin(self, data_id: str) -> None:
        pass

    def prefetch(self, data_ids) -> None:       # RAM is already "resident"
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self._bufs.clear()

    def stats_dict(self) -> dict:
        """Merged snapshot, same shape as SafsBackend's (absent subsystems
        report None so consumers need no backend-type dispatch)."""
        return {"io": self.stats.as_dict(), "cache": None, "prefetch": None,
                "write_behind": None, "integrity": None,
                "namespaces": self.ns_io.as_dict()}


# ---------------------------------------------------------------- safs
class SafsBackend:
    """File-backed slow tier: PageFiles + shared page cache + readahead
    pool + async write-behind demotions."""

    def __init__(self, root: str, *, page_size: int = PAGE_SIZE,
                 cache_bytes: int = 64 << 20, use_mmap: bool = False,
                 enable_prefetch: bool = True, io_workers: int = 2,
                 readahead_depth: int = 8, write_behind: bool = True,
                 wb_max_pages: int = 4096, pin_pages: bool = True,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = DEFAULT_RETRY,
                 verify_reads: bool = True):
        self.root = root
        self.page_size = int(page_size)
        self.use_mmap = use_mmap
        self.enable_prefetch = enable_prefetch
        # verify_reads: CRC-check every page served off the medium against
        # its sidecar checksum block; detections quarantine the page and
        # raise CorruptPageError instead of serving rotten bytes upward
        self.verify_reads = bool(verify_reads)
        self.integrity = IntegrityCounters()
        self._quarantine: set = set()          # {(data_id, page)}
        # pin_pages=False degrades the cache to plain LRU (no §3.4.4
        # most-recent-matrix pin) — the measured baseline in bench_safs
        self.pin_pages = bool(pin_pages)
        # faults: a seeded repro.safs.faults.FaultPlan consulted at every
        # I/O boundary (tests script failure interleavings with it; the
        # solver checkpointer discovers it here for its own sites).
        # retry: transient-error policy applied to every preadv/pwritev
        # chunk and write-behind retire; retries are counted in
        # stats.retries and emitted as safs.retry trace events.
        self.faults = faults
        self.retry = retry
        os.makedirs(root, exist_ok=True)
        self._files: Dict[str, PageFile] = {}
        self._lock = threading.RLock()
        self.cache = PageCache(cache_bytes, self.page_size, self._writeback)
        self.stats = self.cache.stats      # shared: byte-exact disk traffic
        self.ns_io = _NsIO()               # per-session physical splits
        self.writebehind: Optional[WriteBehind] = None
        if write_behind:
            self.writebehind = WriteBehind(self._writeback_sync,
                                           max_pages=wb_max_pages,
                                           stats=self.stats,
                                           retry=retry, faults=faults,
                                           on_retry=self._count_retry)
        self.prefetcher = Prefetcher(self._fill, io_workers=io_workers,
                                     depth=readahead_depth,
                                     on_retry=self._count_retry)
        self._reopen()

    def _count_retry(self, **kw) -> None:
        """on_retry sink for every retry site (page files, write-behind,
        prefetch workers): one IOStats counter, so `stats_dict()["io"]
        ["retries"]` reconciles 1:1 with the `safs.retry` trace events;
        `retry_sleep_ms` accumulates the backoff actually slept (bounded
        per operation by RetryPolicy.max_total_sleep)."""
        self.stats.add(retries=1,
                       retry_sleep_ms=float(kw.get("slept_ms", 0.0)))

    def _note_corrupt(self, data_id: str, **kw) -> None:
        """on_corrupt sink: quarantine the page (the PageFile already
        counted crc_failures and emitted the safs.corrupt event)."""
        with self._lock:
            self._quarantine.add((data_id, int(kw.get("page") or 0)))

    def _open_pagefile(self, path: str, data_id: Optional[str] = None,
                       **kw) -> PageFile:
        if data_id is None:
            data_id = self._unpath(os.path.basename(path))
        return PageFile(path, use_mmap=self.use_mmap, faults=self.faults,
                        retry=self.retry, on_retry=self._count_retry,
                        verify=self.verify_reads, integrity=self.integrity,
                        on_corrupt=lambda **c: self._note_corrupt(data_id,
                                                                  **c),
                        **kw)

    # ------------------------------------------------------------- naming
    def _path(self, data_id: str) -> str:
        """Namespaced ids live one subdirectory down (`root/<sid>/`) so a
        session's page files are enumerable and reclaimable as a unit; the
        file NAME stays the quoted full id either way, so basename-keyed
        consumers (checkpoint page snapshots, `_reopen`) need no namespace
        dispatch."""
        ns = ns_of(data_id)
        sub = self.root
        if ns:
            sub = os.path.join(self.root, urllib.parse.quote(ns, safe=""))
            os.makedirs(sub, exist_ok=True)
        return os.path.join(sub,
                            urllib.parse.quote(data_id, safe="") + ".pages")

    def _unpath(self, fname: str) -> str:
        return urllib.parse.unquote(fname[:-len(".pages")])

    def _reopen(self) -> None:
        """Adopt page files already in root (checkpoint-restore path) —
        root itself plus one level of per-namespace subdirs."""
        dirs = [self.root]
        for d in sorted(os.listdir(self.root)):
            p = os.path.join(self.root, d)
            if os.path.isdir(p):
                dirs.append(p)
        for dirpath in dirs:
            for f in sorted(os.listdir(dirpath)):
                if f.endswith(".pages") and os.path.exists(
                        os.path.join(dirpath, f + ".meta")):
                    data_id = self._unpath(f)
                    self._files[data_id] = self._open_pagefile(
                        os.path.join(dirpath, f))

    def pagefile(self, data_id: str) -> PageFile:
        return self._files[data_id]

    def data_ids(self):
        with self._lock:
            return list(self._files)

    # ------------------------------------------------------------- plumbing
    def _writeback_sync(self, data_id: str, pages: Dict[int, bytes]) -> int:
        with self._lock:
            pf = self._files.get(data_id)
        if pf is None:      # deleted while the batch sat in the queue
            return 0
        written = pf.write_pages(pages)
        if written:
            # every physical write (sync evict/flush AND async retire)
            # funnels through here — the one choke point where the owning
            # session's split can be advanced in lockstep with the bytes
            self.ns_io.add(data_id, host_bytes_written=written,
                           host_writes=1)
        return written

    def _writeback(self, data_id: str, pages: Dict[int, bytes]) -> int:
        """Cache demotion sink: async via the write-behind queue when
        enabled (returns 0 — the queue accounts the bytes at retire),
        synchronous journaled write otherwise."""
        if self.writebehind is not None:
            return self.writebehind.submit(data_id, pages)
        return self._writeback_sync(data_id, pages)

    def _stage_page(self, data_id: str, i: int) -> Optional[bytes]:
        """A page's newest bytes short of the disk (never stale disk
        bytes). Freshness order: dirty cache line > write-behind queue >
        clean cache line — a *clean* line can be a stale disk fill that
        raced a concurrent evict-into-queue, so queued bytes beat it."""
        got = self.cache.get(data_id, i, with_dirty=True)
        # the emptiness probe is only safe *after* the cache lookup: an
        # eviction publishes its queue insert before the cache lock drops
        if got is not None:
            data, dirty = got
            if (dirty or self.writebehind is None
                    or self.writebehind.empty()):
                return data
            wb = self.writebehind.lookup(data_id, i)
            return data if wb is None else wb
        if self.writebehind is not None and not self.writebehind.empty():
            data = self.writebehind.lookup(data_id, i)
            if data is not None:
                self.cache.put(data_id, i, data, dirty=False)
            return data
        return None

    def _fill_read(self, data_id: str, nbytes: int) -> None:
        """Account one physical disk read: the shared cache IOStats plus
        the owning session's split (all three fill sites route here)."""
        self.cache.fill_bytes_read(nbytes)
        self.ns_io.add(data_id, host_bytes_read=nbytes, host_reads=1)

    def _fill(self, data_id: str) -> int:
        """Batched cache fill: every non-resident page of data_id, read as
        coalesced vectored runs (one preadv per run). Runs on the
        readahead workers; pread keeps it safe vs the consumer."""
        with trace.span("safs.fill", file=data_id) as sp:
            n = self._fill_inner(data_id)
            sp.set(bytes=n)
            return n

    def _fill_inner(self, data_id: str) -> int:
        with self._lock:
            pf = self._files.get(data_id)
        if pf is None:
            return 0
        # generation captured BEFORE the staleness probes: a submit that
        # precedes the capture is necessarily still queued when the probe
        # below runs (retire follows our disk read in any stale
        # interleaving), so the probe catches it; one that follows the
        # capture fails the post-insert compare. Capturing after the
        # probes would leave a window where a submit lands in between and
        # both checks pass on stale bytes.
        gen0 = (self.writebehind.generation(data_id)
                if self.writebehind is not None else 0)
        wb = (self.writebehind
              if self.writebehind is not None and not self.writebehind.empty()
              else None)
        missing = []
        for i in pf.page_indices():
            if self.cache.peek(data_id, i):
                continue
            if wb is not None and wb.lookup(data_id, i) is not None:
                continue               # disk copy is stale; skip
            missing.append(i)
        if not missing:
            return 0
        n = 0
        for i, data in pf.read_pages_batch(missing).items():
            n += len(data)
            if self.writebehind is None:
                self.cache.put(data_id, i, data, dirty=False)
                continue
            if self.writebehind.lookup(data_id, i) is not None:
                continue   # dirtied + evicted while we read: ours is stale
            # insert only if no evict for this file landed inside our
            # read window: the queue entry may have already RETIRED
            # (lookup misses it while the disk already holds newer
            # bytes), so only an unchanged submit generation proves the
            # fill fresh. The check-and-insert is atomic — a stale line
            # must never be published, even transiently. A refused fill
            # costs nothing here: prefetch returns no bytes, and the
            # consumer's load re-reads.
            self.cache.put_clean_if(
                data_id, i, data,
                lambda: self.writebehind.generation(data_id) == gen0)
        self._fill_read(data_id, n)
        return n

    # ------------------------------------------------------------- protocol
    def store(self, data_id: str, arr: np.ndarray) -> None:
        a = np.ascontiguousarray(arr)
        with self._lock:
            pf = self._files.get(data_id)
            mismatch = pf is not None and (pf.shape != a.shape
                                           or pf.dtype != a.dtype)
        if mismatch:
            # outside the lock: delete's discard waits out an in-flight
            # write-behind batch whose writer needs this lock (deadlock)
            self.delete(data_id)
        with self._lock:
            pf = self._files.get(data_id)
            if pf is None:
                pf = self._open_pagefile(self._path(data_id),
                                         page_size=self.page_size,
                                         shape=a.shape, dtype=a.dtype.name)
                self._files[data_id] = pf
        for i, payload in pf.split(a).items():
            self.cache.put(data_id, i, payload, dirty=True)

    def load(self, data_id: str) -> np.ndarray:
        try:
            self.prefetcher.wait(data_id)
        except PrefetchError:
            pass    # fall through: the batched miss path below re-reads
        with self._lock:
            pf = self._files[data_id]
        # generation captured BEFORE the _stage_page probes — see _fill
        # for why capture-after-probe leaves a stale-fill window
        gen0 = (self.writebehind.generation(data_id)
                if self.writebehind is not None else 0)
        pages: Dict[int, bytes] = {}
        missing = []
        for i in pf.page_indices():
            data = self._stage_page(data_id, i)
            if data is None:
                missing.append(i)
            else:
                pages[i] = data
        if missing:       # one coalesced vectored read for all misses
            filled = pf.read_pages_batch(missing)
            self._fill_read(data_id, sum(len(d) for d in filled.values()))
            for i, data in filled.items():
                if self.writebehind is None:
                    self.cache.put(data_id, i, data, dirty=False)
                    pages[i] = data
                    continue
                wb = self.writebehind.lookup(data_id, i)
                if wb is not None:       # evicted into the queue mid-read
                    pages[i] = wb
                    continue
                if self.cache.put_clean_if(
                        data_id, i, data,
                        lambda: self.writebehind.generation(data_id)
                        == gen0):
                    pages[i] = data
                    continue
                # insert refused: an evict for this file raced our read
                # window (see _fill — the queue entry may have already
                # retired, so lookup alone cannot prove freshness).
                # Retry optimistically: serve the queue's bytes if the
                # entry is still pending, else re-read the page under
                # its own generation capture — a retire made the disk
                # fresh, and a *further* racing evict re-fails the
                # capture and loops. The fresh bytes are left uncached
                # (caching would need yet another guard round; an
                # uncached page merely costs a re-read).
                while True:
                    gen1 = self.writebehind.generation(data_id)
                    wb = self.writebehind.lookup(data_id, i)
                    if wb is not None:
                        pages[i] = wb
                        break
                    data = pf.read_pages_batch([i])[i]
                    self._fill_read(data_id, len(data))
                    if self.writebehind.generation(data_id) == gen1:
                        pages[i] = data
                        break
        return pf.assemble(pages)

    def delete(self, data_id: str) -> None:
        # discard first (it waits out an in-flight batch), THEN unmap the
        # file — so the drain thread never writes into a vanished id
        if self.writebehind is not None:
            self.writebehind.discard(data_id)
        with self._lock:
            pf = self._files.pop(data_id, None)
        self.cache.invalidate(data_id, drop_dirty=True)
        if pf is not None:
            pf.delete()

    def has(self, data_id: str) -> bool:
        with self._lock:
            return data_id in self._files

    def drop_namespace(self, session_id: str) -> None:
        """Reclaim a retired session: delete any of its page files still
        open (the store normally deletes them per-id first) and remove the
        now-empty per-namespace subdir. The session's physical IOStats
        split survives for post-mortem reporting."""
        with self._lock:
            ids = [d for d in self._files if ns_of(d) == session_id]
        for d in ids:
            self.delete(d)
        try:
            os.rmdir(os.path.join(self.root,
                                  urllib.parse.quote(session_id, safe="")))
        except OSError:
            pass        # never created, or a straggler file — leave it

    # ------------------------------------------------------------ integrity
    def scrub_file(self, data_id: str) -> list:
        """Verify one file's pages against its checksum block, straight
        off the medium (the cache is bypassed on purpose — scrub checks
        the bytes at rest). Detections are quarantined, counted and
        emitted as `safs.corrupt` events (site "scrub"); returns the
        corrupt page indices. Used by `safs.scrub.Scrubber`, which paces
        whole-store passes over the prefetch pool."""
        with self._lock:
            pf = self._files.get(data_id)
        if pf is None:
            return []
        bad = pf.verify_pages()
        self.integrity.add(pages_scrubbed=pf.n_pages,
                           scrub_corrupt=len(bad),
                           crc_failures=len(bad))
        for i in bad:
            trace.event("safs.corrupt", site="scrub", file=pf.path, page=i)
            with self._lock:
                self._quarantine.add((data_id, i))
        return bad

    def quarantined(self) -> list:
        """Pages whose corruption has been detected and not yet repaired,
        as sorted (data_id, page) pairs."""
        with self._lock:
            return sorted(self._quarantine)

    def repair_page(self, data_id: str, page: int, data: bytes) -> None:
        """Overwrite one corrupt page with verified replacement bytes
        (journaled, checksum block updated in the same commit) and lift
        its quarantine. The caller (`safs.scrub.repair_from_checkpoint`)
        is responsible for sourcing `data` from a *verified* snapshot."""
        with self._lock:
            pf = self._files[data_id]
        pf.write_pages({int(page): data})
        self.ns_io.add(data_id, host_bytes_written=len(data), host_writes=1)
        # drop any cached clean copy so the next read re-fills from the
        # repaired medium (dirty lines are newer than the snapshot — keep)
        self.cache.invalidate(data_id, drop_dirty=False)
        with self._lock:
            self._quarantine.discard((data_id, int(page)))
        self.integrity.add(pages_repaired=1)
        trace.event("safs.repair", file=data_id, page=int(page))

    def sweep_orphan_namespaces(self, *, live: Iterable[str] = (),
                                grace_s: float = 3600.0) -> list:
        """Startup GC for a serve root reused after a killed process:
        per-session page subdirs that belong to no live session and have
        not been touched for `grace_s` seconds are reclaimed (their files
        were adopted by `_reopen`, so `drop_namespace` both closes and
        deletes them). Age-gating spares a directory a concurrent serve
        process just created. Returns the swept session ids."""
        import time as _time
        live = set(live)
        swept = []
        now = _time.time()
        for d in sorted(os.listdir(self.root)):
            p = os.path.join(self.root, d)
            if not os.path.isdir(p):
                continue
            sid = urllib.parse.unquote(d)
            if sid in live or now - os.path.getmtime(p) < grace_s:
                continue
            self.drop_namespace(sid)
            if os.path.isdir(p):       # stragglers drop_namespace spared
                import shutil
                shutil.rmtree(p, ignore_errors=True)
            trace.event("safs.gc_namespace", namespace=sid)
            swept.append(sid)
        return swept

    def pin(self, data_id: str) -> None:
        if self.pin_pages:
            self.cache.pin(data_id)

    def unpin(self, data_id: str) -> None:
        self.cache.unpin(data_id)

    def prefetch(self, data_ids) -> None:
        """Queue readahead fills. Files whose every page is already cache-
        resident are skipped (O(1) per id off the cache's per-file
        counters): a fused pass announces its FULL block list up front
        (`core.stream.SubspacePass`), and without the skip the cached
        prefix of the pattern would burn the scheduler's bounded window
        on no-op fills while the blocks that actually need disk reads get
        dropped past it."""
        if not self.enable_prefetch:
            return
        todo = []
        for d in data_ids:
            with self._lock:
                pf = self._files.get(d)
            if pf is None:
                continue
            if self.cache.resident_pages(d) >= pf.n_pages:
                continue
            todo.append(d)
        if todo:
            self.prefetcher.schedule(todo)

    def flush(self, data_id: str | None = None) -> int:
        """Write back all dirty pages (journaled per file), drain the
        write-behind queue (durability barrier), and fsync. Returns bytes
        written to the medium (for the async sink: bytes the queue
        retired during this flush, prior demotions included)."""
        if self.writebehind is not None:
            before = self.writebehind.stats_dict()["bytes_retired"]
            self.cache.flush(data_id)
            self.writebehind.drain()
            n = self.writebehind.stats_dict()["bytes_retired"] - before
        else:
            n = self.cache.flush(data_id)
        with self._lock:
            files = ([self._files[data_id]] if data_id is not None
                     else list(self._files.values()))
        for pf in files:
            pf.sync()
        return n

    def stats_dict(self) -> dict:
        """One merged snapshot of every SAFS counter surface: physical
        disk traffic (`io` — the shared cache IOStats), cache residency,
        prefetcher overlap accounting, write-behind queue state. This is
        the supported external surface — benchmarks/examples read this
        instead of poking `backend.writebehind`/`backend.prefetcher`
        internals (which may be absent on other backends)."""
        with self._lock:
            n_files = len(self._files)
        return {
            "io": self.stats.as_dict(),
            "cache": {"capacity_bytes": self.cache.capacity,
                      "page_size": self.page_size,
                      "resident_pages": self.cache.n_pages(),
                      "resident_bytes": self.cache.nbytes(),
                      "pinned_files": len(self.cache.pinned()),
                      "n_files": n_files},
            "prefetch": self.prefetcher.stats(),
            "write_behind": (self.writebehind.stats_dict()
                             if self.writebehind is not None else None),
            # crc_failures reconciles 1:1 with safs.corrupt trace events,
            # scrub_passes with safs.scrub (asserted by the kill-matrix
            # tests and repro.obs.report --validate)
            "integrity": {**self.integrity.as_dict(),
                          "quarantined": len(self._quarantine)},
            # per-session physical splits; after a flush/drain barrier
            # their read/written byte sums reconcile exactly with "io"
            "namespaces": self.ns_io.as_dict(),
        }

    def close(self) -> None:
        try:
            self.flush()
        finally:
            # a flush failure (WriteBehindError) must still propagate, but
            # never leak worker threads or page-file fds
            self.prefetcher.close()
            if self.writebehind is not None:
                self.writebehind.close()
            with self._lock:
                for pf in self._files.values():
                    pf.close()
                self._files.clear()


def make_backend(spec, **opts) -> StorageBackend:
    """Factory: 'ram', 'safs' (opts: root, page_size, cache_bytes,
    use_mmap, io_workers, readahead_depth, write_behind, wb_max_pages,
    pin_pages, faults, retry, verify_reads), or pass through an
    already-constructed backend."""
    if not isinstance(spec, str):
        return spec
    if spec == "ram":
        return RamBackend()
    if spec == "safs":
        if "root" not in opts:
            import atexit
            import shutil
            import tempfile
            opts["root"] = tempfile.mkdtemp(prefix="safs_")
            # an auto-created root is ours to reclaim; long-lived processes
            # creating many stores should pass `root` and call close()
            atexit.register(shutil.rmtree, opts["root"], ignore_errors=True)
        return SafsBackend(**opts)
    raise ValueError(f"unknown storage backend {spec!r}")
