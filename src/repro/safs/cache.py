"""SAFS page cache — LRU over (data_id, page) with most-recent-block pinning.

The paper's SAFS keeps a page cache in front of the SSD array and FlashEigen
pins the most recent dense matrix in it (§3.4.4): the newest subspace block
is about to be re-read by reorthogonalization, so evicting it would double
the read traffic, and re-writing a clean page would burn write endurance.
Both policies live here:

  * keys are (data_id, page_index) — a transposed view shares its parent's
    data_id (§3.4.4 "data identifiers"), so its pages hit the same lines;
  * eviction is LRU over unpinned pages; a dirty page is written back to
    its PageFile on eviction (write-back, not write-through — this is where
    the 145 TB-read vs 4 TB-write asymmetry of Table 3 comes from);
  * stats are byte-exact and mirror `core.tiered.IOStats` field names so
    the two accounting layers compose: `host_bytes_read/written` count real
    disk traffic (endurance), `cache_hits/misses` count page lookups.

Demotions are *asynchronous* behind `WriteBehind` (§3.4's async I/O made
concrete): an eviction hands its dirty pages to a bounded queue and
returns immediately; a drain thread batches the queue per file and pushes
each batch through the journaled `PageFile.write_pages`, so crash
consistency is inherited — a page is *acked* (durable) the moment its
batch's journal commits, and a kill mid-patch is replayed on reopen.
Until a page retires, `WriteBehind.lookup` serves its newest bytes to
cache-miss reads (the queue doubles as a victim buffer), so readers can
never observe the stale on-disk copy of an evicted-but-unwritten page.

Thread safety: one lock around the table — the prefetch thread inserts
pages while the consumer thread reads them.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from repro.core.tiered import IOStats
from repro.obs import trace
from repro.safs.faults import (DEFAULT_RETRY, FaultPlan, OnRetry,
                               RetryPolicy, with_retries)

Key = Tuple[str, int]


class _Line:
    __slots__ = ("data", "dirty")

    def __init__(self, data: bytes, dirty: bool):
        self.data = data
        self.dirty = dirty


class PageCache:
    """Byte-budgeted LRU page cache with per-data_id pinning.

    `writer(data_id, {page: bytes}) -> bytes_written` is the write-back
    sink (the owning backend flushes through its PageFile journal);
    evictions of dirty pages call it one page at a time, explicit
    `flush()` batches all dirty pages of a file into one journal commit.
    """

    def __init__(self, capacity_bytes: int, page_size: int,
                 writer: Callable[[str, Dict[int, bytes]], int]):
        self.capacity = int(capacity_bytes)
        self.page_size = int(page_size)
        self._writer = writer
        self._lines: "OrderedDict[Key, _Line]" = OrderedDict()
        self._pinned: set[str] = set()
        self._per_file: Dict[str, int] = {}   # resident pages per data_id
        self.stats = IOStats()
        self._lock = threading.RLock()

    # ------------------------------------------------------------- sizing
    def nbytes(self) -> int:
        with self._lock:
            return len(self._lines) * self.page_size

    def n_pages(self) -> int:
        return len(self._lines)

    def _evict_for(self, incoming_pages: int) -> None:
        # caller holds the lock
        budget = self.capacity - incoming_pages * self.page_size
        if len(self._lines) * self.page_size <= budget:
            return
        # Evict past the budget by a slack of ~capacity/8 (whole pages; 0 on
        # tiny caches) and batch the dirty write-backs per file: a streaming
        # store then pays one journal commit (with its fsyncs) per slack
        # chunk instead of one per evicted page.
        slack = (self.capacity // 8 // self.page_size) * self.page_size
        target = max(0, budget - slack)
        victims = []
        for key in self._lines:                     # oldest first
            if (len(self._lines) - len(victims)) * self.page_size <= target:
                break
            if key[0] not in self._pinned:
                victims.append(key)
        if not victims:
            return
        with trace.span("safs.evict", pages=len(victims)) as sp:
            by_file: Dict[str, Dict[int, bytes]] = {}
            dirty = 0
            for key in victims:
                line = self._lines.pop(key)
                self._dec_per_file(key[0])
                if line.dirty:
                    dirty += 1
                    by_file.setdefault(key[0], {})[key[1]] = line.data
            sp.set(dirty_pages=dirty)
            for d, pages in by_file.items():
                n = self._writer(d, pages)
                if n:   # an async (write-behind) sink returns 0 at submit
                    self.stats.add(host_bytes_written=n, host_writes=1)

    # ------------------------------------------------------------ lookups
    def get(self, data_id: str, page: int, *, with_dirty: bool = False):
        """Hit → payload (LRU-touched); miss → None (caller reads disk).
        with_dirty=True returns (payload, dirty) instead — the backend
        uses the flag to rank a clean line against write-behind bytes."""
        with self._lock:
            line = self._lines.get((data_id, page))
            if line is None:
                self.stats.add(cache_misses=1)
                return None
            self._lines.move_to_end((data_id, page))
            self.stats.add(cache_hits=1)
            return (line.data, line.dirty) if with_dirty else line.data

    def peek(self, data_id: str, page: int) -> bool:
        """Residency probe without touching LRU order or stats (prefetch)."""
        with self._lock:
            return (data_id, page) in self._lines

    def resident_pages(self, data_id: str) -> int:
        """How many of a file's pages are resident — O(1) off a running
        per-file counter (the backend's prefetch uses it to skip
        fully-cached files instead of probing every page)."""
        with self._lock:
            return self._per_file.get(data_id, 0)

    def _dec_per_file(self, data_id: str) -> None:
        # caller holds the lock
        left = self._per_file.get(data_id, 0) - 1
        if left > 0:
            self._per_file[data_id] = left
        else:
            self._per_file.pop(data_id, None)

    def put_clean_if(self, data_id: str, page: int, data: bytes,
                     fresh) -> bool:
        """Insert a clean fill only if `fresh()` — evaluated under the
        cache lock — confirms no eviction raced the disk read that
        produced it (the backend passes a write-behind generation
        compare). Returns False, inserting nothing, on a failed check.

        The atomicity matters: an insert-then-verify would publish the
        possibly-stale line for the verify's duration, and a concurrent
        reader could be served it while the write-behind queue no longer
        shadows the page (its batch already retired). Evictions bump the
        generation while still holding this lock, so check-then-insert
        under the same lock leaves no window: a racing evict is either
        fully ordered before (check fails) or after (its dirty line was
        present during our insert, and the no-clean-clobber rule in
        `put` already kept our bytes out)."""
        with self._lock:          # RLock: the nested put re-enters
            if not fresh():
                return False
            self.put(data_id, page, data, dirty=False)
            return True

    def put(self, data_id: str, page: int, data: bytes, *,
            dirty: bool) -> None:
        """Insert/overwrite a line. dirty=False for fill-on-read/prefetch,
        dirty=True for stores (write-back deferred to eviction/flush)."""
        with self._lock:
            key = (data_id, page)
            if key not in self._lines:
                self._evict_for(1)
                self._lines[key] = _Line(data, dirty)
                self._per_file[data_id] = self._per_file.get(data_id, 0) + 1
            else:
                line = self._lines[key]
                if dirty:
                    line.data = data
                    line.dirty = True
                # a clean fill never clobbers a resident line: the line may
                # hold newer dirty bytes than the disk copy the (prefetch)
                # filler read between its peek and this put
            self._lines.move_to_end(key)

    # ------------------------------------------------------------ pinning
    def pin(self, data_id: str) -> None:
        with self._lock:
            self._pinned.add(data_id)

    def unpin(self, data_id: str) -> None:
        with self._lock:
            self._pinned.discard(data_id)

    def pinned(self) -> set:
        with self._lock:
            return set(self._pinned)

    # ------------------------------------------------------- flush/forget
    def flush(self, data_id: str | None = None) -> int:
        """Write back dirty pages (all files, or one), batched per file so
        each file gets a single journal commit. Returns bytes written."""
        with self._lock:
            by_file: Dict[str, Dict[int, bytes]] = {}
            for (d, p), line in self._lines.items():
                if line.dirty and (data_id is None or d == data_id):
                    by_file.setdefault(d, {})[p] = line.data
            total = 0
            for d, pages in by_file.items():
                n = self._writer(d, pages)
                if n:
                    self.stats.add(host_writes=1)
                total += n
                for p in pages:
                    self._lines[(d, p)].dirty = False
            self.stats.add(host_bytes_written=total)
            return total

    def invalidate(self, data_id: str, *, drop_dirty: bool = False) -> None:
        """Forget a file's pages (on delete). Dirty pages are dropped only
        when drop_dirty (the file itself is going away)."""
        with self._lock:
            for key in [k for k in self._lines if k[0] == data_id]:
                line = self._lines[key]
                if line.dirty and not drop_dirty:
                    n = self._writer(data_id, {key[1]: line.data})
                    if n:
                        self.stats.add(host_bytes_written=n, host_writes=1)
                del self._lines[key]
                self._dec_per_file(data_id)
            self._pinned.discard(data_id)

    def fill_bytes_read(self, n: int) -> None:
        """Account a disk read that filled this cache (backend helper)."""
        self.stats.add(host_bytes_read=n, host_reads=1)


# ---------------------------------------------------------------------------
# Async write-behind queue for cache demotions
# ---------------------------------------------------------------------------
class WriteBehindError(RuntimeError):
    """A background write-back failed; re-raised at submit/drain."""


class WriteBehind:
    """Bounded async write-behind queue over a journaled page writer.

    `writer(data_id, {page: bytes}) -> bytes_written` is the *synchronous*
    journaled sink (`PageFile.write_pages` via the backend). Eviction paths
    call `submit` and return immediately; one drain thread pops the oldest
    file's accumulated pages as a single batch → one journal commit per
    batch instead of one per evicted page, and in submit order per file
    (a re-dirtied page resubmitted later can never be overtaken by its
    older bytes).

    Durability ("ack") semantics: a page is acked once the journal of the
    batch containing it has committed — from then on a crash is redone on
    reopen (`PageFile._recover`), so every acked page survives a kill
    mid-demotion. Pages still queued at the kill are *not* acked; callers
    needing a durability barrier call `drain()` (backend `flush`/`close`
    do). Until its batch retires, a page's newest bytes are served by
    `lookup` — the queue is also the victim buffer for evicted-but-
    unwritten pages.

    `stats` (an IOStats, usually the PageCache's) is advanced by the drain
    thread with the *actual* bytes the journaled writer reported, so
    physical-endurance accounting stays byte-exact even when queue merging
    collapses a resubmitted page into one write.

    Fault tolerance: each retire is retried with backoff on transient
    errors per `retry` (site "wb.retire"; an attached `FaultPlan` is
    consulted there too). Exhaustion raises a typed `SafsIOError`
    carrying file/attempt context, which is captured like any writer
    failure and surfaces at the next `drain()` as `WriteBehindError`
    (the SafsIOError is its __cause__). Retries are counted in
    `stats_dict()["retries"]` and through `on_retry`.
    """

    def __init__(self, writer: Callable[[str, Dict[int, bytes]], int], *,
                 max_pages: int = 4096, stats: Optional["IOStats"] = None,
                 retry: Optional[RetryPolicy] = DEFAULT_RETRY,
                 faults: Optional[FaultPlan] = None,
                 on_retry: Optional[OnRetry] = None):
        self._writer = writer
        self.max_pages = max(1, int(max_pages))
        self._stats = stats
        self._retry = retry
        self._faults = faults
        self._on_retry = on_retry
        self.retries = 0               # retire attempts that were retried
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: "OrderedDict[str, Dict[int, bytes]]" = OrderedDict()
        self._inflight: Optional[Tuple[str, Dict[int, bytes]]] = None
        self._gen: Dict[str, int] = {}   # per-file submit counter (stale-
        #                                  fill guard: see generation())
        self._n_pending = 0            # pages queued (excl. in flight)
        self._error: Optional[BaseException] = None
        self._error_id: Optional[str] = None   # file the error belongs to
        self._shutdown = False
        self.pages_retired = 0
        self.bytes_retired = 0
        self.batches_retired = 0
        self.max_depth_pages = 0       # high-water queue depth (bench stat)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="safs-writebehind")
        self._thread.start()

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cv:
                # pause while a captured error awaits drain(): retrying a
                # persistently failing writer would spin, and the failed
                # batch is back in _pending so lookup still serves it
                while ((not self._pending or self._error is not None)
                       and not self._shutdown):
                    self._cv.wait()
                if self._shutdown and (not self._pending
                                       or self._error is not None):
                    return
                data_id, pages = self._pending.popitem(last=False)
                self._n_pending -= len(pages)
                self._inflight = (data_id, pages)
                self._cv.notify_all()          # submit backpressure
            err: Optional[BaseException] = None
            written = 0
            try:
                with trace.span("safs.wb.retire", file=data_id,
                                pages=len(pages)) as sp:
                    written = self._retire(data_id, pages)
                    sp.set(bytes=written)
            except BaseException as e:
                err = e
            with self._cv:
                self._inflight = None
                if err is None:
                    self.pages_retired += len(pages)
                    self.bytes_retired += written
                    self.batches_retired += 1
                    if self._stats is not None and written:
                        self._stats.add(host_bytes_written=written,
                                        host_writes=1)
                else:
                    if self._error is None:
                        self._error, self._error_id = err, data_id
                    # re-queue the failed batch: the queue may hold the
                    # only copy of these bytes, and dropping them would
                    # let readers see the stale disk copy. A page
                    # resubmitted since the pop is newer — keep it.
                    batch = self._pending.setdefault(data_id, {})
                    for p, data in pages.items():
                        if p not in batch:
                            batch[p] = data
                            self._n_pending += 1
                self._cv.notify_all()

    def _retire(self, data_id: str, pages: Dict[int, bytes]) -> int:
        """One journaled batch write, retried on transient errors. The
        fault-plan check ("wb.retire") runs inside the retry unit so an
        injected transient fault is absorbed, while an injected CrashPoint
        propagates (non-transient) and is captured as the queue error."""

        def attempt() -> int:
            if self._faults is not None:
                self._faults.check("wb.retire", file=data_id,
                                   pages=len(pages))
            return self._writer(data_id, pages)

        return with_retries(attempt, self._retry, site="wb.retire",
                            file=data_id, on_retry=self._count_retry)

    def _count_retry(self, **kw) -> None:
        with self._lock:
            self.retries += 1
        if self._on_retry is not None:
            self._on_retry(**kw)

    # ----------------------------------------------------------- frontend
    def _raise_pending_error(self) -> None:
        # caller holds the lock
        if self._error is not None:
            err, self._error, self._error_id = self._error, None, None
            self._cv.notify_all()      # un-pause the worker (it retries)
            raise WriteBehindError("async write-back failed") from err

    def submit(self, data_id: str, pages: Dict[int, bytes]) -> int:
        """Queue dirty pages (newest bytes win per page). Blocks only when
        the queue is at max_pages (backpressure). Returns 0 — the actual
        write is accounted by the drain thread when the batch retires.

        Never raises a captured write-back failure: submit runs inside
        eviction paths (including on prefetch workers, where a raise would
        be mistaken for a read error and the pending error lost) — the
        durability barrier that surfaces failures is `drain()`. While an
        error is pending the worker is paused, so backpressure is waived
        (the queue may overshoot max_pages) — blocking here would deadlock
        against the very flush that clears the error."""
        if not pages:
            return 0
        with self._cv:
            while (self._n_pending >= self.max_pages
                   and self._error is None and not self._shutdown):
                self._cv.wait()
            batch = self._pending.setdefault(data_id, {})
            for p, data in pages.items():
                if p not in batch:
                    self._n_pending += 1
                batch[p] = data
            self._gen[data_id] = self._gen.get(data_id, 0) + 1
            self.max_depth_pages = max(self.max_depth_pages,
                                       self.pending_pages_locked())
            self._cv.notify_all()
        return 0

    def pending_pages_locked(self) -> int:
        # caller holds the lock
        n = self._n_pending
        if self._inflight is not None:
            n += len(self._inflight[1])
        return n

    def pending_pages(self) -> int:
        with self._lock:
            return self.pending_pages_locked()

    def empty(self) -> bool:
        """Lock-free emptiness probe for hot read paths. Safe as a
        lookup-skip: an eviction publishes its queue insert *before*
        releasing the page-cache lock, so any reader whose cache lookup
        already missed is guaranteed to observe a non-empty queue here;
        and a just-retired batch is on disk, so reading disk is fresh."""
        return self._n_pending == 0 and self._inflight is None

    def generation(self, data_id: str) -> int:
        """Monotonic count of submits for a file — the stale-fill guard.

        A disk reader that captures the generation *before* reading and
        observes it unchanged *after* inserting its fill into the cache
        knows no eviction raced the read: `lookup` alone cannot prove
        that, because a batch that was submitted AND retired inside the
        window has already left the queue (the disk then holds newer
        bytes than the fill). `discard` drops the counter; the reset
        reads as a generation change, which errs toward dropping a
        (possibly fine) fill — the safe direction.
        """
        with self._lock:
            return self._gen.get(data_id, 0)

    def lookup(self, data_id: str, page: int) -> Optional[bytes]:
        """Newest not-yet-retired bytes for a page, or None. Pending beats
        in-flight (a resubmission after the batch was popped is newer)."""
        with self._lock:
            batch = self._pending.get(data_id)
            if batch is not None and page in batch:
                return batch[page]
            if self._inflight is not None and self._inflight[0] == data_id:
                return self._inflight[1].get(page)
            return None

    def discard(self, data_id: str) -> None:
        """Drop queued pages of a file about to be deleted; waits out an
        in-flight batch so the writer never races the unlink. An error
        captured for this file dies with it — it must not pause the
        worker or fail a later unrelated drain."""
        with self._cv:
            self._gen.pop(data_id, None)
            while True:     # an in-flight batch that fails re-queues itself
                batch = self._pending.pop(data_id, None)
                if batch:
                    self._n_pending -= len(batch)
                if self._error_id == data_id:
                    self._error, self._error_id = None, None
                self._cv.notify_all()
                if (self._inflight is None
                        or self._inflight[0] != data_id):
                    return
                self._cv.wait()

    def drain(self) -> None:
        """Durability barrier: block until the queue is empty and the last
        batch retired; re-raise any captured write-back failure. A failed
        batch stays queued (still served by lookup) and is retried once
        the error has been surfaced here."""
        with self._cv:
            while self._pending or self._inflight is not None:
                if self._error is not None:
                    break
                self._cv.wait()
            self._raise_pending_error()

    def stats_dict(self) -> dict:
        with self._lock:
            return {"pages_retired": self.pages_retired,
                    "bytes_retired": self.bytes_retired,
                    "batches_retired": self.batches_retired,
                    "max_depth_pages": self.max_depth_pages,
                    "pending_pages": self.pending_pages_locked(),
                    "retries": self.retries}

    def close(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        self._thread.join(timeout=10)
