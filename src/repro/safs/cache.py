"""SAFS page cache — LRU over (data_id, page) with most-recent-block pinning.

The paper's SAFS keeps a page cache in front of the SSD array and FlashEigen
pins the most recent dense matrix in it (§3.4.4): the newest subspace block
is about to be re-read by reorthogonalization, so evicting it would double
the read traffic, and re-writing a clean page would burn write endurance.
Both policies live here:

  * keys are (data_id, page_index) — a transposed view shares its parent's
    data_id (§3.4.4 "data identifiers"), so its pages hit the same lines;
  * eviction is LRU over unpinned pages; a dirty page is written back to
    its PageFile on eviction (write-back, not write-through — this is where
    the 145 TB-read vs 4 TB-write asymmetry of Table 3 comes from);
  * stats are byte-exact and mirror `core.tiered.IOStats` field names so
    the two accounting layers compose: `host_bytes_read/written` count real
    disk traffic (endurance), `cache_hits/misses` count page lookups.

Thread safety: one lock around the table — the prefetch thread inserts
pages while the consumer thread reads them.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from repro.core.tiered import IOStats

Key = Tuple[str, int]


class _Line:
    __slots__ = ("data", "dirty")

    def __init__(self, data: bytes, dirty: bool):
        self.data = data
        self.dirty = dirty


class PageCache:
    """Byte-budgeted LRU page cache with per-data_id pinning.

    `writer(data_id, {page: bytes}) -> bytes_written` is the write-back
    sink (the owning backend flushes through its PageFile journal);
    evictions of dirty pages call it one page at a time, explicit
    `flush()` batches all dirty pages of a file into one journal commit.
    """

    def __init__(self, capacity_bytes: int, page_size: int,
                 writer: Callable[[str, Dict[int, bytes]], int]):
        self.capacity = int(capacity_bytes)
        self.page_size = int(page_size)
        self._writer = writer
        self._lines: "OrderedDict[Key, _Line]" = OrderedDict()
        self._pinned: set[str] = set()
        self.stats = IOStats()
        self._lock = threading.RLock()

    # ------------------------------------------------------------- sizing
    def nbytes(self) -> int:
        with self._lock:
            return len(self._lines) * self.page_size

    def n_pages(self) -> int:
        return len(self._lines)

    def _evict_for(self, incoming_pages: int) -> None:
        # caller holds the lock
        budget = self.capacity - incoming_pages * self.page_size
        if len(self._lines) * self.page_size <= budget:
            return
        # Evict past the budget by a slack of ~capacity/8 (whole pages; 0 on
        # tiny caches) and batch the dirty write-backs per file: a streaming
        # store then pays one journal commit (with its fsyncs) per slack
        # chunk instead of one per evicted page.
        slack = (self.capacity // 8 // self.page_size) * self.page_size
        target = max(0, budget - slack)
        victims = []
        for key in self._lines:                     # oldest first
            if (len(self._lines) - len(victims)) * self.page_size <= target:
                break
            if key[0] not in self._pinned:
                victims.append(key)
        by_file: Dict[str, Dict[int, bytes]] = {}
        for key in victims:
            line = self._lines.pop(key)
            if line.dirty:
                by_file.setdefault(key[0], {})[key[1]] = line.data
        for d, pages in by_file.items():
            self.stats.host_bytes_written += self._writer(d, pages)
            self.stats.host_writes += 1

    # ------------------------------------------------------------ lookups
    def get(self, data_id: str, page: int) -> Optional[bytes]:
        """Hit → payload (LRU-touched); miss → None (caller reads disk)."""
        with self._lock:
            line = self._lines.get((data_id, page))
            if line is None:
                self.stats.cache_misses += 1
                return None
            self._lines.move_to_end((data_id, page))
            self.stats.cache_hits += 1
            return line.data

    def peek(self, data_id: str, page: int) -> bool:
        """Residency probe without touching LRU order or stats (prefetch)."""
        with self._lock:
            return (data_id, page) in self._lines

    def put(self, data_id: str, page: int, data: bytes, *,
            dirty: bool) -> None:
        """Insert/overwrite a line. dirty=False for fill-on-read/prefetch,
        dirty=True for stores (write-back deferred to eviction/flush)."""
        with self._lock:
            key = (data_id, page)
            if key not in self._lines:
                self._evict_for(1)
                self._lines[key] = _Line(data, dirty)
            else:
                line = self._lines[key]
                if dirty:
                    line.data = data
                    line.dirty = True
                # a clean fill never clobbers a resident line: the line may
                # hold newer dirty bytes than the disk copy the (prefetch)
                # filler read between its peek and this put
            self._lines.move_to_end(key)

    # ------------------------------------------------------------ pinning
    def pin(self, data_id: str) -> None:
        with self._lock:
            self._pinned.add(data_id)

    def unpin(self, data_id: str) -> None:
        with self._lock:
            self._pinned.discard(data_id)

    def pinned(self) -> set:
        with self._lock:
            return set(self._pinned)

    # ------------------------------------------------------- flush/forget
    def flush(self, data_id: str | None = None) -> int:
        """Write back dirty pages (all files, or one), batched per file so
        each file gets a single journal commit. Returns bytes written."""
        with self._lock:
            by_file: Dict[str, Dict[int, bytes]] = {}
            for (d, p), line in self._lines.items():
                if line.dirty and (data_id is None or d == data_id):
                    by_file.setdefault(d, {})[p] = line.data
            total = 0
            for d, pages in by_file.items():
                total += self._writer(d, pages)
                self.stats.host_writes += 1
                for p in pages:
                    self._lines[(d, p)].dirty = False
            self.stats.host_bytes_written += total
            return total

    def invalidate(self, data_id: str, *, drop_dirty: bool = False) -> None:
        """Forget a file's pages (on delete). Dirty pages are dropped only
        when drop_dirty (the file itself is going away)."""
        with self._lock:
            for key in [k for k in self._lines if k[0] == data_id]:
                line = self._lines[key]
                if line.dirty and not drop_dirty:
                    self.stats.host_bytes_written += self._writer(
                        data_id, {key[1]: line.data})
                    self.stats.host_writes += 1
                del self._lines[key]
            self._pinned.discard(data_id)

    def fill_bytes_read(self, n: int) -> None:
        """Account a disk read that filled this cache (backend helper)."""
        with self._lock:
            self.stats.host_bytes_read += n
            self.stats.host_reads += 1
