"""Deterministic fault injection + bounded retry for the SAFS I/O path.

A four-hour single-machine solve (the paper's headline run, §4) WILL see
transient NVMe errors, preemptions and kills — FlashGraph-class SSD arrays
make flaky I/O a when, not an if. This module supplies both halves of the
robustness story:

  * `FaultPlan` — a seeded, site-keyed schedule of injected faults
    (transient `EIO`, short reads, latency spikes, hard `CrashPoint`s)
    that the SAFS layer consults at its real I/O boundaries, so any
    failure interleaving is reproducible in tests. Sites are the actual
    syscall/commit points of `pagefile.py` / `cache.py`:

      pread              each vectored preadv chunk (`PageFile.read_run`)
      pwritev            each vectored pwritev chunk (`_pwritev_runs`)
      journal.precommit  journal written, commit trailer NOT yet durable
      journal.commit     journal committed, in-place patch not yet started
      wb.retire          write-behind drain thread, before the journaled
                         batch write (`WriteBehind._run`)
      ckpt.save          between a checkpoint's page snapshot and its
                         state-manifest commit (`ckpt.solver`)
      solve.restart      the solver's restart boundary (checkpoint hook)
      prefetch           a readahead worker's whole-file fill

  * `RetryPolicy` / `with_retries` — bounded retry with exponential
    backoff + jitter on *transient* errors (OSError errno in
    `TRANSIENT_ERRNOS`). Exhaustion raises `SafsIOError` carrying
    file/page/attempt context; `CrashPoint` and `SafsIOError` itself are
    never retried. Every retry emits a `safs.retry` event through the
    `repro.obs` tracer and hits the caller's `on_retry` hook (the backend
    counts them into `IOStats.retries`), so retry totals reconcile
    between `stats_dict()` and the trace.

Wiring: construct `SafsBackend(root, faults=plan, retry=policy)` — the
plan and policy are threaded into every `PageFile`, the write-behind
drain thread and the prefetch workers; the solver-side checkpointer
discovers the same plan via `store.backend.faults` for the `ckpt.save` /
`solve.restart` sites. One plan therefore scripts a whole solve's
failure schedule.
"""
from __future__ import annotations

import dataclasses
import errno
import fnmatch
import os
import random
import threading
import time
from typing import Callable, List, Optional, Sequence

from repro.obs import trace

TRANSIENT_ERRNOS = frozenset({
    errno.EIO, errno.EAGAIN, errno.EINTR, errno.ETIMEDOUT, errno.EBUSY,
})


class CrashPoint(RuntimeError):
    """A simulated mid-operation kill (test/crash-hook injection). Never
    retried: the on-disk state it leaves behind is exactly what a real
    kill leaves, and recovery happens on reopen, not in-line."""


class TransientIOError(OSError):
    """An injected transient I/O failure (errno EIO) — retryable."""

    def __init__(self, message: str):
        super().__init__(errno.EIO, message)


class SafsIOError(OSError):
    """A SAFS I/O operation failed permanently (retries exhausted, or a
    non-transient error wrapped with context). Carries the failing site,
    file, page and attempt count for post-mortems."""

    def __init__(self, message: str, *, site: str, file: str | None = None,
                 page: int | None = None, attempts: int = 1):
        super().__init__(errno.EIO, message)
        self.site = site
        self.file = file
        self.page = page
        self.attempts = attempts

    def __str__(self) -> str:  # keep the context visible in logs/asserts
        loc = f" file={self.file!r}" if self.file else ""
        if self.page is not None:
            loc += f" page={self.page}"
        return (f"{self.args[1]} [site={self.site}{loc} "
                f"attempts={self.attempts}]")


class CorruptPageError(SafsIOError):
    """A page's bytes failed checksum verification and re-reads did not
    clear the mismatch: silent corruption (media bit-rot, torn write, bad
    transfer). Never retried by `with_retries` — the data is wrong, not
    slow; repair happens from a verified checkpoint or the solve fails
    typed instead of converging on garbage."""

    def __init__(self, *, site: str, file: str | None = None,
                 page: int | None = None):
        super().__init__("page checksum mismatch", site=site, file=file,
                         page=page, attempts=1)


class IntegrityCounters:
    """Thread-safe integrity counter block shared by every PageFile of a
    backend (and its scrubber). Surfaces as `stats_dict()["integrity"]`;
    `crc_failures` reconciles 1:1 with `safs.corrupt` trace events and
    `scrub_passes` with `safs.scrub` events."""

    FIELDS = ("pages_verified", "crc_retries", "crc_failures",
              "scrub_passes", "pages_scrubbed", "scrub_corrupt",
              "pages_repaired")

    def __init__(self):
        self._lock = threading.Lock()
        self._c = {k: 0 for k in self.FIELDS}

    def add(self, **kw) -> None:
        with self._lock:
            for k, v in kw.items():
                self._c[k] = self._c.get(k, 0) + int(v)

    def as_dict(self) -> dict:
        with self._lock:
            return dict(self._c)


def is_transient(err: BaseException) -> bool:
    """True for errors worth retrying: OSError with a transient errno.
    `SafsIOError` (already-exhausted retries) and `CrashPoint` are final."""
    if isinstance(err, SafsIOError):
        return False
    return isinstance(err, OSError) and err.errno in TRANSIENT_ERRNOS


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + jitter (transient errors
    only). max_attempts counts the first try: max_attempts=1 disables
    retrying; the default absorbs 3 consecutive transient failures.
    `max_total_sleep` caps the *cumulative* backoff per operation — a
    latency-spike fault storm cannot stack unbounded exponential sleeps
    on the write-behind drain thread; once the budget is spent the
    remaining attempts run back-to-back."""
    max_attempts: int = 4
    base_delay: float = 0.002      # seconds before the first retry
    multiplier: float = 2.0
    max_delay: float = 0.25
    jitter: float = 0.5            # +[0, jitter) fraction on each delay
    max_total_sleep: float = 1.0   # cumulative sleep cap per operation


DEFAULT_RETRY = RetryPolicy()

OnRetry = Callable[..., None]


def with_retries(fn: Callable[[], object], policy: Optional[RetryPolicy], *,
                 site: str, file: str | None = None, page: int | None = None,
                 on_retry: Optional[OnRetry] = None):
    """Run `fn`, retrying transient failures per `policy` (None = single
    attempt). Each retry emits a `safs.retry` trace event and calls
    `on_retry(site=, file=, page=, attempt=, error=, slept_ms=)`.
    Cumulative backoff is capped at `policy.max_total_sleep` per call.
    Exhaustion raises `SafsIOError` (chained); non-transient errors
    propagate untouched."""
    if policy is None:
        return fn()
    delay = policy.base_delay
    attempt = 1
    slept = 0.0
    while True:
        try:
            return fn()
        except BaseException as e:
            if not is_transient(e):
                raise
            if attempt >= policy.max_attempts:
                raise SafsIOError(
                    f"I/O failed after {attempt} attempts: {e}",
                    site=site, file=file, page=page, attempts=attempt) from e
            pause = (min(delay, policy.max_delay)
                     * (1.0 + policy.jitter * random.random()))
            pause = max(0.0, min(pause, policy.max_total_sleep - slept))
            trace.event("safs.retry", site=site, file=file, page=page,
                        attempt=attempt, error=type(e).__name__)
            if on_retry is not None:
                on_retry(site=site, file=file, page=page, attempt=attempt,
                         error=e, slept_ms=pause * 1e3)
            time.sleep(pause)
            slept += pause
            delay *= policy.multiplier
            attempt += 1


# --------------------------------------------------------------------------
# Seeded fault schedules
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FaultRule:
    """One scheduled fault. Fires on hits `at .. at+times-1` of matching
    sites (1-based, counted per rule across all matching sites), or with
    probability `prob` per hit when `prob` is set (seeded via the plan).

    site: exact site name or fnmatch glob ("journal.*").
    kind: "eio" (raise TransientIOError) | "crash" (raise CrashPoint) |
          "latency" (sleep `delay` seconds) | "short_read" (truncate the
          first preadv of the chunk — exercises the short-read loop) |
          "bitflip" (silently corrupt one bit of the first page moving
          through the site: on "pread" the corruption is in the transfer,
          on "pwritev" it lands on the medium) | "torn_page" (on
          "pwritev": persist only the first half of the first page — a
          power-cut torn write). bitflip/torn_page never raise at the
          fault site; they exist to prove the checksum layer catches what
          the syscalls cannot.
    file_glob: optionally restrict to basenames matching this glob.
    """
    site: str
    kind: str
    at: int = 1
    times: Optional[int] = 1       # None = every matching hit from `at` on
    prob: Optional[float] = None
    delay: float = 0.005           # latency-spike seconds
    file_glob: Optional[str] = None

    def __post_init__(self):
        if self.kind not in ("eio", "crash", "latency", "short_read",
                             "bitflip", "torn_page"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """A deterministic, thread-safe schedule of injected faults.

    The I/O layer calls `check(site, **ctx)` at each boundary; the plan
    counts the hit, fires any matching rules (raising / sleeping /
    returning the "short_read" action), and logs what fired so tests can
    assert the schedule actually executed (`fired`, `hits`)."""

    def __init__(self, rules: Sequence[FaultRule] = (), *, seed: int = 0):
        self.rules: List[FaultRule] = list(rules)
        self._rng = random.Random(seed)
        self._hits: dict = {}               # site -> hit count
        self._rule_hits = [0] * len(self.rules)
        self._fired: List[dict] = []
        self._lock = threading.Lock()

    def check(self, site: str, **ctx) -> Optional[str]:
        """Consult the plan at an I/O boundary. Raises (eio/crash), sleeps
        (latency) or returns "short_read"; returns None when nothing
        fires. ctx (file=..., page=..., step=...) is recorded with the
        firing and matched against `file_glob`."""
        action: Optional[str] = None
        to_sleep = 0.0
        with self._lock:
            self._hits[site] = self._hits.get(site, 0) + 1
            for idx, r in enumerate(self.rules):
                if not fnmatch.fnmatchcase(site, r.site):
                    continue
                if r.file_glob is not None and not fnmatch.fnmatch(
                        os.path.basename(str(ctx.get("file", ""))),
                        r.file_glob):
                    continue
                self._rule_hits[idx] += 1
                k = self._rule_hits[idx]
                if r.prob is not None:
                    fire = self._rng.random() < r.prob
                else:
                    fire = k >= r.at and (r.times is None
                                          or k < r.at + r.times)
                if not fire:
                    continue
                self._fired.append({"site": site, "kind": r.kind, **ctx})
                if r.kind == "crash":
                    raise CrashPoint(f"injected crash at {site} (hit {k})")
                if r.kind == "eio":
                    raise TransientIOError(
                        f"injected EIO at {site} (hit {k})")
                if r.kind == "latency":
                    to_sleep = max(to_sleep, r.delay)
                else:                 # short_read / bitflip / torn_page
                    action = r.kind
        if to_sleep > 0.0:
            time.sleep(to_sleep)
        return action

    # ------------------------------------------------------- introspection
    def hits(self, site: str | None = None) -> int:
        with self._lock:
            if site is None:
                return sum(self._hits.values())
            return self._hits.get(site, 0)

    def fired(self, site: str | None = None,
              kind: str | None = None) -> List[dict]:
        with self._lock:
            return [f for f in self._fired
                    if (site is None or f["site"] == site)
                    and (kind is None or f["kind"] == kind)]
