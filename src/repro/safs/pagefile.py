"""PageFile — one on-disk file per TAS matrix, split into fixed-size pages.

The paper stores the vector subspace on SSDs behind SAFS, one file per
dense (TAS) matrix (§3.4.1); SAFS moves data in pages and the eigensolver
never overwrites a page it could instead avoid writing (write endurance,
Table 3). This module is the byte level of our reproduction of that layer:

  * a file is an array of PAGE_SIZE-byte pages, page i at offset
    i * page_size; reads go through pread (positional, thread-safe — the
    prefetcher reads concurrently with the consumer) or an optional mmap;
  * batched reads coalesce the requested pages into maximal contiguous
    *runs* and issue one vectored `os.preadv` per run (§3.4.2's request
    merging): at SAFS's native 4 KiB grain this turns ~16 python syscalls
    per 64 KiB of subspace into one, which is where the fast-path
    throughput comes from (see `read_pages_batch` / BENCH_safs.json);
    in-place journal patches likewise go out as one `os.pwritev` per run;
  * dirty-page write-back is crash consistent via a per-file journal:
    a flush first writes every dirty page plus a checksum to
    `<file>.journal`, fsyncs, appends a commit trailer, and only then
    patches the main file in place. Reopening after a crash replays a
    committed journal (redo) or discards an uncommitted one, so every
    page is always either entirely-old or entirely-new — never torn;
  * shape/dtype metadata lives in a `<file>.meta` JSON sidecar so a page
    store can be reopened cold (checkpoint restore path).

Tests inject crashes with the `crash_after_pages` / `crash_in_journal`
hooks instead of killing the process; the on-disk states they produce are
exactly the ones a mid-flush kill leaves behind. `repro.safs.faults`
generalizes those hooks into seeded schedules (`PageFile(faults=plan)`):
the plan is consulted at every preadv/pwritev chunk and at the journal
pre-commit/commit boundaries, and transient errors at those sites are
absorbed by bounded retry with backoff (`retry=RetryPolicy(...)`,
counted via `on_retry` and emitted as `safs.retry` trace events).
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.safs.faults import (CrashPoint, DEFAULT_RETRY, FaultPlan,
                               OnRetry, RetryPolicy, with_retries)

PAGE_SIZE = 4096                       # SAFS default page size (§3.4.1)

# Max iovecs per preadv/pwritev syscall (POSIX IOV_MAX is >= 1024 on Linux);
# longer runs are split — still one syscall per IOV_MAX pages, not per page.
_IOV_MAX = 1024


def coalesce_runs(indices: Sequence[int]) -> List[Tuple[int, int]]:
    """Merge page indices into maximal contiguous (start, count) runs.

    The batched I/O engine's request merging: sorted, de-duplicated, and
    adjacency-coalesced so each run becomes a single vectored syscall.
    """
    runs: List[Tuple[int, int]] = []
    for i in sorted(set(int(i) for i in indices)):
        if runs and i == runs[-1][0] + runs[-1][1]:
            runs[-1] = (runs[-1][0], runs[-1][1] + 1)
        else:
            runs.append((i, 1))
    return runs

_JOURNAL_MAGIC = b"SAFSJRNL"
_COMMIT = b"COMMITTD"
_HDR = struct.Struct("<qII")           # page_index, crc32, payload_len

# CrashPoint moved to repro.safs.faults (the fault-injection layer owns the
# error taxonomy); re-exported here for existing importers.
__all__ = ["PAGE_SIZE", "CrashPoint", "PageFile", "coalesce_runs"]


def _meta_path(path: str) -> str:
    return path + ".meta"


def _journal_path(path: str) -> str:
    return path + ".journal"


class PageFile:
    """Fixed-size-page file with journaled, crash-consistent write-back.

    `shape`/`dtype` describe the logical array the pages back; they are
    persisted to the sidecar on create and recovered on reopen.
    """

    def __init__(self, path: str, *, page_size: int = PAGE_SIZE,
                 shape: tuple | None = None, dtype: str = "float32",
                 use_mmap: bool = False,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = DEFAULT_RETRY,
                 on_retry: Optional[OnRetry] = None):
        self.path = path
        self.page_size = int(page_size)
        self.use_mmap = use_mmap
        self.faults = faults
        self.retry = retry
        self.on_retry = on_retry
        self._mmap = None
        meta = _meta_path(path)
        if os.path.exists(meta):
            with open(meta) as f:
                m = json.load(f)
            self.page_size = int(m["page_size"])
            self.shape = tuple(m["shape"])
            self.dtype = np.dtype(m["dtype"])
        else:
            if shape is None:
                raise FileNotFoundError(f"no page file metadata at {meta}")
            self.shape = tuple(int(s) for s in shape)
            self.dtype = np.dtype(dtype)
            with open(meta, "w") as f:
                json.dump({"page_size": self.page_size,
                           "shape": list(self.shape),
                           "dtype": self.dtype.name}, f)
        self.nbytes = int(np.prod(self.shape)) * self.dtype.itemsize
        self.n_pages = max(1, -(-self.nbytes // self.page_size))
        flags = os.O_RDWR | os.O_CREAT
        self._fd = os.open(path, flags, 0o644)
        size = self.n_pages * self.page_size
        if os.fstat(self._fd).st_size < size:
            os.ftruncate(self._fd, size)
        self._recover()

    # ------------------------------------------------------------- raw I/O
    def read_page(self, i: int) -> bytes:
        """Positional page read (pread — safe from the prefetch thread)."""
        assert 0 <= i < self.n_pages, (i, self.n_pages)
        if self.use_mmap:
            if self._mmap is None:
                import mmap
                self._mmap = mmap.mmap(self._fd, self.n_pages * self.page_size)
            off = i * self.page_size
            return bytes(self._mmap[off:off + self.page_size])
        return os.pread(self._fd, self.page_size, i * self.page_size)

    def read_run(self, start: int, count: int) -> List[bytes]:
        """Read `count` consecutive pages with one vectored syscall per
        _IOV_MAX pages: a single preadv into per-page buffers replaces
        `count` python pread calls (the 4 KiB-grain fast path). Each
        chunk is a retry unit: transient errors (injected or real EIO)
        are retried with backoff per `self.retry`; exhaustion raises
        `SafsIOError` with file/page context."""
        assert 0 <= start and start + count <= self.n_pages, \
            (start, count, self.n_pages)
        if self.use_mmap:
            return [self.read_page(start + k) for k in range(count)]
        out: List[bytes] = []
        done = 0
        while done < count:
            nv = min(count - done, _IOV_MAX)   # bounds the staging buffer
            out.extend(self._read_chunk(start + done, nv))
            done += nv
        return out

    def _read_chunk(self, start: int, nv: int) -> List[bytes]:
        ps = self.page_size

        def attempt() -> List[bytes]:
            action = None
            if self.faults is not None:
                action = self.faults.check("pread", file=self.path,
                                           page=start, pages=nv)
            mv = memoryview(bytearray(nv * ps))
            off = start * ps
            want = nv * ps
            # an injected short read truncates the FIRST preadv to one
            # page; the continuation loop below must complete the chunk
            first = ps if (action == "short_read" and want > ps) else want
            got = os.preadv(self._fd, [mv[:first]], off)
            while got < want:          # short read (signal/EOF-adjacent)
                n = os.preadv(self._fd, [mv[got:]], off + got)
                if n <= 0:
                    raise IOError(
                        f"short preadv at page {start + got // ps}")
                got += n
            return [bytes(mv[k * ps:(k + 1) * ps]) for k in range(nv)]

        return with_retries(attempt, self.retry, site="pread",
                            file=self.path, page=start,
                            on_retry=self.on_retry)

    def read_pages_batch(self, indices: Sequence[int]) -> Dict[int, bytes]:
        """Batched page read: coalesce `indices` into contiguous runs and
        issue one vectored preadv per run (§3.4.2 request merging)."""
        pages: Dict[int, bytes] = {}
        for start, count in coalesce_runs(indices):
            for k, payload in enumerate(self.read_run(start, count)):
                pages[start + k] = payload
        return pages

    def _write_page_raw(self, i: int, data: bytes) -> None:
        assert len(data) == self.page_size
        if self._mmap is not None:
            off = i * self.page_size
            self._mmap[off:off + self.page_size] = data
        else:
            os.pwrite(self._fd, data, i * self.page_size)

    # --------------------------------------------------- journaled flush
    def write_pages(self, pages: Dict[int, bytes], *,
                    crash_after_pages: Optional[int] = None,
                    crash_in_journal: bool = False) -> int:
        """Crash-consistent write-back of a batch of dirty pages.

        Returns the number of bytes written to the main file (the
        endurance-relevant count; journal bytes are transient). The two
        crash hooks abort, respectively, after `crash_after_pages` in-place
        page writes (journal already committed → redo on reopen) and
        mid-journal before the commit trailer (→ discard on reopen).
        """
        if not pages:
            return 0
        jp = _journal_path(self.path)
        with open(jp, "wb") as j:
            j.write(_JOURNAL_MAGIC)
            for k, (i, data) in enumerate(sorted(pages.items())):
                assert len(data) == self.page_size
                j.write(_HDR.pack(i, zlib.crc32(data), len(data)))
                j.write(data)
                if crash_in_journal and k + 1 == len(pages):
                    j.flush()
                    os.fsync(j.fileno())
                    raise CrashPoint("crash before journal commit")
            j.flush()
            os.fsync(j.fileno())
            # journal durable, commit trailer not: a crash here discards
            self._fault("journal.precommit", pages=len(pages))
            j.write(_COMMIT)
            j.flush()
            os.fsync(j.fileno())
        # journal committed, in-place patch not started: a crash from
        # here on is redone on reopen (the batch is already durable)
        self._fault("journal.commit", pages=len(pages))
        written = 0
        if crash_after_pages is not None or self._mmap is not None:
            # crash-hook path keeps the per-page write granularity the
            # hooks are defined against (k counts in-place page writes)
            for k, (i, data) in enumerate(sorted(pages.items())):
                if crash_after_pages is not None and k >= crash_after_pages:
                    raise CrashPoint(f"crash after {k} in-place page writes")
                self._write_page_raw(i, data)
                written += len(data)
        else:
            written = self._pwritev_runs(pages)
        self.sync()
        try:
            os.unlink(jp)
        except FileNotFoundError:
            pass      # a concurrent reopen already recovered + unlinked it
        return written

    def _fault(self, site: str, **ctx) -> Optional[str]:
        if self.faults is not None:
            return self.faults.check(site, file=self.path, **ctx)
        return None

    def _pwritev_runs(self, pages: Dict[int, bytes]) -> int:
        """In-place patch as one vectored pwritev per contiguous run.
        Each chunk is a retry unit (idempotent: same bytes, same
        offsets), so a transient mid-patch error costs a re-write of the
        chunk, never a torn page — the journal is already committed."""
        written = 0
        for start, count in coalesce_runs(pages.keys()):
            done = 0
            while done < count:
                nv = min(count - done, _IOV_MAX)
                written += self._write_chunk(pages, start + done, nv)
                done += nv
        return written

    def _write_chunk(self, pages: Dict[int, bytes], start: int,
                     nv: int) -> int:
        def attempt() -> int:
            self._fault("pwritev", page=start, pages=nv)
            bufs = [pages[start + k] for k in range(nv)]
            for b in bufs:             # offsets assume full pages
                assert len(b) == self.page_size, len(b)
            off = start * self.page_size
            want = nv * self.page_size
            got = os.pwritev(self._fd, bufs, off)
            while got < want:          # short write: retry the remainder
                flat = b"".join(bufs)
                n = os.pwrite(self._fd, flat[got:], off + got)
                if n <= 0:
                    raise IOError(
                        f"short pwrite at page "
                        f"{start + got // self.page_size}")
                got += n
            return want

        return with_retries(attempt, self.retry, site="pwritev",
                            file=self.path, page=start,
                            on_retry=self.on_retry)

    def _recover(self) -> None:
        """Replay a committed journal; discard an uncommitted one."""
        jp = _journal_path(self.path)
        if not os.path.exists(jp):
            return
        with open(jp, "rb") as j:
            blob = j.read()
        ok = blob.startswith(_JOURNAL_MAGIC) and blob.endswith(_COMMIT)
        if ok:
            off = len(_JOURNAL_MAGIC)
            end = len(blob) - len(_COMMIT)
            while off < end:
                i, crc, n = _HDR.unpack_from(blob, off)
                off += _HDR.size
                data = blob[off:off + n]
                off += n
                if zlib.crc32(data) != crc:   # torn journal: abort replay
                    ok = False
                    break
                self._write_page_raw(i, data)
            self.sync()
        try:
            os.unlink(jp)
        except FileNotFoundError:
            pass
        return

    def sync(self) -> None:
        if self._mmap is not None:
            self._mmap.flush()
        os.fsync(self._fd)

    # --------------------------------------------------------- array view
    def page_indices(self) -> Iterable[int]:
        return range(self.n_pages)

    def pages_of_slice(self, byte_lo: int, byte_hi: int) -> range:
        """Pages overlapping the byte range [lo, hi) of the logical array."""
        return range(byte_lo // self.page_size,
                     -(-byte_hi // self.page_size))

    def assemble(self, pages: Dict[int, bytes]) -> np.ndarray:
        """Rebuild the logical array from a full set of page payloads."""
        buf = b"".join(pages[i] for i in range(self.n_pages))
        return np.frombuffer(buf[:self.nbytes],
                             dtype=self.dtype).reshape(self.shape).copy()

    def split(self, arr: np.ndarray) -> Dict[int, bytes]:
        """Split the logical array into zero-padded page payloads."""
        raw = np.ascontiguousarray(arr, dtype=self.dtype).tobytes()
        raw += b"\0" * (self.n_pages * self.page_size - len(raw))
        return {i: raw[i * self.page_size:(i + 1) * self.page_size]
                for i in range(self.n_pages)}

    def close(self) -> None:
        if self._mmap is not None:
            self._mmap.close()
            self._mmap = None
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def delete(self) -> None:
        self.close()
        for p in (self.path, _meta_path(self.path), _journal_path(self.path)):
            if os.path.exists(p):
                os.unlink(p)
