"""PageFile — one on-disk file per TAS matrix, split into fixed-size pages.

The paper stores the vector subspace on SSDs behind SAFS, one file per
dense (TAS) matrix (§3.4.1); SAFS moves data in pages and the eigensolver
never overwrites a page it could instead avoid writing (write endurance,
Table 3). This module is the byte level of our reproduction of that layer:

  * a file is an array of PAGE_SIZE-byte pages, page i at offset
    i * page_size; reads go through pread (positional, thread-safe — the
    prefetcher reads concurrently with the consumer) or an optional mmap;
  * batched reads coalesce the requested pages into maximal contiguous
    *runs* and issue one vectored `os.preadv` per run (§3.4.2's request
    merging): at SAFS's native 4 KiB grain this turns ~16 python syscalls
    per 64 KiB of subspace into one, which is where the fast-path
    throughput comes from (see `read_pages_batch` / BENCH_safs.json);
    in-place journal patches likewise go out as one `os.pwritev` per run;
  * dirty-page write-back is crash consistent via a per-file journal:
    a flush first writes every dirty page plus a checksum to
    `<file>.journal`, fsyncs, appends a commit trailer, and only then
    patches the main file in place. Reopening after a crash replays a
    committed journal (redo) or discards an uncommitted one, so every
    page is always either entirely-old or entirely-new — never torn;
  * shape/dtype metadata lives in a `<file>.meta` JSON sidecar so a page
    store can be reopened cold (checkpoint restore path).

Tests inject crashes with the `crash_after_pages` / `crash_in_journal`
hooks instead of killing the process; the on-disk states they produce are
exactly the ones a mid-flush kill leaves behind. `repro.safs.faults`
generalizes those hooks into seeded schedules (`PageFile(faults=plan)`):
the plan is consulted at every preadv/pwritev chunk and at the journal
pre-commit/commit boundaries, and transient errors at those sites are
absorbed by bounded retry with backoff (`retry=RetryPolicy(...)`,
counted via `on_retry` and emitted as `safs.retry` trace events).

Integrity: every page carries a CRC32C-style checksum in a `<file>.sums`
sidecar block, journaled with the same crash-consistency as the data —
the sidecar is rewritten (durably) *before* the batch's journal is
unlinked, so any crash window in which data and checksums could disagree
is exactly the window the journal replay already covers. `read_run` (and
therefore every fill/miss path) verifies payloads against the block; a
persistent mismatch raises a typed `CorruptPageError(site, file, page)`
and emits a `safs.corrupt` trace event — silent bit-rot is detected at
the read boundary, never served upward into Ritz vectors. A transient
mismatch (a read racing an in-place patch, or an injected single-shot
`bitflip` in the transfer) is healed by re-reading the page and counted
as a `crc_retries` integrity event.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace
from repro.safs.faults import (CorruptPageError, CrashPoint, DEFAULT_RETRY,
                               FaultPlan, IntegrityCounters, OnRetry,
                               RetryPolicy, with_retries)

PAGE_SIZE = 4096                       # SAFS default page size (§3.4.1)

# Max iovecs per preadv/pwritev syscall (POSIX IOV_MAX is >= 1024 on Linux);
# longer runs are split — still one syscall per IOV_MAX pages, not per page.
_IOV_MAX = 1024


def coalesce_runs(indices: Sequence[int]) -> List[Tuple[int, int]]:
    """Merge page indices into maximal contiguous (start, count) runs.

    The batched I/O engine's request merging: sorted, de-duplicated, and
    adjacency-coalesced so each run becomes a single vectored syscall.
    """
    runs: List[Tuple[int, int]] = []
    for i in sorted(set(int(i) for i in indices)):
        if runs and i == runs[-1][0] + runs[-1][1]:
            runs[-1] = (runs[-1][0], runs[-1][1] + 1)
        else:
            runs.append((i, 1))
    return runs

_JOURNAL_MAGIC = b"SAFSJRNL"
_COMMIT = b"COMMITTD"
_HDR = struct.Struct("<qII")           # page_index, crc32, payload_len

# Checksum sidecar block: magic | algo | page_size | n_pages | u32 CRC per
# page | crc32-of-table trailer. Rewritten atomically (tmp + rename) before
# each batch's journal unlink, so it shares the journal's crash window.
_SUMS_MAGIC = b"SAFSSUMS"
_SUMS_HDR = struct.Struct("<BIQ")      # algo_id, page_size, n_pages

try:                    # hardware CRC32C (Castagnoli) when the wheel exists
    from crc32c import crc32c as _crc32c        # type: ignore
    _CRC_ALGO = 1
except ImportError:     # stdlib fallback — same 32-bit contract, no new dep
    _crc32c = None
    _CRC_ALGO = 0


def page_crc(data) -> int:
    """Per-page content checksum: CRC32C if the accelerated wheel is
    importable, zlib.crc32 otherwise. The sidecar records which algorithm
    produced it and is rebuilt (adopt-current-content) on mismatch."""
    if _crc32c is not None:
        return _crc32c(data)
    return zlib.crc32(data)


_ZERO_CRC: Dict[int, int] = {}          # page_size -> crc of an all-zero page


def _zero_crc(page_size: int) -> int:
    c = _ZERO_CRC.get(page_size)
    if c is None:
        c = _ZERO_CRC[page_size] = page_crc(b"\0" * page_size)
    return c


def flip_bit(path: str, page: int, *, page_size: int = PAGE_SIZE,
             bit: int = 0) -> None:
    """Flip one bit of one page directly on the medium — the test/smoke
    hook for at-rest silent corruption (what a FaultRule cannot model:
    the bytes rotted while nobody was reading or writing them)."""
    fd = os.open(path, os.O_RDWR)
    try:
        off = page * page_size + bit // 8
        b = os.pread(fd, 1, off)
        os.pwrite(fd, bytes([b[0] ^ (1 << (bit % 8))]), off)
        os.fsync(fd)
    finally:
        os.close(fd)


def _flip_payload(data: bytes) -> bytes:
    """The injected `bitflip` action: corrupt the lowest bit of byte 0."""
    b = bytearray(data)
    b[0] ^= 1
    return bytes(b)


# CrashPoint moved to repro.safs.faults (the fault-injection layer owns the
# error taxonomy); re-exported here for existing importers.
__all__ = ["PAGE_SIZE", "CorruptPageError", "CrashPoint", "PageFile",
           "coalesce_runs", "flip_bit", "page_crc"]


def _meta_path(path: str) -> str:
    return path + ".meta"


def _journal_path(path: str) -> str:
    return path + ".journal"


def _sums_path(path: str) -> str:
    return path + ".sums"


class PageFile:
    """Fixed-size-page file with journaled, crash-consistent write-back.

    `shape`/`dtype` describe the logical array the pages back; they are
    persisted to the sidecar on create and recovered on reopen.
    """

    def __init__(self, path: str, *, page_size: int = PAGE_SIZE,
                 shape: tuple | None = None, dtype: str = "float32",
                 use_mmap: bool = False,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = DEFAULT_RETRY,
                 on_retry: Optional[OnRetry] = None,
                 verify: bool = True,
                 integrity: Optional[IntegrityCounters] = None,
                 on_corrupt: Optional[OnRetry] = None):
        self.path = path
        self.page_size = int(page_size)
        self.use_mmap = use_mmap
        self.faults = faults
        self.retry = retry
        self.on_retry = on_retry
        # verify: CRC-check every payload `read_run` returns against the
        # sidecar block; a persistent mismatch raises CorruptPageError.
        # integrity/on_corrupt: shared counter block + detection hook (the
        # backend quarantines the page and splits counters per store).
        self.verify = bool(verify)
        self.integrity = integrity
        self.on_corrupt = on_corrupt
        self._mmap = None
        meta = _meta_path(path)
        if os.path.exists(meta):
            with open(meta) as f:
                m = json.load(f)
            self.page_size = int(m["page_size"])
            self.shape = tuple(m["shape"])
            self.dtype = np.dtype(m["dtype"])
        else:
            if shape is None:
                raise FileNotFoundError(f"no page file metadata at {meta}")
            self.shape = tuple(int(s) for s in shape)
            self.dtype = np.dtype(dtype)
            with open(meta, "w") as f:
                json.dump({"page_size": self.page_size,
                           "shape": list(self.shape),
                           "dtype": self.dtype.name}, f)
        self.nbytes = int(np.prod(self.shape)) * self.dtype.itemsize
        self.n_pages = max(1, -(-self.nbytes // self.page_size))
        flags = os.O_RDWR | os.O_CREAT
        self._fd = os.open(path, flags, 0o644)
        fresh = os.fstat(self._fd).st_size == 0
        size = self.n_pages * self.page_size
        if os.fstat(self._fd).st_size < size:
            os.ftruncate(self._fd, size)
        self._sums_lock = threading.Lock()
        self._sums = self._load_sums(fresh)
        self._recover()

    # -------------------------------------------------------- checksum block
    def _load_sums(self, fresh: bool) -> List[int]:
        """Load the sidecar checksum block; a fresh file gets zero-page
        CRCs, a missing/invalid/foreign-algo sidecar is rebuilt from the
        current file content (adopt — legacy stores verify from now on)."""
        sp = _sums_path(self.path)
        if os.path.exists(sp):
            try:
                with open(sp, "rb") as f:
                    blob = f.read()
                if (blob.startswith(_SUMS_MAGIC)
                        and len(blob) >= len(_SUMS_MAGIC) + _SUMS_HDR.size + 4):
                    algo, ps, n = _SUMS_HDR.unpack_from(blob,
                                                        len(_SUMS_MAGIC))
                    body = blob[len(_SUMS_MAGIC) + _SUMS_HDR.size:-4]
                    (tcrc,) = struct.unpack("<I", blob[-4:])
                    if (algo == _CRC_ALGO and ps == self.page_size
                            and n == self.n_pages and len(body) == 4 * n
                            and zlib.crc32(body) == tcrc):
                        return list(np.frombuffer(body, dtype="<u4"))
            except OSError:
                pass
        if fresh:
            sums = [_zero_crc(self.page_size)] * self.n_pages
        else:
            sums = []
            for i in range(self.n_pages):
                sums.append(page_crc(
                    os.pread(self._fd, self.page_size, i * self.page_size)))
        self._sums = sums
        self._store_sums()
        return sums

    def _store_sums(self) -> None:
        """Durably rewrite the sidecar (tmp + fsync + rename). Called with
        current in-memory sums; crash windows are covered by the journal
        (the batch's journal is only unlinked after this persists)."""
        sp = _sums_path(self.path)
        body = np.asarray(self._sums, dtype="<u4").tobytes()
        blob = (_SUMS_MAGIC
                + _SUMS_HDR.pack(_CRC_ALGO, self.page_size, self.n_pages)
                + body + struct.pack("<I", zlib.crc32(body)))
        tmp = sp + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, sp)

    def _sum(self, i: int) -> int:
        with self._sums_lock:
            return self._sums[i]

    def _set_sums(self, pages: Dict[int, bytes], *, persist: bool) -> None:
        with self._sums_lock:
            for i, data in pages.items():
                self._sums[i] = page_crc(data)
        if persist:
            self._store_sums()

    # ------------------------------------------------------------- raw I/O
    def read_page(self, i: int) -> bytes:
        """Positional page read (pread — safe from the prefetch thread)."""
        assert 0 <= i < self.n_pages, (i, self.n_pages)
        if self.use_mmap:
            if self._mmap is None:
                import mmap
                self._mmap = mmap.mmap(self._fd, self.n_pages * self.page_size)
            off = i * self.page_size
            return bytes(self._mmap[off:off + self.page_size])
        return os.pread(self._fd, self.page_size, i * self.page_size)

    def read_run(self, start: int, count: int) -> List[bytes]:
        """Read `count` consecutive pages with one vectored syscall per
        _IOV_MAX pages: a single preadv into per-page buffers replaces
        `count` python pread calls (the 4 KiB-grain fast path). Each
        chunk is a retry unit: transient errors (injected or real EIO)
        are retried with backoff per `self.retry`; exhaustion raises
        `SafsIOError` with file/page context."""
        assert 0 <= start and start + count <= self.n_pages, \
            (start, count, self.n_pages)
        if self.use_mmap:
            out = [self.read_page(start + k) for k in range(count)]
        else:
            out = []
            done = 0
            while done < count:
                nv = min(count - done, _IOV_MAX)  # bounds the staging buffer
                out.extend(self._read_chunk(start + done, nv))
                done += nv
        if self.verify:
            for k in range(count):
                out[k] = self._verify_payload(start + k, out[k])
            if self.integrity is not None:
                self.integrity.add(pages_verified=count)
        return out

    # ------------------------------------------------------- verification
    def _reread_page(self, i: int) -> bytes:
        """Single-page raw re-read for checksum arbitration. Consults the
        fault plan (a persistent transfer fault keeps corrupting the
        re-read and is therefore *detected*; a single-shot one heals)."""
        if self.use_mmap:
            return self.read_page(i)
        action = None
        if self.faults is not None:
            action = self.faults.check("pread", file=self.path,
                                       page=i, pages=1)
        data = os.pread(self._fd, self.page_size, i * self.page_size)
        return _flip_payload(data) if action == "bitflip" else data

    def _verify_payload(self, i: int, data: bytes, *,
                        site: str = "pread") -> bytes:
        """CRC-check one payload. A mismatch is re-arbitrated by re-reading
        the page (it may be a benign torn read racing an in-place patch,
        or a transient transfer flip — both heal and count as
        `crc_retries`); a persistent mismatch is silent corruption: emit
        `safs.corrupt`, count `crc_failures`, raise typed."""
        if page_crc(data) == self._sum(i):
            return data
        pause = 0.001
        for _ in range(5):
            time.sleep(pause)
            pause *= 2
            data = self._reread_page(i)
            if page_crc(data) == self._sum(i):
                if self.integrity is not None:
                    self.integrity.add(crc_retries=1)
                return data
        trace.event("safs.corrupt", site=site, file=self.path, page=i)
        if self.integrity is not None:
            self.integrity.add(crc_failures=1)
        if self.on_corrupt is not None:
            self.on_corrupt(site=site, file=self.path, page=i)
        raise CorruptPageError(site=site, file=self.path, page=i)

    def verify_pages(self, indices: Optional[Sequence[int]] = None,
                     *, reread: int = 2) -> List[int]:
        """Scrub primitive: raw medium check of `indices` (default: every
        page) against the checksum block. Never raises and never serves
        bytes — returns the indices whose mismatch survived `reread`
        arbitration re-reads (racing write-back heals; bit-rot persists).
        The caller (the scrubber / backend) does the counting,
        quarantining and event emission."""
        bad: List[int] = []
        for i in (range(self.n_pages) if indices is None else indices):
            data = os.pread(self._fd, self.page_size, i * self.page_size)
            ok = page_crc(data) == self._sum(i)
            for _ in range(reread):
                if ok:
                    break
                time.sleep(0.002)
                data = os.pread(self._fd, self.page_size, i * self.page_size)
                ok = page_crc(data) == self._sum(i)
            if not ok:
                bad.append(i)
        return bad

    def _read_chunk(self, start: int, nv: int) -> List[bytes]:
        ps = self.page_size

        def attempt() -> List[bytes]:
            action = None
            if self.faults is not None:
                action = self.faults.check("pread", file=self.path,
                                           page=start, pages=nv)
            mv = memoryview(bytearray(nv * ps))
            off = start * ps
            want = nv * ps
            # an injected short read truncates the FIRST preadv to one
            # page; the continuation loop below must complete the chunk
            first = ps if (action == "short_read" and want > ps) else want
            got = os.preadv(self._fd, [mv[:first]], off)
            while got < want:          # short read (signal/EOF-adjacent)
                n = os.preadv(self._fd, [mv[got:]], off + got)
                if n <= 0:
                    raise IOError(
                        f"short preadv at page {start + got // ps}")
                got += n
            out = [bytes(mv[k * ps:(k + 1) * ps]) for k in range(nv)]
            if action == "bitflip":    # corruption in the transfer: the
                out[0] = _flip_payload(out[0])   # checksum layer's problem
            return out

        return with_retries(attempt, self.retry, site="pread",
                            file=self.path, page=start,
                            on_retry=self.on_retry)

    def read_pages_batch(self, indices: Sequence[int]) -> Dict[int, bytes]:
        """Batched page read: coalesce `indices` into contiguous runs and
        issue one vectored preadv per run (§3.4.2 request merging)."""
        pages: Dict[int, bytes] = {}
        for start, count in coalesce_runs(indices):
            for k, payload in enumerate(self.read_run(start, count)):
                pages[start + k] = payload
        return pages

    def _write_page_raw(self, i: int, data: bytes) -> None:
        assert len(data) == self.page_size
        if self._mmap is not None:
            off = i * self.page_size
            self._mmap[off:off + self.page_size] = data
        else:
            os.pwrite(self._fd, data, i * self.page_size)

    # --------------------------------------------------- journaled flush
    def write_pages(self, pages: Dict[int, bytes], *,
                    crash_after_pages: Optional[int] = None,
                    crash_in_journal: bool = False) -> int:
        """Crash-consistent write-back of a batch of dirty pages.

        Returns the number of bytes written to the main file (the
        endurance-relevant count; journal bytes are transient). The two
        crash hooks abort, respectively, after `crash_after_pages` in-place
        page writes (journal already committed → redo on reopen) and
        mid-journal before the commit trailer (→ discard on reopen).
        """
        if not pages:
            return 0
        jp = _journal_path(self.path)
        with open(jp, "wb") as j:
            j.write(_JOURNAL_MAGIC)
            for k, (i, data) in enumerate(sorted(pages.items())):
                assert len(data) == self.page_size
                j.write(_HDR.pack(i, zlib.crc32(data), len(data)))
                j.write(data)
                if crash_in_journal and k + 1 == len(pages):
                    j.flush()
                    os.fsync(j.fileno())
                    raise CrashPoint("crash before journal commit")
            j.flush()
            os.fsync(j.fileno())
            # journal durable, commit trailer not: a crash here discards
            self._fault("journal.precommit", pages=len(pages))
            j.write(_COMMIT)
            j.flush()
            os.fsync(j.fileno())
        # journal committed, in-place patch not started: a crash from
        # here on is redone on reopen (the batch is already durable)
        self._fault("journal.commit", pages=len(pages))
        written = 0
        if crash_after_pages is not None or self._mmap is not None:
            # crash-hook path keeps the per-page write granularity the
            # hooks are defined against (k counts in-place page writes)
            for k, (i, data) in enumerate(sorted(pages.items())):
                if crash_after_pages is not None and k >= crash_after_pages:
                    raise CrashPoint(f"crash after {k} in-place page writes")
                self._write_page_raw(i, data)
                written += len(data)
        else:
            written = self._pwritev_runs(pages)
        self.sync()
        # checksum block BEFORE the journal unlink: a crash anywhere in
        # between replays the journal on reopen, which re-derives exactly
        # these sums — data and checksums can never durably disagree
        self._set_sums(pages, persist=True)
        try:
            os.unlink(jp)
        except FileNotFoundError:
            pass      # a concurrent reopen already recovered + unlinked it
        return written

    def _fault(self, site: str, **ctx) -> Optional[str]:
        if self.faults is not None:
            return self.faults.check(site, file=self.path, **ctx)
        return None

    def _pwritev_runs(self, pages: Dict[int, bytes]) -> int:
        """In-place patch as one vectored pwritev per contiguous run.
        Each chunk is a retry unit (idempotent: same bytes, same
        offsets), so a transient mid-patch error costs a re-write of the
        chunk, never a torn page — the journal is already committed."""
        written = 0
        for start, count in coalesce_runs(pages.keys()):
            done = 0
            while done < count:
                nv = min(count - done, _IOV_MAX)
                written += self._write_chunk(pages, start + done, nv)
                done += nv
        return written

    def _write_chunk(self, pages: Dict[int, bytes], start: int,
                     nv: int) -> int:
        def attempt() -> int:
            action = self._fault("pwritev", page=start, pages=nv)
            bufs = [pages[start + k] for k in range(nv)]
            for b in bufs:             # offsets assume full pages
                assert len(b) == self.page_size, len(b)
            off = start * self.page_size
            want = nv * self.page_size
            if action == "bitflip":
                # silent media corruption: flipped bits land on disk while
                # the checksum block keeps the intended CRC — every later
                # read/scrub of this page detects the mismatch
                bufs = [_flip_payload(bufs[0])] + bufs[1:]
            elif action == "torn_page":
                # power-cut torn write: only the first half of the first
                # page persists; the rest of the chunk lands normally
                os.pwrite(self._fd, bufs[0][:self.page_size // 2], off)
                if nv > 1:
                    os.pwritev(self._fd, bufs[1:], off + self.page_size)
                return want
            got = os.pwritev(self._fd, bufs, off)
            while got < want:          # short write: retry the remainder
                flat = b"".join(bufs)
                n = os.pwrite(self._fd, flat[got:], off + got)
                if n <= 0:
                    raise IOError(
                        f"short pwrite at page "
                        f"{start + got // self.page_size}")
                got += n
            return want

        return with_retries(attempt, self.retry, site="pwritev",
                            file=self.path, page=start,
                            on_retry=self.on_retry)

    def _recover(self) -> None:
        """Replay a committed journal; discard an uncommitted one."""
        jp = _journal_path(self.path)
        if not os.path.exists(jp):
            return
        with open(jp, "rb") as j:
            blob = j.read()
        ok = blob.startswith(_JOURNAL_MAGIC) and blob.endswith(_COMMIT)
        if ok:
            off = len(_JOURNAL_MAGIC)
            end = len(blob) - len(_COMMIT)
            replayed: Dict[int, bytes] = {}
            while off < end:
                i, crc, n = _HDR.unpack_from(blob, off)
                off += _HDR.size
                data = blob[off:off + n]
                off += n
                if zlib.crc32(data) != crc:   # torn journal: abort replay
                    ok = False
                    break
                self._write_page_raw(i, data)
                replayed[i] = data
            self.sync()
            if replayed:   # re-derive the sums the interrupted batch meant
                self._set_sums(replayed, persist=True)
        try:
            os.unlink(jp)
        except FileNotFoundError:
            pass
        return

    def sync(self) -> None:
        if self._mmap is not None:
            self._mmap.flush()
        os.fsync(self._fd)

    # --------------------------------------------------------- array view
    def page_indices(self) -> Iterable[int]:
        return range(self.n_pages)

    def pages_of_slice(self, byte_lo: int, byte_hi: int) -> range:
        """Pages overlapping the byte range [lo, hi) of the logical array."""
        return range(byte_lo // self.page_size,
                     -(-byte_hi // self.page_size))

    def assemble(self, pages: Dict[int, bytes]) -> np.ndarray:
        """Rebuild the logical array from a full set of page payloads."""
        buf = b"".join(pages[i] for i in range(self.n_pages))
        return np.frombuffer(buf[:self.nbytes],
                             dtype=self.dtype).reshape(self.shape).copy()

    def split(self, arr: np.ndarray) -> Dict[int, bytes]:
        """Split the logical array into zero-padded page payloads."""
        raw = np.ascontiguousarray(arr, dtype=self.dtype).tobytes()
        raw += b"\0" * (self.n_pages * self.page_size - len(raw))
        return {i: raw[i * self.page_size:(i + 1) * self.page_size]
                for i in range(self.n_pages)}

    def close(self) -> None:
        if self._mmap is not None:
            self._mmap.close()
            self._mmap = None
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def delete(self) -> None:
        self.close()
        for p in (self.path, _meta_path(self.path), _journal_path(self.path),
                  _sums_path(self.path), _sums_path(self.path) + ".tmp"):
            if os.path.exists(p):
                os.unlink(p)
