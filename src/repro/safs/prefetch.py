"""Multi-worker readahead scheduler — overlap SSD page reads with compute.

FlashGraph's contribution (and this paper's §3.4.2/§3.4.3) is that SEM
performance lives or dies on overlapping disk with compute: while the
eigensolver contracts one group of subspace blocks, SAFS should already be
streaming the *next* groups' pages. PR 2's version of this module was a
single-worker double buffer (one dispatch thread, one group ahead); this is
the full readahead scheduler the paper's SAFS actually runs:

  * `schedule(data_ids)` enqueues whole-file batched page reads on a pool
    of `io_workers` daemon threads (each read is one `read_pages_batch`
    in the backend — coalesced preadv runs, not a python page loop);
    the queue is bounded by `depth` files — the readahead window. Ids
    past the window are *dropped*, not queued: the caller re-announces
    its access pattern as the walk advances (`core.stream.SubspacePass`
    announces the full pass up front, then re-offers the sliding window
    each block visit), so a dropped id is re-offered once the window has
    advanced. This
    bounds both queue memory and cache thrash from overly deep readahead;
  * workers fill the shared PageCache with clean lines only (prefetch is
    read-only — it never dirties a page);
  * the consumer calls `wait(data_id)` (the backend does, inside `load`)
    before using a file; the time actually blocked there is the
    *un*-overlapped remainder. A reader exception is captured and
    re-raised from `wait` (as `PrefetchError`), and a dead worker pool is
    detected rather than waited on forever — `wait` never hangs;
  * overlap accounting: `overlap_seconds() = busy_seconds - wait_seconds`
    where busy sums reader wall time across workers (it can exceed
    wall-clock when io_workers > 1) — the disk time hidden behind
    compute. `bench_safs.py` reports it and the derived overlap fraction.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, Optional

from repro.obs import trace
from repro.safs.faults import OnRetry, is_transient


class PrefetchError(RuntimeError):
    """A background reader failed; re-raised at the consumer's wait()."""


class Prefetcher:
    """Multi-worker readahead scheduler over a shared PageCache.

    `reader(data_id) -> int` performs the actual cache fill for one file
    and returns bytes read from disk (the backend provides it; it skips
    pages already resident and batches the rest into vectored runs).

    io_workers: reader threads issuing concurrent fills (NVMe wants queue
        depth; one python thread per in-flight file works the GIL because
        preadv releases it).
    depth: readahead window — max files queued beyond the ones in flight.
    retries: whole-fill retries a worker attempts on a *transient* reader
        error before capturing it for `wait()` — a second defense above
        the page-level retry inside `PageFile.read_run` (which already
        absorbs transient preadv errors; this layer catches transient
        failures that escape it, e.g. around the fill's staging logic).
        Retries are counted (`stats()["read_retries"]`), emitted as
        `safs.retry` trace events and reported through `on_retry`.
    """

    def __init__(self, reader: Callable[[str], int], *,
                 io_workers: int = 2, depth: int = 8, retries: int = 1,
                 on_retry: Optional[OnRetry] = None):
        self._reader = reader
        self.retries = max(0, int(retries))
        self._on_retry = on_retry
        self.read_retries = 0
        self.io_workers = max(1, int(io_workers))
        self.depth = max(1, int(depth))
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: Deque[str] = deque()
        self._done: Dict[str, threading.Event] = {}
        self._errors: Dict[str, BaseException] = {}
        self._tasks: Dict[str, Callable[[], int]] = {}
        self._shutdown = False
        self.busy_seconds = 0.0
        self.wait_seconds = 0.0
        self.bytes_prefetched = 0
        self.files_prefetched = 0
        self.files_dropped = 0      # offered past the readahead window
        self.tasks_run = 0          # generic pool tasks (scrub verifies)
        self.read_errors = 0
        self._threads = [threading.Thread(target=self._run, daemon=True,
                                          name=f"safs-ra-{i}")
                         for i in range(self.io_workers)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- workers
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._shutdown:
                    self._cv.wait()
                if self._shutdown and not self._pending:
                    return
                data_id = self._pending.popleft()
                ev = self._done.get(data_id)
                task = self._tasks.pop(data_id, None)
            t0 = time.perf_counter()
            err: Optional[BaseException] = None
            n = 0
            for attempt in range(self.retries + 1):
                err = None
                try:
                    n = task() if task is not None else self._reader(data_id)
                    break
                except BaseException as e:  # captured, re-raised at wait()
                    err = e
                    if attempt >= self.retries or not is_transient(e):
                        break
                    with self._lock:
                        self.read_retries += 1
                    trace.event("safs.retry", site="prefetch", file=data_id,
                                attempt=attempt + 1,
                                error=type(e).__name__)
                    if self._on_retry is not None:
                        self._on_retry(site="prefetch", file=data_id,
                                       page=None, attempt=attempt + 1,
                                       error=e)
                    time.sleep(0.002 * (attempt + 1))
            dt = time.perf_counter() - t0
            with self._lock:
                self.busy_seconds += dt
                if err is not None:
                    self._errors[data_id] = err
                    self.read_errors += 1
                elif task is not None:
                    self.tasks_run += 1   # pool tasks don't skew the
                    #                       prefetch byte/file gauges
                else:
                    self.bytes_prefetched += n
                    self.files_prefetched += 1
            if ev is not None:
                ev.set()

    # ----------------------------------------------------------- frontend
    def schedule(self, data_ids) -> None:
        """Announce upcoming reads. Ids already in flight are ignored; ids
        past the `depth` readahead window are dropped (re-offer later)."""
        with self._cv:
            for d in data_ids:
                ev = self._done.get(d)
                if ev is not None and not ev.is_set():
                    continue             # already queued or in flight
                if len(self._pending) >= self.depth:
                    self.files_dropped += 1
                    continue
                self._errors.pop(d, None)
                self._done[d] = threading.Event()
                self._pending.append(d)
            self._cv.notify_all()

    def submit(self, key: str, fn: Callable[[], int]) -> bool:
        """Run an arbitrary zero-arg callable on the reader pool — the
        scrubber's paced verify passes share the prefetch workers instead
        of spawning their own. Bypasses the readahead window (the caller
        paces itself); join with `wait(key)`, which re-raises the task's
        exception as PrefetchError. Keys must not collide with data_ids
        (the scrubber prefixes "scrub::"). Returns False if `key` is
        already in flight."""
        with self._cv:
            ev = self._done.get(key)
            if ev is not None and not ev.is_set():
                return False
            self._errors.pop(key, None)
            self._tasks[key] = fn
            self._done[key] = threading.Event()
            self._pending.append(key)
            self._cv.notify_all()
        return True

    def wait(self, data_id: str, *, poll: float = 0.2) -> float:
        """Block until an in-flight prefetch of data_id completes (no-op if
        never scheduled). Returns (and accounts) the seconds blocked.

        Never hangs on a dead pool: if every worker thread has exited while
        the read is still unfinished, raises PrefetchError; a reader
        exception captured by the worker is chained and re-raised here.
        """
        with self._lock:
            ev = self._done.get(data_id)
        if ev is None:
            return 0.0
        # span only when a prefetch was actually in flight: its duration
        # is the *un*-overlapped disk time the consumer pays (§3.4.2)
        with trace.span("safs.prefetch_wait", file=data_id) as sp:
            t0 = time.perf_counter()
            while not ev.wait(poll):
                if not any(t.is_alive() for t in self._threads):
                    with self._lock:
                        self.wait_seconds += time.perf_counter() - t0
                        self._done.pop(data_id, None)
                    raise PrefetchError(
                        f"prefetch workers died with {data_id!r} unfinished")
            dt = time.perf_counter() - t0
            sp.set(seconds=dt)
            with self._lock:
                self.wait_seconds += dt
                self._done.pop(data_id, None)
                err = self._errors.pop(data_id, None)
        if err is not None:
            raise PrefetchError(f"prefetch of {data_id!r} failed") from err
        return dt

    def drain(self, *, ignore_errors: bool = True) -> None:
        """Wait for everything in flight (benchmark/flush epilogue)."""
        for d in list(self._done):
            try:
                self.wait(d)
            except PrefetchError:
                if not ignore_errors:
                    raise

    def overlap_seconds(self) -> float:
        """Disk-read time hidden behind foreground compute."""
        return max(0.0, self.busy_seconds - self.wait_seconds)

    def stats(self) -> dict:
        with self._lock:
            return {"busy_seconds": self.busy_seconds,
                    "wait_seconds": self.wait_seconds,
                    "overlap_seconds": self.overlap_seconds(),
                    "bytes_prefetched": self.bytes_prefetched,
                    "files_prefetched": self.files_prefetched,
                    "files_dropped": self.files_dropped,
                    "tasks_run": self.tasks_run,
                    "read_errors": self.read_errors,
                    "read_retries": self.read_retries,
                    "io_workers": self.io_workers,
                    "depth": self.depth}

    def close(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5)
