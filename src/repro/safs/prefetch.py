"""Async prefetcher — overlap SSD page reads with JAX compute.

FlashGraph's contribution (and this paper's §3.4.2/§3.4.3) is that SEM
performance lives or dies on overlapping disk with compute: while the
eigensolver contracts one group of subspace blocks, SAFS should already be
streaming the *next* group's pages. This module is that double buffer:

  * `schedule(data_ids)` enqueues whole-file page reads on a daemon worker
    thread; the worker fills the shared PageCache with clean lines (it
    never dirties pages — prefetch is read-only);
  * the consumer calls `wait(data_id)` (the backend does, inside `load`)
    before using a file; time the consumer actually blocks there is the
    *un*-overlapped remainder;
  * overlap accounting: `overlap_seconds() = busy_seconds - wait_seconds`,
    the disk time hidden behind compute — `bench_safs.py` reports it and
    the acceptance bar is that it is nonzero.

One worker is enough: a single NVMe stream already saturates the emulated
tier, and the paper's prefetcher likewise issues from one dispatch thread
per file (§3.4.2).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Optional


class Prefetcher:
    """Single-worker async page reader over a shared PageCache.

    `reader(data_id) -> int` performs the actual cache fill for one file
    and returns bytes read from disk (the backend provides it; it skips
    pages already resident).
    """

    def __init__(self, reader: Callable[[str], int]):
        self._reader = reader
        self._q: "queue.Queue[Optional[str]]" = queue.Queue()
        self._done: Dict[str, threading.Event] = {}
        self._lock = threading.Lock()
        self.busy_seconds = 0.0
        self.wait_seconds = 0.0
        self.bytes_prefetched = 0
        self.files_prefetched = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            data_id = self._q.get()
            if data_id is None:
                return
            with self._lock:
                ev = self._done.get(data_id)
            t0 = time.perf_counter()
            try:
                n = self._reader(data_id)
                with self._lock:
                    self.bytes_prefetched += n
                    self.files_prefetched += 1
            except Exception:      # a failed prefetch is only a lost overlap
                pass
            finally:
                with self._lock:
                    self.busy_seconds += time.perf_counter() - t0
                if ev is not None:
                    ev.set()

    # ----------------------------------------------------------- frontend
    def schedule(self, data_ids) -> None:
        """Enqueue background reads; ignores ids already in flight."""
        for d in data_ids:
            with self._lock:
                if d in self._done and not self._done[d].is_set():
                    continue
                self._done[d] = threading.Event()
            self._q.put(d)

    def wait(self, data_id: str) -> float:
        """Block until an in-flight prefetch of data_id completes (no-op if
        never scheduled). Returns (and accounts) the seconds blocked."""
        with self._lock:
            ev = self._done.get(data_id)
        if ev is None:
            return 0.0
        t0 = time.perf_counter()
        ev.wait()
        dt = time.perf_counter() - t0
        with self._lock:
            self.wait_seconds += dt
            self._done.pop(data_id, None)
        return dt

    def drain(self) -> None:
        """Wait for everything in flight (benchmark epilogue)."""
        for d in list(self._done):
            self.wait(d)

    def overlap_seconds(self) -> float:
        """Disk-read time hidden behind foreground compute."""
        return max(0.0, self.busy_seconds - self.wait_seconds)

    def stats(self) -> dict:
        return {"busy_seconds": self.busy_seconds,
                "wait_seconds": self.wait_seconds,
                "overlap_seconds": self.overlap_seconds(),
                "bytes_prefetched": self.bytes_prefetched,
                "files_prefetched": self.files_prefetched}

    def close(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=5)
