"""Background integrity scrubber + checkpoint-sourced page repair.

Checksums only help against silent medium rot if something *reads* the
cold pages: a bit that flips under a history block nobody touches for an
hour would otherwise surface exactly when a restart needs that block.
The scrubber is the paced full-store verify pass (classic ZFS/ceph
"scrub") over a `SafsBackend`:

  * each pass walks every adopted page file and CRC-checks its pages
    straight off the medium (`backend.scrub_file` — the page cache is
    bypassed on purpose: scrub proves the bytes at rest, not the cached
    copies);
  * verify work runs on the backend's existing prefetch worker pool
    (`Prefetcher.submit`, keys `scrub::<data_id>`) so scrub I/O shares
    the same queue-depth budget as readahead instead of fighting it with
    its own threads; `pace_s` additionally sleeps between files so a
    scrub never saturates the device under a live solve;
  * detections are quarantined on the backend, counted
    (`integrity.scrub_corrupt` / `crc_failures`) and emitted as
    `safs.corrupt` trace events with site "scrub"; each completed pass
    emits exactly one `safs.scrub` event and bumps
    `integrity.scrub_passes` — the 1:1 pairs `repro.obs.report
    --validate` reconciles.

Repair closes the loop: `repair_from_checkpoint` re-fills quarantined
pages from the newest checkpoint snapshot that passes
`verify_safs_snapshot` — a page is only ever rewritten from a snapshot
that proved itself clean, and only when that snapshot covers it;
uncovered pages stay quarantined (the caller fails typed rather than
serving rot). NOTE the soundness boundary: page-level refill from an
older snapshot into a *live, newer* store would silently mix epochs —
it is only sound at rest (a suspended/idle solve whose store state IS
the snapshot state, e.g. right before a checkpoint resume). In-flight
solves recover at solve granularity instead (roll back to the newest
verified checkpoint — `serve.session`).

CLI (used by the tier-1 integrity smoke)::

    python -m repro.safs.scrub ROOT                 # one verify pass
    python -m repro.safs.scrub ROOT --repair-from C # pass + repair
"""
from __future__ import annotations

import json
import os
import threading
import time
import urllib.parse
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs import trace

__all__ = ["Scrubber", "newest_verified_step", "repair_from_checkpoint"]


class Scrubber:
    """Paced full-store verify passes over one SafsBackend.

    `run_once()` is synchronous (returns the pass summary); `start()`
    runs passes on a daemon thread every `interval_s` until `stop()`.
    `pace_s` sleeps between files within a pass (0 = as fast as the
    shared reader pool allows).
    """

    def __init__(self, backend, *, interval_s: float = 30.0,
                 pace_s: float = 0.0, use_pool: bool = True):
        self.backend = backend
        self.interval_s = float(interval_s)
        self.pace_s = float(pace_s)
        # use_pool=False verifies inline on the caller's thread — for
        # tests and the CLI, where there is no foreground solve to
        # overlap with and determinism beats concurrency
        self.use_pool = bool(use_pool)
        self.passes = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- one pass
    def run_once(self) -> dict:
        """Verify every page file once; returns the pass summary dict
        {files, pages, corrupt: [(data_id, page), ...], seconds}."""
        t0 = time.perf_counter()
        ids = list(self.backend.data_ids())
        corrupt: List[Tuple[str, int]] = []
        results: Dict[str, list] = {}

        def verify(data_id: str):
            def task() -> int:
                results[data_id] = self.backend.scrub_file(data_id)
                return 0
            return task

        pool = getattr(self.backend, "prefetcher", None)
        for d in ids:
            if self.use_pool and pool is not None:
                key = "scrub::" + d
                if not pool.submit(key, verify(d)):
                    # already in flight from a previous pass — join it
                    pool.wait(key)
                    pool.submit(key, verify(d))
                pool.wait(key)
            else:
                results[d] = self.backend.scrub_file(d)
            if self.pace_s > 0:
                time.sleep(self.pace_s)
        pages = 0
        for d in ids:
            pf = self.backend._files.get(d)
            if pf is not None:
                pages += pf.n_pages
            for i in results.get(d, []):
                corrupt.append((d, int(i)))
        dt = time.perf_counter() - t0
        self.passes += 1
        self.backend.integrity.add(scrub_passes=1)
        # exactly one safs.scrub event per pass: reconciles 1:1 with
        # integrity.scrub_passes (report --validate asserts this)
        trace.event("safs.scrub", files=len(ids), pages=pages,
                    corrupt=len(corrupt), seconds=dt)
        return {"files": len(ids), "pages": pages, "corrupt": corrupt,
                "seconds": dt}

    # ---------------------------------------------------------- background
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval_s):
                try:
                    self.run_once()
                except Exception as e:     # scrub must never kill a serve
                    trace.event("safs.scrub_error", error=type(e).__name__)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="safs-scrub")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None


# ------------------------------------------------------------------ repair
def newest_verified_step(ckpt_root: str) -> Optional[int]:
    """Newest committed page-snapshot step under ckpt_root that passes
    content verification; None when no snapshot proves clean. Corrupt
    newer steps are skipped (and traced), mirroring the resume fallback
    in `ckpt.solver.SolveCheckpointer.load`."""
    from repro.ckpt import checkpoint as ck
    for step in reversed(ck.valid_steps(ckpt_root)):
        snap = os.path.join(ckpt_root, f"step_{step:010d}")
        problems = ck.verify_safs_snapshot(snap)
        if not problems:
            return step
        trace.event("ckpt.corrupt_snapshot", step=step,
                    problems=list(problems))
    return None


def repair_from_checkpoint(backend, ckpt_root: str,
                           targets: Optional[Sequence[Tuple[str, int]]]
                           = None) -> dict:
    """Re-fill quarantined pages from the newest *verified* snapshot.

    targets defaults to `backend.quarantined()`. Each (data_id, page)
    covered by the snapshot is read out of the snapshot's page file
    (itself CRC-verified on read — a rotten snapshot page raises rather
    than repairing with rot) and rewritten through `backend.repair_page`
    (journaled, checksum block updated, quarantine lifted, counted as
    `pages_repaired`, emitted as `safs.repair`). Pages no verified
    snapshot covers are returned in "unrepaired" and stay quarantined —
    the caller decides whether that is a typed failure.

    Only sound at rest — see the module docstring.
    """
    from repro.ckpt import checkpoint as ck
    from repro.safs.pagefile import PageFile

    if targets is None:
        targets = backend.quarantined()
    targets = [(d, int(p)) for d, p in targets]
    out = {"step": None, "repaired": [], "unrepaired": list(targets)}
    if not targets:
        return out
    step = newest_verified_step(ckpt_root)
    if step is None:
        return out
    snap = os.path.join(ckpt_root, f"step_{step:010d}")
    with open(os.path.join(snap, ck.MANIFEST)) as f:
        covered = set(json.load(f).get("data_ids", []))
    out["step"] = step
    repaired, unrepaired = [], []
    by_file: Dict[str, List[int]] = {}
    for d, p in targets:
        by_file.setdefault(d, []).append(p)
    for d, pages in sorted(by_file.items()):
        path = os.path.join(snap, urllib.parse.quote(d, safe="") + ".pages")
        if d not in covered or not os.path.exists(path):
            unrepaired.extend((d, p) for p in sorted(pages))
            continue
        pf = PageFile(path, integrity=backend.integrity)
        try:
            valid = [p for p in sorted(pages) if p < pf.n_pages]
            unrepaired.extend((d, p) for p in sorted(pages)
                              if p >= pf.n_pages)
            # verified read path: a rotten snapshot page raises here
            # instead of being installed as a "repair"
            got = pf.read_pages_batch(valid)
            for p in valid:
                backend.repair_page(d, p, got[p])
                repaired.append((d, p))
        finally:
            pf.close()
    out["repaired"], out["unrepaired"] = repaired, unrepaired
    return out


# --------------------------------------------------------------------- CLI
def main(argv: Optional[Iterable[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Verify a SAFS page store at rest; optionally repair "
                    "corrupt pages from a verified checkpoint snapshot.")
    ap.add_argument("root", help="SAFS store root (the backend's page dir)")
    ap.add_argument("--repair-from", metavar="CKPT_ROOT", default=None,
                    help="page-checkpoint root to source repairs from")
    ap.add_argument("--trace", default=None,
                    help="write trace events to this JSONL file")
    ap.add_argument("--json", action="store_true",
                    help="print the machine-readable summary")
    args = ap.parse_args(list(argv) if argv is not None else None)

    tracer = trace.install(trace.Tracer()) if args.trace else None
    from repro.safs.backend import SafsBackend
    backend = SafsBackend(args.root, enable_prefetch=False,
                          write_behind=False)
    try:
        summary = Scrubber(backend, use_pool=False).run_once()
        repair = None
        if args.repair_from and summary["corrupt"]:
            repair = repair_from_checkpoint(backend, args.repair_from,
                                            summary["corrupt"])
        report = {"scrub": {"files": summary["files"],
                            "pages": summary["pages"],
                            "corrupt": summary["corrupt"],
                            "seconds": round(summary["seconds"], 4)},
                  "repair": repair,
                  "integrity": backend.stats_dict()["integrity"]}
        if args.json:
            print(json.dumps(report))
        else:
            print(f"scrub: {summary['files']} files, "
                  f"{summary['pages']} pages, "
                  f"{len(summary['corrupt'])} corrupt")
            for d, p in summary["corrupt"]:
                print(f"  CORRUPT {d} page {p}")
            if repair is not None:
                print(f"repair: step={repair['step']} "
                      f"repaired={len(repair['repaired'])} "
                      f"unrepaired={len(repair['unrepaired'])}")
        bad = (repair["unrepaired"] if repair is not None
               else summary["corrupt"])
        return 1 if bad else 0
    finally:
        backend.close()
        if tracer is not None:
            tracer.write_jsonl(args.trace)
            trace.uninstall()


if __name__ == "__main__":
    raise SystemExit(main())
