"""repro.serve"""
