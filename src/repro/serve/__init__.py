"""repro.serve — eigensolver-as-a-service over one shared SAFS store.

Layers (see serve/README.md): `TieredStore.namespace()` gives each job an
isolated, accounted slice of one store; `BudgetArbiter` splits the global
device budget across live sessions by priority; `SolveScheduler` runs an
admission-controlled priority queue with checkpoint-based preemption;
`EigenService` is the front end that submits JobSpecs and emits the
machine-readable serve report. `PagedKVCache` (the LM-serving demo) rides
the same namespace API.
"""
from repro.serve.api import EigenService, build_service, validate_report
from repro.serve.arbiter import BudgetArbiter
from repro.serve.paged_kv import PagedConfig, PagedKVCache
from repro.serve.scheduler import AdmissionError, SolveScheduler
from repro.serve.session import (JobSpec, PreemptFlag, SolveSession,
                                 spectrum_digest)

__all__ = [
    "AdmissionError", "BudgetArbiter", "EigenService", "JobSpec",
    "PagedConfig", "PagedKVCache", "PreemptFlag", "SolveScheduler",
    "SolveSession", "build_service", "spectrum_digest", "validate_report",
]
