"""EigenService — the eigensolver-as-a-service front end.

One object wires the whole multi-tenant stack over ONE shared store:

    EigenService
      ├─ TieredStore (shared; sessions live in `store.namespace(job_id)`)
      │    └─ SafsBackend / RamBackend (one page cache, one write-behind)
      ├─ BudgetArbiter (one device budget split by priority)
      ├─ SolveScheduler (admission, priority dispatch, preempt/resume)
      └─ MetricsRegistry (store/arbiter/scheduler gauges, pull-based)

`submit()` takes a JobSpec (or its dict form), `drain()` runs the queue to
empty, `report()` emits the machine-readable serve report: per-job wall
time / queue wait / preemption count / spectrum digest, per-namespace
logical and physical I/O, arbiter shares, backend totals. The report is
written to be *checkable* — `validate_report` asserts the serve-level
invariants (queue drained, zero lost jobs, per-namespace physical byte
sums reconciling EXACTLY against the backend's global counters), and the
tier-1 smoke gates on it.
"""
from __future__ import annotations

import logging
from typing import List, Optional, Union

from repro.core.tiered import TieredStore
from repro.obs import metrics as obs_metrics
from repro.serve.arbiter import BudgetArbiter
from repro.serve.scheduler import SolveScheduler
from repro.serve.session import DONE, JobSpec, SolveSession

log = logging.getLogger("repro.serve")


class EigenService:
    """Multi-tenant solve service over one shared TieredStore."""

    def __init__(self, store: TieredStore, *,
                 ckpt_root: Optional[str] = None,
                 device_budget: Optional[int] = None,
                 min_share: int = 1 << 20,
                 max_concurrent: int = 2, max_queued: int = 64,
                 poll_interval: float = 0.01, owns_store: bool = False,
                 default_deadline_s: Optional[float] = None,
                 deadline_grace_s: float = 2.0,
                 orphan_grace_s: Optional[float] = 3600.0):
        self.store = store
        self.ckpt_root = ckpt_root
        self._owns_store = owns_store
        self.arbiter = BudgetArbiter(store, device_budget=device_budget,
                                     min_share=min_share)
        self.scheduler = SolveScheduler(store, self.arbiter,
                                        max_concurrent=max_concurrent,
                                        max_queued=max_queued,
                                        poll_interval=poll_interval,
                                        default_deadline_s=default_deadline_s,
                                        deadline_grace_s=deadline_grace_s)
        self.sessions: List[SolveSession] = []
        # Startup GC: a serve root reused after a killed process still
        # holds the dead process's per-session page subdirs. No session
        # is live yet, so any namespace older than the age gate is an
        # orphan; sweeping here (not lazily) bounds disk leakage to one
        # process lifetime. orphan_grace_s=None disables the sweep.
        self.orphans_swept: List[str] = []
        backend = getattr(store, "backend", None)
        if (orphan_grace_s is not None
                and hasattr(backend, "sweep_orphan_namespaces")):
            self.orphans_swept = backend.sweep_orphan_namespaces(
                grace_s=float(orphan_grace_s))
            if self.orphans_swept:
                log.warning("swept %d orphan namespace(s) at startup: %s",
                            len(self.orphans_swept),
                            ", ".join(self.orphans_swept))
        self.registry = obs_metrics.MetricsRegistry()
        self.registry.register(
            "store", lambda: obs_metrics.snapshot_store(store))
        self.registry.register("namespaces", store.namespace_stats)
        self.registry.register("arbiter", self.arbiter)
        self.registry.register("scheduler", self.scheduler)

    # ------------------------------------------------------------- intake
    def submit(self, spec: Union[JobSpec, dict]) -> SolveSession:
        """Queue one job (raises `AdmissionError` when the queue is full);
        returns its session for progress polling."""
        if isinstance(spec, dict):
            spec = JobSpec.from_dict(spec)
        if any(s.spec.job_id == spec.job_id for s in self.sessions):
            raise ValueError(f"duplicate job_id {spec.job_id!r}")
        session = SolveSession(spec, self.store, self.ckpt_root)
        self.scheduler.submit(session)
        self.sessions.append(session)
        return session

    def drain(self) -> List[SolveSession]:
        """Run the scheduler until every submitted job reaches a terminal
        state (preempted jobs resume and finish before drain returns)."""
        return self.scheduler.drain()

    # ------------------------------------------------------------- report
    def report(self) -> dict:
        """Machine-readable serve report. Flushes the store first — the
        write-behind drain is the barrier that makes per-namespace
        physical write sums reconcile exactly against backend totals."""
        self.store.flush()
        snap = self.registry.snapshot()
        backend = (snap.get("store") or {}).get("backend") or {}
        return {
            "jobs": [s.report() for s in self.sessions],
            "scheduler": snap.get("scheduler"),
            "arbiter": snap.get("arbiter"),
            "namespaces": snap.get("namespaces"),   # logical, per-session
            "backend": backend,                     # physical, shared
            "orphans_swept": list(self.orphans_swept),
            "gauges": obs_metrics.gauges(snap.get("store") or {}),
        }

    def close(self) -> None:
        if self._owns_store:
            self.store.close()


def build_service(*, backend: str = "ram", root: Optional[str] = None,
                  device_budget: int = 32 << 20,
                  cache_bytes: int = 8 << 20,
                  ckpt_root: Optional[str] = None,
                  max_concurrent: int = 2, max_queued: int = 64,
                  min_share: int = 1 << 20,
                  poll_interval: float = 0.01,
                  default_deadline_s: Optional[float] = None,
                  deadline_grace_s: float = 2.0,
                  orphan_grace_s: Optional[float] = 3600.0) -> EigenService:
    """Stand up the full stack from scalars (the CLI's entry point): one
    backend, one store whose device budget the arbiter will split, one
    service that owns and closes them."""
    opts = {}
    if backend == "safs":
        if root is not None:
            opts["root"] = root
        opts["cache_bytes"] = cache_bytes
    store = TieredStore(device_budget_bytes=device_budget,
                        backend=backend, backend_opts=opts)
    return EigenService(store, ckpt_root=ckpt_root,
                        device_budget=device_budget, min_share=min_share,
                        max_concurrent=max_concurrent,
                        max_queued=max_queued,
                        poll_interval=poll_interval, owns_store=True,
                        default_deadline_s=default_deadline_s,
                        deadline_grace_s=deadline_grace_s,
                        orphan_grace_s=orphan_grace_s)


# ------------------------------------------------------------- validation
def validate_report(report: dict) -> List[str]:
    """Serve-level invariants; returns human-readable violations (empty =
    valid). Checked: queue fully drained, zero lost jobs (every job DONE),
    per-namespace PHYSICAL byte sums reconciling exactly against the
    backend's global IOStats (reads and writes — the multi-tenant
    accounting contract)."""
    errors: List[str] = []
    sched = report.get("scheduler") or {}
    if sched.get("pending"):
        errors.append(f"queue not drained: {sched['pending']} pending")
    if sched.get("running"):
        errors.append(f"queue not drained: "
                      f"{sorted(sched['running'])} still running")
    jobs = report.get("jobs") or []
    if not jobs:
        errors.append("no jobs in report")
    for j in jobs:
        if j.get("state") != DONE:
            errors.append(f"job {j.get('job_id')!r} lost: "
                          f"state={j.get('state')!r} "
                          f"error={j.get('error')!r}")
        elif j.get("spectrum") is None:
            errors.append(f"job {j.get('job_id')!r} done but no spectrum")
    backend = report.get("backend") or {}
    ns = backend.get("namespaces") or {}
    io = backend.get("io") or {}
    for field in ("host_bytes_read", "host_bytes_written"):
        total = sum(int(d.get(field, 0)) for d in ns.values())
        want = int(io.get(field, 0))
        if total != want:
            errors.append(
                f"physical accounting leak: per-namespace {field} sum "
                f"{total} != backend total {want}")
    return errors
