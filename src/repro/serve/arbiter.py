"""BudgetArbiter — one global device/host budget split across live sessions.

The paper runs FlashEigen against SAFS's *shared* page cache (§3.4): many
workloads, one SSD array, one cache budget. The serving layer reproduces
that contract for the device tier too — instead of every script hard-coding
its own `TieredStore(device_budget_bytes=...)` global, the arbiter owns ONE
global budget and splits it across admitted sessions by priority:

    share(s) = device_budget · weight(s) / Σ weight,   weight = priority + 1

recomputed on every admit/release, floored at `min_share` so a low-priority
session can always make progress (a share below one subspace block would
thrash). Shares are pushed into the store as per-namespace budgets
(`TieredStore.set_namespace_budget`) — shrinking a live session's allotment
demotes its own LRU entries immediately, so an admit takes effect without
waiting for the incumbent's next put.

The host-tier budget is advisory (the SSD/page-file tier is effectively
unbounded in this emulation); it is tracked and reported so the serve
report can flag oversubscription, but not enforced by eviction.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional


class BudgetArbiter:
    """Priority-proportional splitter of one device budget over sessions."""

    def __init__(self, store, *, device_budget: Optional[int] = None,
                 host_budget: Optional[int] = None,
                 min_share: int = 1 << 20):
        self.store = store
        self.device_budget = int(device_budget if device_budget is not None
                                 else store.device_budget)
        self.host_budget = host_budget
        self.min_share = int(min_share)
        self._live: Dict[str, int] = {}     # session_id -> priority
        self._shares: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.admits = 0
        self.releases = 0

    @staticmethod
    def _weight(priority: int) -> int:
        return max(1, int(priority) + 1)

    def admit(self, session_id: str, priority: int = 0) -> int:
        """Admit a session and recompute every live share; returns the new
        session's device allotment in bytes."""
        with self._lock:
            self._live[session_id] = int(priority)
            self.admits += 1
            self._recompute()
            return self._shares[session_id]

    def release(self, session_id: str) -> None:
        """Drop a finished/suspended session and redistribute its share."""
        with self._lock:
            if session_id not in self._live:
                return
            del self._live[session_id]
            self._shares.pop(session_id, None)
            self.releases += 1
            self.store.set_namespace_budget(session_id, None)
            self._recompute()

    def allotment(self, session_id: str) -> Optional[int]:
        with self._lock:
            return self._shares.get(session_id)

    def _recompute(self) -> None:
        # caller holds the lock
        total_w = sum(self._weight(p) for p in self._live.values())
        for sid, prio in self._live.items():
            share = self.device_budget * self._weight(prio) // max(total_w, 1)
            share = max(self.min_share, share)
            self._shares[sid] = share
            self.store.set_namespace_budget(sid, share)

    def stats_dict(self) -> dict:
        with self._lock:
            return {
                "device_budget": self.device_budget,
                "host_budget": self.host_budget,
                "min_share": self.min_share,
                "live_sessions": dict(self._live),
                "shares": dict(self._shares),
                "admits": self.admits,
                "releases": self.releases,
                "oversubscribed": (sum(self._shares.values())
                                   > self.device_budget),
            }
