"""Paged KV cache with tier spill — the paper's memory-tiering discipline
applied to serving (DESIGN.md §5 integration point).

Long-context serving has the same shape as the paper's problem: a large,
append-mostly state (KV pages ≙ the subspace), a small hot working set
(recent pages ≙ the most-recent block), and a slow big tier to spill to
(host DRAM ≙ SSD). This module implements:

  * fixed-size KV pages with a block table per sequence (vLLM-style),
  * LRU spill of cold pages to the TieredStore host tier with byte-exact
    accounting (reads ≪ writes inverted here: decode *writes* one page
    slot per token but *reads* the whole context — same read-dominated
    profile as Table 3),
  * gather-based attention over the page table (pure JAX; works with the
    ring-buffer decode path for windowed archs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiered import TieredStore


@dataclasses.dataclass
class PagedConfig:
    page_size: int = 128          # tokens per page
    n_kv_heads: int = 2
    head_dim: int = 16
    hot_pages: int = 8            # device-tier page budget per sequence
    dtype: str = "float32"


class PagedKVCache:
    """Per-sequence paged KV storage over a TieredStore.

    `session_id` routes every page name through `store.namespace(...)`, so
    a KV-spill workload coexists with solver sessions on ONE shared store:
    its pages live under its own key prefix, its device bytes count against
    its own arbiter allotment, and session end (`close()`) reclaims them
    without touching the solvers' blocks. Omitted (the default), the cache
    uses the store directly — the standalone demo path is byte-identical
    to before namespaces existed.
    """

    def __init__(self, cfg: PagedConfig, store: TieredStore | None = None,
                 *, session_id: str | None = None):
        self.cfg = cfg
        store = store or TieredStore()
        self.session_id = session_id
        if session_id is not None:
            ns = getattr(store, "namespace", None)
            if ns is None:
                raise TypeError(f"store {type(store).__name__!r} has no "
                                "namespace() — cannot scope session "
                                f"{session_id!r}")
            store = ns(session_id)
        self.store = store
        self._tables: dict[int, list[str]] = {}   # seq id -> page names
        self._fill: dict[int, int] = {}           # tokens written

    def close(self) -> None:
        """Retire a namespaced cache (drops its pages from the shared
        store); a no-op for the un-namespaced standalone form."""
        if self.session_id is not None:
            self.store.close()
        self._tables.clear()
        self._fill.clear()

    def _page_shape(self):
        c = self.cfg
        return (c.page_size, c.n_kv_heads, c.head_dim)

    def _new_page(self, seq: int) -> str:
        name = f"kv/{seq}/p{len(self._tables[seq])}"
        z = jnp.zeros((2,) + self._page_shape(), jnp.dtype(self.cfg.dtype))
        self.store.put(name, z)
        self._tables[seq].append(name)
        # spill: keep only hot_pages newest on device
        table = self._tables[seq]
        for old in table[:-self.cfg.hot_pages]:
            if self.store.tier_of(old) != "host":
                self.store.demote(old)
        return name

    def start(self, seq: int) -> None:
        self._tables[seq] = []
        self._fill[seq] = 0

    def append(self, seq: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Append one token's (K,hd) k/v."""
        c = self.cfg
        pos = self._fill[seq]
        if pos % c.page_size == 0:
            self._new_page(seq)
        name = self._tables[seq][-1]
        page = self.store.get(name)
        slot = pos % c.page_size
        page = page.at[0, slot].set(k).at[1, slot].set(v)
        self.store.put(name, page)  # rewrite hot page (device tier)
        self._fill[seq] = pos + 1

    def length(self, seq: int) -> int:
        return self._fill[seq]

    def gather(self, seq: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize (k, v) for attention: (S, K, hd) each. Cold pages
        are read from the host tier (counted)."""
        pages = [self.store.get(n) for n in self._tables[seq]]
        if not pages:
            shape = (0,) + self._page_shape()
            z = jnp.zeros(shape, jnp.dtype(self.cfg.dtype))
            return z, z
        stacked = jnp.concatenate(pages, axis=1)  # (2, S_pages, K, hd)
        s = self._fill[seq]
        return stacked[0, :s], stacked[1, :s]

    def attend(self, seq: int, q: jnp.ndarray) -> jnp.ndarray:
        """Single-token attention over the paged context.
        q (H, hd) with GQA groups folded → returns (H, hd)."""
        k, v = self.gather(seq)
        kh = self.cfg.n_kv_heads
        h = q.shape[0]
        g = h // kh
        qg = q.reshape(kh, g, -1)
        s = jnp.einsum("kgd,skd->kgs", qg, k) / np.sqrt(q.shape[-1])
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("kgs,skd->kgd", w, v)
        return out.reshape(h, -1)
