"""SolveScheduler — admission-controlled priority queue over one store.

The multi-tenant heart of the serving layer: N submitted `SolveSession`s,
up to `max_concurrent` running at once on worker threads, every one
confined to its own store namespace with the device allotment the
`BudgetArbiter` granted at admission. The dispatcher loop (`drain`, on the
caller's thread) does three things per tick:

  reap     finished workers — DONE/FAILED release the namespace and the
           arbiter share; SUSPENDED additionally *requeues* the session,
           which will resume from its committed checkpoint;
  preempt  when a strictly higher-priority job is waiting and no slot is
           free, raise the lowest-priority running preemptible session's
           `PreemptFlag` — it checkpoints at its next restart boundary and
           exits `SUSPENDED`, so short high-priority jobs jump the queue
           without losing the long job's progress;
  fill     pop pending jobs in (-priority, submit-order) order into free
           slots: `arbiter.admit` first (shares shrink for incumbents
           immediately), then the worker thread.

A fourth concern rides the same tick: the **watchdog**. Jobs carry a
wall-clock deadline (`JobSpec.deadline_s`, or the scheduler-wide
`default_deadline_s`); past it the watchdog first asks nicely (raise the
`PreemptFlag` — a cooperative worker checkpoints and exits SUSPENDED,
keeping its progress but giving up its slot for good: a deadline-expired
suspension is terminal, not requeued), and after `deadline_grace_s` more
it *abandons* a worker that still hasn't exited — the session is marked
FAILED, its namespace and arbiter share are released exactly once, and
the daemon thread is left to die detached so one hung solve can never
stall the other tenants or wedge `drain()`.

Worker exceptions can't go missing either: the thread target wraps
`session.run()` so anything escaping it (run() catching only `Exception`
leaves BaseException holes) lands in `session.error` as a full traceback
with state FAILED, and `_reap` force-fails any dead worker whose session
is still in a non-terminal state — every submitted job is accounted
DONE/SUSPENDED/FAILED in the serve report, never silently lost.

Admission control is a hard queue bound (`max_queued`), not a soft hint —
a serve front end that accepts unboundedly is just an OOM with extra
steps.
"""
from __future__ import annotations

import heapq
import threading
import time
import traceback
from typing import Dict, List, Optional, Tuple

from repro.obs import trace
from repro.serve.session import DONE, FAILED, SUSPENDED, SolveSession


class AdmissionError(RuntimeError):
    """The queue is full — the caller must back off and resubmit."""


class _Worker:
    """One running slot: the session, its thread, and watchdog clocks."""

    __slots__ = ("session", "thread", "started", "expired_at")

    def __init__(self, session, thread):
        self.session = session
        self.thread = thread
        self.started = time.monotonic()
        self.expired_at: Optional[float] = None   # deadline preempt sent

    def job_wall_s(self, now: float) -> float:
        """Cumulative job wall-clock: prior segments + this one so far."""
        return getattr(self.session, "wall_s", 0.0) + (now - self.started)


class SolveScheduler:
    """Priority scheduler for SolveSessions over one shared TieredStore."""

    def __init__(self, store, arbiter, *, max_concurrent: int = 2,
                 max_queued: int = 64, poll_interval: float = 0.01,
                 default_deadline_s: Optional[float] = None,
                 deadline_grace_s: float = 2.0):
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        self.store = store
        self.arbiter = arbiter
        self.max_concurrent = int(max_concurrent)
        self.max_queued = int(max_queued)
        self.poll_interval = float(poll_interval)
        # watchdog: per-job deadline_s overrides this scheduler-wide
        # default; grace is the extra time a deadline-expired worker gets
        # to checkpoint-suspend before it is abandoned as hung
        self.default_deadline_s = default_deadline_s
        self.deadline_grace_s = float(deadline_grace_s)
        # heap of (-priority, seq, session): highest priority first,
        # FIFO within a priority level
        self._pending: List[Tuple[int, int, SolveSession]] = []
        self._running: Dict[str, _Worker] = {}
        self.completed: List[SolveSession] = []
        self._seq = 0
        self.preempt_requests = 0
        self.requeues = 0
        self.timeouts = 0           # deadline preempts the watchdog sent
        self.abandoned = 0          # hung workers detached past the grace
        self.worker_crashes = 0     # threads killed by escaped exceptions

    # ------------------------------------------------------------- intake
    def submit(self, session: SolveSession) -> None:
        if len(self._pending) + len(self._running) >= self.max_queued:
            raise AdmissionError(
                f"queue full ({self.max_queued} jobs in flight)")
        self._enqueue(session)

    def _enqueue(self, session: SolveSession) -> None:
        session.mark_queued()
        heapq.heappush(self._pending,
                       (-session.spec.priority, self._seq, session))
        self._seq += 1

    # ---------------------------------------------------------- dispatch
    def drain(self) -> List[SolveSession]:
        """Run the dispatcher loop until queue and slots are empty;
        returns every session in completion order."""
        while self._pending or self._running:
            self.tick()
            time.sleep(self.poll_interval)
        return self.completed

    def tick(self) -> None:
        """One dispatcher step: reap, watchdog, maybe preempt, fill.
        Exposed so tests can single-step scheduling decisions
        deterministically."""
        self._reap()
        self._watchdog()
        self._maybe_preempt()
        self._fill()

    def _run_worker(self, session: SolveSession) -> None:
        """Thread target: nothing escaping `run()` may lose the session.
        `run()` catches Exception itself; this net catches what it can't
        (BaseException, or a bug in run's own except/finally) and turns
        it into an accounted FAILED with the full traceback in the serve
        report instead of a silently dead thread."""
        try:
            session.run()
        except BaseException:
            session.error = traceback.format_exc()
            session.state = FAILED
            self.worker_crashes += 1

    def _reap(self) -> None:
        for sid in list(self._running):
            w = self._running[sid]
            if w.thread.is_alive():
                continue
            w.thread.join()
            del self._running[sid]
            session = w.session
            if session.state not in (DONE, FAILED, SUSPENDED):
                # dead worker, non-terminal state: the thread died before
                # run() could classify its exit (e.g. killed before entry)
                self.worker_crashes += 1
                if not getattr(session, "error", None):
                    session.error = ("worker thread died with session "
                                     f"in state {session.state!r}")
                session.state = FAILED
            # Namespace teardown in EVERY terminal state: a suspended
            # session's live blocks are dead weight — the committed page
            # snapshot in its checkpoint root is the only state that
            # survives, and resume rebuilds into a fresh namespace.
            self.store.drop_namespace(sid)
            self.arbiter.release(sid)
            if session.state == SUSPENDED and w.expired_at is None:
                self.requeues += 1
                self._enqueue(session)
            else:
                # deadline-expired suspensions are terminal: the snapshot
                # keeps the progress, but the job gives up its claim on
                # the cluster (requeueing it would loop forever)
                self.completed.append(session)

    def _watchdog(self) -> None:
        """Enforce per-job wall-clock deadlines: graceful checkpoint-
        suspend at the deadline, hard abandonment `deadline_grace_s`
        later for a worker that is hung (or whose solve can't reach a
        restart boundary). Abandonment releases the namespace and the
        arbiter share exactly once — `_reap` can't see the sid again."""
        now = time.monotonic()
        for sid in list(self._running):
            w = self._running[sid]
            deadline = getattr(w.session.spec, "deadline_s", None)
            if deadline is None:
                deadline = self.default_deadline_s
            if deadline is None:
                continue
            elapsed = w.job_wall_s(now)
            if elapsed <= deadline:
                continue
            if w.expired_at is None:
                w.expired_at = now
                w.session.guard.request()
                self.timeouts += 1
                trace.event("serve.deadline", job=sid,
                            elapsed_s=elapsed, deadline_s=deadline)
                continue
            if now - w.expired_at <= self.deadline_grace_s:
                continue
            if not w.thread.is_alive():
                continue    # just exited — next _reap accounts it
            del self._running[sid]
            self.abandoned += 1
            w.session.error = (f"deadline exceeded: {elapsed:.1f}s > "
                               f"{deadline:.1f}s budget and the worker "
                               f"did not suspend within the "
                               f"{self.deadline_grace_s:.1f}s grace")
            w.session.state = FAILED
            trace.event("serve.abandoned", job=sid, elapsed_s=elapsed)
            self.store.drop_namespace(sid)
            self.arbiter.release(sid)
            self.completed.append(w.session)

    def _maybe_preempt(self) -> None:
        if not self._pending or len(self._running) < self.max_concurrent:
            return
        head_priority = -self._pending[0][0]
        victims = [w.session for w in self._running.values()
                   if w.session.can_preempt
                   and w.session.spec.priority < head_priority]
        if not victims:
            return
        victim = min(victims, key=lambda s: s.spec.priority)
        victim.guard.request()
        self.preempt_requests += 1

    def _fill(self) -> None:
        while self._pending and len(self._running) < self.max_concurrent:
            _, _, session = heapq.heappop(self._pending)
            session.mark_dequeued()
            sid = session.spec.job_id
            self.arbiter.admit(sid, session.spec.priority)
            thread = threading.Thread(target=self._run_worker,
                                      args=(session,),
                                      name=f"solve-{sid}", daemon=True)
            self._running[sid] = _Worker(session, thread)
            thread.start()

    # ------------------------------------------------------------ surface
    def stats_dict(self) -> dict:
        """Live gauges for obs.metrics: queue depth, per-job progress,
        preemption/watchdog counters."""
        return {
            "pending": len(self._pending),
            "running": {sid: w.session.progress()
                        for sid, w in self._running.items()},
            "completed": len(self.completed),
            "max_concurrent": self.max_concurrent,
            "preempt_requests": self.preempt_requests,
            "requeues": self.requeues,
            "timeouts": self.timeouts,
            "abandoned": self.abandoned,
            "worker_crashes": self.worker_crashes,
        }
