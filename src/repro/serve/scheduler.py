"""SolveScheduler — admission-controlled priority queue over one store.

The multi-tenant heart of the serving layer: N submitted `SolveSession`s,
up to `max_concurrent` running at once on worker threads, every one
confined to its own store namespace with the device allotment the
`BudgetArbiter` granted at admission. The dispatcher loop (`drain`, on the
caller's thread) does three things per tick:

  reap     finished workers — DONE/FAILED release the namespace and the
           arbiter share; SUSPENDED additionally *requeues* the session,
           which will resume from its committed checkpoint;
  preempt  when a strictly higher-priority job is waiting and no slot is
           free, raise the lowest-priority running preemptible session's
           `PreemptFlag` — it checkpoints at its next restart boundary and
           exits `SUSPENDED`, so short high-priority jobs jump the queue
           without losing the long job's progress;
  fill     pop pending jobs in (-priority, submit-order) order into free
           slots: `arbiter.admit` first (shares shrink for incumbents
           immediately), then the worker thread.

Admission control is a hard queue bound (`max_queued`), not a soft hint —
a serve front end that accepts unboundedly is just an OOM with extra
steps.
"""
from __future__ import annotations

import heapq
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.serve.session import SUSPENDED, SolveSession


class AdmissionError(RuntimeError):
    """The queue is full — the caller must back off and resubmit."""


class SolveScheduler:
    """Priority scheduler for SolveSessions over one shared TieredStore."""

    def __init__(self, store, arbiter, *, max_concurrent: int = 2,
                 max_queued: int = 64, poll_interval: float = 0.01):
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        self.store = store
        self.arbiter = arbiter
        self.max_concurrent = int(max_concurrent)
        self.max_queued = int(max_queued)
        self.poll_interval = float(poll_interval)
        # heap of (-priority, seq, session): highest priority first,
        # FIFO within a priority level
        self._pending: List[Tuple[int, int, SolveSession]] = []
        self._running: Dict[str, Tuple[SolveSession, threading.Thread]] = {}
        self.completed: List[SolveSession] = []
        self._seq = 0
        self.preempt_requests = 0
        self.requeues = 0

    # ------------------------------------------------------------- intake
    def submit(self, session: SolveSession) -> None:
        if len(self._pending) + len(self._running) >= self.max_queued:
            raise AdmissionError(
                f"queue full ({self.max_queued} jobs in flight)")
        self._enqueue(session)

    def _enqueue(self, session: SolveSession) -> None:
        session.mark_queued()
        heapq.heappush(self._pending,
                       (-session.spec.priority, self._seq, session))
        self._seq += 1

    # ---------------------------------------------------------- dispatch
    def drain(self) -> List[SolveSession]:
        """Run the dispatcher loop until queue and slots are empty;
        returns every session in completion order."""
        while self._pending or self._running:
            self.tick()
            time.sleep(self.poll_interval)
        return self.completed

    def tick(self) -> None:
        """One dispatcher step: reap, maybe preempt, fill. Exposed so
        tests can single-step scheduling decisions deterministically."""
        self._reap()
        self._maybe_preempt()
        self._fill()

    def _reap(self) -> None:
        for sid in list(self._running):
            session, thread = self._running[sid]
            if thread.is_alive():
                continue
            thread.join()
            del self._running[sid]
            # Namespace teardown in EVERY terminal state: a suspended
            # session's live blocks are dead weight — the committed page
            # snapshot in its checkpoint root is the only state that
            # survives, and resume rebuilds into a fresh namespace.
            self.store.drop_namespace(sid)
            self.arbiter.release(sid)
            if session.state == SUSPENDED:
                self.requeues += 1
                self._enqueue(session)
            else:
                self.completed.append(session)

    def _maybe_preempt(self) -> None:
        if not self._pending or len(self._running) < self.max_concurrent:
            return
        head_priority = -self._pending[0][0]
        victims = [s for s, _ in self._running.values()
                   if s.can_preempt and s.spec.priority < head_priority]
        if not victims:
            return
        victim = min(victims, key=lambda s: s.spec.priority)
        victim.guard.request()
        self.preempt_requests += 1

    def _fill(self) -> None:
        while self._pending and len(self._running) < self.max_concurrent:
            _, _, session = heapq.heappop(self._pending)
            session.mark_dequeued()
            sid = session.spec.job_id
            self.arbiter.admit(sid, session.spec.priority)
            thread = threading.Thread(target=session.run,
                                      name=f"solve-{sid}", daemon=True)
            self._running[sid] = (session, thread)
            thread.start()

    # ------------------------------------------------------------ surface
    def stats_dict(self) -> dict:
        """Live gauges for obs.metrics: queue depth, per-job progress,
        preemption counters."""
        return {
            "pending": len(self._pending),
            "running": {sid: s.progress()
                        for sid, (s, _) in self._running.items()},
            "completed": len(self.completed),
            "max_concurrent": self.max_concurrent,
            "preempt_requests": self.preempt_requests,
            "requeues": self.requeues,
        }
