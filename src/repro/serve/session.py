"""SolveSession — one spectral job inside its own store namespace.

A session owns nothing global: its subspace blocks, its streamed matrix
image and its checkpoints all live under `store.namespace(job_id)` on the
*shared* TieredStore/SafsBackend, its device bytes are whatever the
`BudgetArbiter` allotted, and its lifecycle is driven by the scheduler:

    PENDING ──run()──► RUNNING ──► DONE | FAILED
                          │  ▲
           guard fires →  ▼  │ rerun (resume=ckpt_root)
                       SUSPENDED

Preemption composes PR 8's machinery: the scheduler raises the session's
`PreemptFlag`; the solve's `CheckpointPolicy(guard=flag)` finishes the
in-flight restart, commits a snapshot, and raises `SolveSuspended`; the
scheduler then drops the namespace (freeing the allotment for the job that
preempted it) and requeues the session, whose next `run()` resumes from the
committed checkpoint — a bit-identical continuation, so preempted spectra
match uninterrupted ones exactly.

The problem itself (graph + operator) is rebuilt deterministically from the
JobSpec seed on every run — only the solver state crosses a suspension,
exactly like the SIGTERM path in `examples/ooc_lanczos.py`.

Corruption rides the same suspend edge: a typed `CorruptPageError` /
`CorruptSnapshotError` mid-solve moves the session to SUSPENDED (up to
`JobSpec.max_corruption_retries` times, traced
`serve.corruption_recovery`); the scheduler drops the namespace — the
corrupt pages die with it — and the requeued run resumes from the last
good checkpoint. Budget exhausted, or no checkpoint root: FAILED with
the typed error.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.ckpt.checkpoint import CorruptSnapshotError
from repro.ckpt.solver import CheckpointPolicy, SolveSuspended
from repro.core import GraphOperator, solve
from repro.graphs import normalized_adjacency, pack_tiles, rmat_graph
from repro.obs import trace
from repro.obs.progress import ConvergenceTracker
from repro.safs.faults import CorruptPageError

PENDING = "pending"
RUNNING = "running"
SUSPENDED = "suspended"
DONE = "done"
FAILED = "failed"

KINDS = ("eigsh", "lobpcg", "cluster")
GRAPHS = ("rmat", "planted")


class PreemptFlag:
    """The scheduler's suspend signal, duck-compatible with
    `ft.PreemptionGuard` (`CheckpointPolicy.guard` only needs
    `requested()`): raise with `request()`, the solve checkpoints at its
    next restart boundary and raises `SolveSuspended`."""

    def __init__(self):
        self._event = threading.Event()

    def request(self) -> None:
        self._event.set()

    def clear(self) -> None:
        self._event.clear()

    def requested(self) -> bool:
        return self._event.is_set()


@dataclasses.dataclass
class JobSpec:
    """One spectral job: what to solve, on which synthetic graph, at what
    priority. `kind` picks the workload — "eigsh" (Krylov–Schur embedding),
    "lobpcg" (same spectrum via the LOBPCG family member), "cluster"
    (spectral clustering: embed + spherical k-means + purity against the
    planted partition)."""
    job_id: str
    kind: str = "eigsh"
    graph: str = "rmat"            # "planted" forced for kind="cluster"
    n: int = 1200
    nnz: int = 12000               # rmat edge target
    k_classes: int = 4             # planted partition communities
    nev: int = 4
    priority: int = 0
    tol: float = 1e-6
    max_iters: int = 80
    block_size: Optional[int] = None
    which: str = "LA"              # normalized adjacency: largest algebraic
    seed: int = 0
    stream_image: bool = False     # spill the matrix image into the store
    preemptible: bool = True
    checkpoint_every: int = 0      # 0 = preemption-triggered snapshots only
    deadline_s: Optional[float] = None   # job wall-clock budget (watchdog)
    # corruption-recovery budget: how many times a CorruptPageError may be
    # answered by abandoning the namespace and resuming from the newest
    # VERIFIED checkpoint before the job fails typed
    max_corruption_retries: int = 1
    options: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"job {self.job_id!r}: unknown kind "
                             f"{self.kind!r} (one of {KINDS})")
        if self.kind == "cluster":
            self.graph = "planted"
        if self.graph not in GRAPHS:
            raise ValueError(f"job {self.job_id!r}: unknown graph "
                             f"{self.graph!r} (one of {GRAPHS})")

    @property
    def method(self) -> str:
        return "lobpcg" if self.kind == "lobpcg" else "krylov_schur"

    @classmethod
    def from_dict(cls, d: dict) -> "JobSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown job-spec fields: {sorted(unknown)}")
        if "job_id" not in d:
            raise ValueError("job spec needs a job_id")
        return cls(**d)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ------------------------------------------------------------ problem build
def planted_partition(n: int, k: int, d_avg: int = 12, p_in: float = 0.85,
                      seed: int = 0):
    """Planted-partition COO graph + ground-truth labels (the clustering
    workload's dataset; mirrors examples/spectral_cluster.py)."""
    rng = np.random.default_rng(seed)
    labels = np.repeat(np.arange(k), n // k)
    labels = np.concatenate([labels,
                             np.full(n - labels.size, k - 1, labels.dtype)])
    rows, cols = [], []
    for i in range(n):
        for _ in range(d_avg):
            j = int(rng.integers(0, n))
            p = p_in if labels[i] == labels[j] else (1 - p_in) / (k - 1)
            if rng.random() < p and i != j:
                rows.append(i)
                cols.append(j)
    r = np.array(rows + cols, np.int32)
    c = np.array(cols + rows, np.int32)
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    return labels, r[idx], c[idx], np.ones(idx.size, np.float32)


def spherical_kmeans_purity(emb: np.ndarray, labels: np.ndarray,
                            k: int, iters: int = 30) -> float:
    """Cluster rows of `emb` on the unit sphere (deterministic linspace
    init) and score purity against the planted labels."""
    n = emb.shape[0]
    emb = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-12)
    cents = emb[np.linspace(0, n - 1, k).astype(int)]
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        assign = np.argmax(emb @ cents.T, axis=1)
        cents = np.stack([emb[assign == i].mean(0) if (assign == i).any()
                          else cents[i] for i in range(k)])
        cents /= np.linalg.norm(cents, axis=1, keepdims=True) + 1e-12
    return float(sum(np.bincount(labels[assign == i]).max()
                     for i in range(k) if (assign == i).any()) / n)


def build_problem(spec: JobSpec, store):
    """Deterministically rebuild the job's operator inside `store` (a
    session namespace). Returns (op, labels) — labels only for the planted
    graph. Determinism matters twice: a resumed session must reconstruct
    the *same* matrix, and the serial-parity test reruns the same spec."""
    if spec.graph == "planted":
        labels, r, c, v = planted_partition(spec.n, spec.k_classes,
                                            seed=spec.seed)
    else:
        labels = None
        r, c, v = rmat_graph(spec.n, spec.nnz, seed=spec.seed,
                             symmetric=True)
    r2, c2, v2 = normalized_adjacency(spec.n, r, c, v)
    image = pack_tiles(spec.n, spec.n, r2, c2, v2, block_shape=(64, 64),
                       min_block_nnz=4)
    op = GraphOperator(image, store=store, impl="ref",
                       stream_image=spec.stream_image, name="A")
    return op, labels


# ----------------------------------------------------------------- session
class SolveSession:
    """One job's full lifecycle over the shared store (see module doc)."""

    def __init__(self, spec: JobSpec, store, ckpt_root: Optional[str]):
        self.spec = spec
        self.store = store                      # the PARENT TieredStore
        self.ckpt_root = (os.path.join(ckpt_root, spec.job_id)
                          if ckpt_root else None)
        self.state = PENDING
        self.guard = PreemptFlag()
        self.tracker = ConvergenceTracker(tol=spec.tol, nev=spec.nev,
                                          method=spec.method)
        self.result: Optional[dict] = None
        self.error: Optional[str] = None
        self.purity: Optional[float] = None
        self.preemptions = 0
        self.corruption_recoveries = 0
        self._resume_next = False      # next run() resumes from ckpt_root
        self.resumes = 0
        self.segments = 0              # run() invocations (1 + resumes)
        self.wall_s = 0.0              # solving time, summed over segments
        self.queue_wait_s = 0.0        # time spent PENDING, summed
        self._queued_at: Optional[float] = None

    # ------------------------------------------------------- queue timing
    def mark_queued(self) -> None:
        self._queued_at = time.monotonic()

    def mark_dequeued(self) -> None:
        if self._queued_at is not None:
            self.queue_wait_s += time.monotonic() - self._queued_at
            self._queued_at = None

    @property
    def can_preempt(self) -> bool:
        """Preemption needs a checkpoint root to suspend into and a
        checkpoint-capable method (both family members here qualify)."""
        return (self.spec.preemptible and self.ckpt_root is not None
                and self.state == RUNNING and not self.guard.requested())

    # ------------------------------------------------------------- worker
    def run(self) -> str:
        """Execute (or resume) the solve on the calling thread; returns
        the terminal state of this segment (DONE/SUSPENDED/FAILED)."""
        t0 = time.monotonic()
        self.state = RUNNING
        self.guard.clear()
        self.segments += 1
        resume = self.ckpt_root if self._resume_next else None
        if resume is not None:
            self.resumes += 1
        spec = self.spec
        try:
            ns = self.store.namespace(spec.job_id)
            op, labels = build_problem(spec, ns)
            checkpoint = None
            if self.ckpt_root is not None:
                checkpoint = CheckpointPolicy(
                    root=self.ckpt_root,
                    every_restarts=spec.checkpoint_every,
                    keep=2, guard=self.guard)
            block = spec.block_size or (2 * spec.nev
                                        if spec.method == "lobpcg"
                                        else spec.nev)
            res = solve(op, spec.nev, method=spec.method, which=spec.which,
                        tol=spec.tol, max_iters=spec.max_iters,
                        block_size=block, store=ns, impl="ref",
                        seed=spec.seed, callback=self.tracker.chain(),
                        checkpoint=checkpoint, resume=resume,
                        **spec.options)
            self.result = {
                "eigenvalues": np.sort(np.asarray(res.eigenvalues,
                                                  np.float64)).tolist(),
                "residuals": np.asarray(res.residuals,
                                        np.float64).tolist(),
                "converged": bool(res.converged),
                "n_restarts": int(res.n_restarts),
                "resumed_step": res.resumed_step,
                "io_stats": res.io_stats,
            }
            if spec.kind == "cluster" and res.eigenvectors is not None:
                emb = np.asarray(res.eigenvectors)[:spec.n]
                self.purity = spherical_kmeans_purity(
                    emb, labels, spec.k_classes)
            self.state = DONE
        except SolveSuspended:
            self.preemptions += 1
            self._resume_next = True
            self.state = SUSPENDED
        except (CorruptPageError, CorruptSnapshotError) as e:
            # Corruption recovery: the detection already guaranteed no
            # rotten bytes were served. If the retry budget allows, exit
            # SUSPENDED — the scheduler abandons this namespace (its
            # corrupt pages die with it) and requeues us; the next run()
            # resumes from the newest checkpoint that VERIFIES (the
            # resume path skips corrupt/torn snapshots), or from scratch
            # when none does. Budget exhausted → typed failure.
            if (self.ckpt_root is not None
                    and self.corruption_recoveries
                    < spec.max_corruption_retries):
                self.corruption_recoveries += 1
                self._resume_next = True
                trace.event("serve.corruption_recovery", job=spec.job_id,
                            attempt=self.corruption_recoveries,
                            error=f"{type(e).__name__}: {e}")
                self.state = SUSPENDED
            else:
                self.error = f"{type(e).__name__}: {e}"
                self.state = FAILED
        except Exception as e:            # captured into the serve report
            self.error = f"{type(e).__name__}: {e}"
            self.state = FAILED
        finally:
            self.wall_s += time.monotonic() - t0
        return self.state

    # ------------------------------------------------------------ surface
    def progress(self) -> dict:
        """Live progress for the scheduler's gauges: step count, worst
        relative residual, and the ConvergenceTracker ETA."""
        hist = self.tracker.history
        last = hist[-1][1] if hist else None
        return {
            "state": self.state,
            "priority": self.spec.priority,
            "steps": len(hist),
            "res_max_rel": (None if last is None or not np.isfinite(last)
                            else float(last)),
            "eta_steps": self.tracker.eta_steps(),
            "preemptions": self.preemptions,
            "corruption_recoveries": self.corruption_recoveries,
            "segments": self.segments,
        }

    def report(self) -> dict:
        """The per-job block of the machine-readable serve report."""
        return {
            "job_id": self.spec.job_id,
            "kind": self.spec.kind,
            "method": self.spec.method,
            "priority": self.spec.priority,
            "state": self.state,
            "wall_s": self.wall_s,
            "queue_wait_s": self.queue_wait_s,
            "preemptions": self.preemptions,
            "corruption_recoveries": self.corruption_recoveries,
            "resumes": self.resumes,
            "segments": self.segments,
            "purity": self.purity,
            "error": self.error,
            "result": self.result,
            "spectrum": spectrum_digest(
                self.result["eigenvalues"]) if self.result else None,
        }


def spectrum_digest(eigenvalues: List[float]) -> dict:
    """Stable digest of a spectrum for cross-run comparison: the sorted
    eigenvalues rounded to 1e-8 plus a hash of those rounded bytes."""
    import hashlib
    vals = np.sort(np.asarray(eigenvalues, np.float64))
    rounded = np.round(vals, 8)
    h = hashlib.sha256(rounded.tobytes()).hexdigest()[:16]
    return {"nev": int(vals.size), "values": rounded.tolist(), "sha": h}
