"""repro.train"""
