"""Training loop: data pipeline + optimizer + checkpoint/restart + FT hooks.

Production posture on a pod; runs identically (slower) on the CPU debug
mesh. Fault-tolerance wiring:
  * checkpoint every `ckpt_every` steps through AsyncWriter (atomic
    manifest); restore-on-start picks the newest valid step — preemption
    or crash loses at most `ckpt_every` steps;
  * PreemptionGuard converts SIGTERM into "checkpoint now, exit 0";
  * StragglerTracker consumes per-step timings (per-host in a real pod);
  * the data pipeline is a pure function of (seed, step): restart resumes
    mid-epoch exactly.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.ft.preemption import PreemptionGuard
from repro.ft.straggler import StragglerTracker
from repro.models import steps as S
from repro.models import sharding as shd
from repro.optim import adamw


@dataclasses.dataclass
class TrainConfig:
    steps: int = 300
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_ckpts: int = 3
    log_every: int = 10
    peak_lr: float = 3e-4
    warmup: int = 50
    num_microbatches: int = 1
    seed: int = 0


def train(cfg, tcfg: TrainConfig, data_cfg: DataConfig, *,
          mesh=None, log: Callable[[str], None] = print) -> dict:
    """Returns summary metrics. cfg is an ArchConfig (usually reduced/custom)."""
    key = jax.random.PRNGKey(tcfg.seed)
    params, opt_state = S.init_all(key, cfg)
    step_fn = S.build_train_step(cfg, num_microbatches=tcfg.num_microbatches,
                                 peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                                 total_steps=tcfg.steps)
    if mesh is not None:
        pspec = shd.param_specs(params, cfg, mesh)
        pshard = shd.to_named(pspec, mesh)
        params = jax.device_put(params, pshard)
        step_fn = jax.jit(step_fn)
    else:
        step_fn = jax.jit(step_fn)

    pipe = TokenPipeline(data_cfg)
    writer = ckpt.AsyncWriter()
    start_step = 0
    latest = ckpt.latest_step(tcfg.ckpt_dir)
    if latest is not None:
        (params, opt_state), extra = ckpt.restore(
            tcfg.ckpt_dir, latest, (params, opt_state))
        start_step = int(extra.get("data_step", latest))
        log(f"restored checkpoint step {latest}; resuming at {start_step}")

    tracker = StragglerTracker()
    losses = []
    t_start = time.time()
    with PreemptionGuard() as guard:
        step = start_step
        while step < tcfg.steps:
            batch = pipe.batch(step)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tracker.record(0, dt)
            losses.append(loss)
            if step % tcfg.log_every == 0:
                log(f"step {step:5d} loss {loss:8.4f} "
                    f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
            step += 1
            if step % tcfg.ckpt_every == 0 or guard.requested():
                writer.submit(tcfg.ckpt_dir, step, (params, opt_state),
                              extra={"data_step": step})
                if guard.requested():
                    log("preemption requested — checkpointed, exiting")
                    break
        writer.submit(tcfg.ckpt_dir, step, (params, opt_state),
                      extra={"data_step": step})
        writer.wait()
        ckpt.gc_old(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)

    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "first_loss": losses[0] if losses else float("nan"),
        "steps_run": len(losses),
        "wall_s": time.time() - t_start,
        "straggler_decisions": [dataclasses.asdict(d)
                                for d in tracker.decisions()],
    }
