"""repro.utils"""
