"""Parse collective traffic out of compiled/lowered HLO text.

cost_analysis() has no collective-bytes entry, so §Roofline's collective
term is derived here: we scan the (SPMD, per-device) HLO for collective ops,
take the result shapes, and model per-device wire bytes with the standard
ring-algorithm costs:

  all-gather         out·(g−1)/g          (receives g−1 chunks of out/g)
  reduce-scatter     out·(g−1)            (= in·(g−1)/g, in = g·out)
  all-reduce         2·in·(g−1)/g         (reduce-scatter + all-gather)
  all-to-all         in·(g−1)/g
  collective-permute out                  (one hop)

Group size g comes from replica_groups (explicit braces or iota form
[ngroups,g]<=[N]).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every dtype[dims] occurrence in a type string
    (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        first = m.group(1).strip()
        return len(first.split(",")) if first else 1
    return total_devices


def _source_pairs(line: str) -> int:
    m = re.search(r"source_target_pairs=\{(.*?)\}\}", line)
    return 1 if m else 1


def collective_bytes(hlo_text: str, total_devices: int
                     ) -> Dict[str, float]:
    """Per-device wire bytes by collective op kind (+ 'total')."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        # type is either a tuple "(f32[..]{..}, ...)" or a single token
        # "f32[512,2]{1,0}" — layouts included — followed by the op call
        opm = re.match(r"((?:\([^)]*\)|\S+))\s+"
                       r"(all-gather-start|all-gather|all-reduce-start|"
                       r"all-reduce|reduce-scatter|all-to-all|"
                       r"collective-permute-start|collective-permute)\(",
                       rhs)
        if not opm:
            continue
        type_str, op = opm.group(1), opm.group(2)
        base = op.replace("-start", "")
        nbytes = _shape_bytes(type_str)
        if nbytes == 0:
            continue
        g = _group_size(stripped, total_devices)
        if g <= 1 and base != "collective-permute":
            continue
        if base == "all-gather":
            wire = nbytes * (g - 1) / g
        elif base == "all-reduce":
            # start-op result type may include the input tuple; use half
            if "start" in op:
                nbytes = nbytes / 2 if "(" in type_str else nbytes
            wire = 2 * nbytes * (g - 1) / g
        elif base == "reduce-scatter":
            wire = nbytes * (g - 1)
        elif base == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = nbytes
        out[base] += wire
        out["count_" + base] += 1
    out["total"] = sum(v for k, v in out.items()
                       if not k.startswith("count_") and k != "total")
    return dict(out)
