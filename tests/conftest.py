import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)


@pytest.fixture(scope="session")
def small_graph():
    """Symmetric normalized-adjacency RMAT graph (n=1200) + scipy CSR."""
    import scipy.sparse as sp
    from repro.graphs import rmat_graph, normalized_adjacency
    n = 1200
    r, c, v = rmat_graph(n, 10000, seed=5, symmetric=True)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    a = sp.coo_matrix((v2, (r2, c2)), shape=(n, n)).tocsr()
    return n, r2, c2, v2, a
