import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def run_forced_mesh():
    """Run python `code` in a subprocess pinned to forced host devices.

    Multi-device mesh tests need >1 device while the main test process
    must keep seeing exactly 1 (the dry-run contract), so they run in
    subprocesses. scripts/run_tier1.sh pins DIST_SUBPROCESS_XLA_FLAGS for
    reproducibility on CPU-only boxes; the default matches the pin.
    """
    def run(code: str, timeout: float = 420) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = env.get(
            "DIST_SUBPROCESS_XLA_FLAGS",
            "--xla_force_host_platform_device_count=8")
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=timeout)
        assert out.returncode == 0, out.stdout + out.stderr
        return out.stdout
    return run

# Graceful skip for property-based test modules when hypothesis is not
# installed (see requirements-dev.txt): ignoring them at collection keeps
# the rest of the suite collectable instead of erroring the whole session.
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore = ["test_ft.py", "test_ortho.py", "test_partition.py",
                      "test_tiles.py", "test_safs_props.py"]


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "disk: filesystem-touching test (SAFS page files); run in a bounded "
        "TMPDIR via scripts/run_tier1.sh and size-guarded by disk_tmp")


# Per-test byte budget for SAFS page files — a runaway page store should
# fail its own test, not fill the build box's disk.
DISK_TMP_BUDGET = 64 << 20


def _tree_bytes(root: str) -> int:
    total = 0
    for dirpath, _, files in os.walk(root):
        for f in files:
            try:
                total += os.path.getsize(os.path.join(dirpath, f))
            except OSError:
                pass
    return total


@pytest.fixture
def disk_tmp(tmp_path):
    """tmp dir for pytest.mark.disk tests with a teardown size guard."""
    yield str(tmp_path)
    used = _tree_bytes(str(tmp_path))
    assert used <= DISK_TMP_BUDGET, (
        f"disk test left {used/1e6:.1f} MB in {tmp_path} "
        f"(budget {DISK_TMP_BUDGET/1e6:.0f} MB)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)


@pytest.fixture(scope="session")
def small_graph():
    """Symmetric normalized-adjacency RMAT graph (n=1200) + scipy CSR."""
    import scipy.sparse as sp
    from repro.graphs import rmat_graph, normalized_adjacency
    n = 1200
    r, c, v = rmat_graph(n, 10000, seed=5, symmetric=True)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    a = sp.coo_matrix((v2, (r2, c2)), shape=(n, n)).tocsr()
    return n, r2, c2, v2, a
