"""Checkpoint/restart: atomicity, latest-step discovery, elastic reshard,
async writer, GC."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": jnp.asarray(rng.standard_normal((8, 4)),
                                       jnp.float32),
                      "b": jnp.zeros((4,), jnp.bfloat16)},
            "step": jnp.asarray(3, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, extra={"data_step": 7})
    restored, extra = ck.restore(str(tmp_path), 7, t)
    assert extra["data_step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_ignores_partial(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 5, t)
    ck.save(str(tmp_path), 10, t)
    # simulate a crash mid-write: directory without manifest
    os.makedirs(tmp_path / "step_0000000015")
    assert ck.latest_step(str(tmp_path)) == 10


def test_structure_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, {"other": jnp.zeros((2,))})


def test_gc_keeps_newest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t)
    ck.gc_old(str(tmp_path), keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(steps) == 2


def test_async_writer(tmp_path):
    w = ck.AsyncWriter()
    w.submit(str(tmp_path), 3, _tree())
    w.wait()
    assert ck.latest_step(str(tmp_path)) == 3


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore onto a different sharding (device count changed)."""
    t = _tree()
    ck.save(str(tmp_path), 2, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ck.restore(str(tmp_path), 2, t, shardings=sh)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_restart_resumes(tmp_path):
    """Kill-and-restart: second run resumes from the checkpoint, and the
    deterministic pipeline serves the same batches."""
    from repro import configs
    from repro.data.pipeline import DataConfig
    from repro.train.trainer import TrainConfig, train
    cfg = configs.reduced("qwen2-1.5b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    t1 = TrainConfig(steps=4, ckpt_every=2, ckpt_dir=str(tmp_path),
                     log_every=100)
    s1 = train(cfg, t1, dcfg, log=lambda *_: None)
    assert s1["steps_run"] == 4
    # "crash" happened — restart with more steps; must resume, not redo
    t2 = TrainConfig(steps=6, ckpt_every=2, ckpt_dir=str(tmp_path),
                     log_every=100)
    s2 = train(cfg, t2, dcfg, log=lambda *_: None)
    assert s2["steps_run"] == 2          # only steps 4,5


def test_latest_step_gcs_stale_tmp(tmp_path):
    """A crash mid-`save` leaves a step_*.tmp staging dir behind.
    `latest_step` must never mistake it for a checkpoint, must reclaim it
    once it is clearly abandoned (old mtime), and must leave a *fresh*
    .tmp alone — that one may be an AsyncWriter mid-flight."""
    import time
    t = _tree()
    ck.save(str(tmp_path), 4, t)
    stale = tmp_path / "step_0000000009.tmp"
    fresh = tmp_path / "step_0000000011.tmp"
    os.makedirs(stale)
    os.makedirs(fresh)
    (stale / "leaf.npz").write_bytes(b"partial")
    old = time.time() - 7200
    os.utime(stale, (old, old))
    assert ck.latest_step(str(tmp_path)) == 4
    assert not stale.exists()           # abandoned staging dir reclaimed
    assert fresh.exists()               # in-flight writer untouched
    # and opting out leaves everything in place
    os.makedirs(stale)
    os.utime(stale, (old, old))
    assert ck.latest_step(str(tmp_path), gc_stale_tmp=False) == 4
    assert stale.exists()
