"""Fast CPU unit tests for the repro.dist layout/packing layer.

Everything here is single-device numpy-level: permutation bijectivity,
padding divisibility, panel packing conservation and index bounds, the
compressed-stream roundtrip, and the bridge into the Pallas block-sparse
tile kernel. The multi-device semantics are covered by
tests/test_distributed.py's subprocess tests.
"""
import numpy as np
import pytest

from repro.dist import layout
from repro.dist.compress import (int8_dequantize, int8_quantize,
                                 topk_compress, topk_decompress, topk_init)
from repro.dist.dspmm import (CHUNK, pack_compressed_panels,
                              pack_edge_panels, panel_spmm_blocksparse)
from repro.dist.layout import padded_n, vertex_permutation
from repro.graphs import rmat_graph

GRIDS = [(1, 1), (2, 1), (1, 3), (4, 2), (8, 4)]


@pytest.mark.parametrize("r_groups,m_groups", GRIDS)
@pytest.mark.parametrize("n", [1, 7, 64, 1000])
def test_padded_n_divisible(n, r_groups, m_groups):
    n_pad = padded_n(n, r_groups, m_groups)
    assert n_pad >= n
    assert n_pad % (r_groups * m_groups) == 0
    # shards stay tile-row aligned
    assert (n_pad // (r_groups * m_groups)) % layout.SHARD_MULTIPLE == 0
    # and padding never exceeds one full block
    assert n_pad - n < r_groups * m_groups * layout.SHARD_MULTIPLE


@pytest.mark.parametrize("r_groups,m_groups", GRIDS)
def test_vertex_permutation_bijective_grid(r_groups, m_groups):
    # parametrized superset of the seed's single-case check in
    # tests/test_distributed.py (kept there: that file's 5 tests are the
    # dist subsystem's acceptance contract)
    n_pad = padded_n(997, r_groups, m_groups)
    perm = vertex_permutation(n_pad, r_groups, m_groups)
    assert perm.shape == (n_pad,)
    assert len(np.unique(perm)) == n_pad
    assert perm.min() == 0 and perm.max() == n_pad - 1


def test_local_col_roundtrip():
    n_pad = padded_n(300, 4, 2)
    pos = np.arange(n_pad)
    m = layout.col_group_of(pos, n_pad, 4, 2)
    c_loc = layout.local_col(pos, n_pad, 4, 2)
    for mm in range(2):
        sel = m == mm
        back = layout.unlocal_col(c_loc[sel], mm, n_pad, 4, 2)
        np.testing.assert_array_equal(back, pos[sel])


@pytest.mark.parametrize("r_groups,m_groups", [(2, 2), (4, 2), (3, 1)])
def test_pack_edge_panels_conserves_edges_grid(r_groups, m_groups):
    n = 257
    r, c, v = rmat_graph(n, 1500, seed=3, symmetric=True)
    n_pad = padded_n(n, r_groups, m_groups)
    perm = vertex_permutation(n_pad, r_groups, m_groups)
    pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                         r_groups=r_groups,
                                         m_groups=m_groups)
    assert pc.shape == pr.shape == pv.shape == (r_groups, m_groups, e_loc)
    assert (pv != 0).sum() == len(v)           # every edge, exactly once
    assert abs(pv.sum() - v.sum()) < 1e-3      # value mass conserved
    # local indices stay inside the per-group working sets
    assert pr.min() >= 0 and pr.max() < n_pad // r_groups
    assert pc.min() >= 0 and pc.max() < n_pad // m_groups


def test_pack_edge_panels_reconstructs_matrix():
    """Panels + local->global index maps rebuild exactly A (permuted)."""
    n, R, M = 120, 4, 2
    r, c, v = rmat_graph(n, 800, seed=7, symmetric=True)
    n_pad = padded_n(n, R, M)
    perm = vertex_permutation(n_pad, R, M)
    pc, pr, pv, _ = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                     r_groups=R, m_groups=M)
    dense = np.zeros((n_pad, n_pad), np.float32)
    for g in range(R):
        for m in range(M):
            live = pv[g, m] != 0
            rows = g * (n_pad // R) + pr[g, m][live]
            cols = layout.unlocal_col(pc[g, m][live], m, n_pad, R, M)
            np.add.at(dense, (rows, cols), pv[g, m][live])
    want = np.zeros((n_pad, n_pad), np.float32)
    want[perm[r], perm[c]] = v
    np.testing.assert_array_equal(dense, want)


def test_pack_compressed_roundtrip():
    n, R, M = 200, 2, 2
    r, c, v = rmat_graph(n, 1200, seed=9, symmetric=True)
    n_pad = padded_n(n, R, M)
    perm = vertex_permutation(n_pad, R, M)
    pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                         r_groups=R, m_groups=M)
    packed, bases, valsb = pack_compressed_panels(pc, pr, pv, chunk=64)
    e_pad = packed.shape[-1]
    n_chunks = e_pad // 64
    assert e_pad % 64 == 0 and e_pad >= e_loc
    assert packed.dtype == np.uint32
    assert bases.shape == (R, M, 2 * n_chunks)
    # numpy-side unpack must reproduce the panel endpoints exactly
    for g in range(R):
        for m in range(M):
            b2 = bases[g, m].reshape(n_chunks, 2)
            off = packed[g, m].reshape(n_chunks, 64)
            rr = (off >> 16).astype(np.int64) + b2[:, :1]
            cc = (off & 0xFFFF).astype(np.int64) + b2[:, 1:]
            np.testing.assert_array_equal(rr.reshape(-1)[:e_loc], pr[g, m])
            np.testing.assert_array_equal(cc.reshape(-1)[:e_loc], pc[g, m])
    # padding carries zero weight; live weights survive the bf16 cast
    live = np.asarray(valsb, np.float32)
    assert (live != 0).sum() == len(v)
    assert CHUNK % 2 == 0  # dryrun sizes streams against the real CHUNK


def _unpack_np(packed, bases):
    """Shape-driven numpy unpack (mirrors dspmm._unpack_edges)."""
    n_sub = bases.shape[-1] // 2
    sub = packed.shape[-1] // n_sub
    b2 = bases.reshape(n_sub, 2)
    off = packed.reshape(n_sub, sub)
    rr = (off >> 16).astype(np.int64) + b2[:, :1]
    cc = (off & 0xFFFF).astype(np.int64) + b2[:, 1:]
    return rr.reshape(-1), cc.reshape(-1)


def test_pack_compressed_subtile_rebasing_at_16bit_boundary():
    """Regression for the ROADMAP follow-up: when one chunk's column span
    exceeds 2^16 (panel width n_pad/M > 65536 — one dense row sweeps the
    whole panel), the stream must re-base at sub-tile granularity instead
    of raising, and still round-trip exactly."""
    chunk = 64
    # one panel, one source row fanning out across a 200k-wide panel:
    # column deltas within any 64-edge chunk reach ~99k > 0xFFFF
    e = 2 * chunk
    pr = np.zeros((1, 1, e), np.int32)
    pc = np.zeros((1, 1, e), np.int32)
    pc[0, 0] = np.linspace(0, 200_000, e).astype(np.int32)
    pv = np.ones((1, 1, e), np.float32)
    packed, bases, valsb = pack_compressed_panels(pc, pr, pv, chunk=chunk)
    assert packed.shape[-1] == e            # e_pad stays a chunk multiple
    n_sub = bases.shape[-1] // 2
    sub = packed.shape[-1] // n_sub
    assert sub < chunk and packed.shape[-1] % sub == 0  # re-based finer
    rr, cc = _unpack_np(packed[0, 0], bases[0, 0])
    np.testing.assert_array_equal(rr, pr[0, 0])
    np.testing.assert_array_equal(cc, pc[0, 0])

    # boundary case: span of exactly 0xFFFF must NOT trigger re-basing
    pc2 = np.zeros((1, 1, chunk), np.int32)
    pc2[0, 0, -1] = 0xFFFF
    packed2, bases2, _ = pack_compressed_panels(
        pc2, np.zeros_like(pc2), np.ones((1, 1, chunk), np.float32),
        chunk=chunk)
    assert bases2.shape[-1] == 2            # single chunk, single base
    rr2, cc2 = _unpack_np(packed2[0, 0], bases2[0, 0])
    np.testing.assert_array_equal(cc2, pc2[0, 0])

    # one past the boundary: a half/half split needs exactly one halving
    # (each chunk/2 sub-tile then spans 0 around its own base)
    pc3 = np.zeros((1, 1, chunk), np.int32)
    pc3[0, 0, chunk // 2:] = 0x10000
    packed3, bases3, _ = pack_compressed_panels(
        pc3, np.zeros_like(pc3), np.ones((1, 1, chunk), np.float32),
        chunk=chunk)
    assert bases3.shape[-1] == 4            # 2 sub-tiles of chunk/2
    rr3, cc3 = _unpack_np(packed3[0, 0], bases3[0, 0])
    np.testing.assert_array_equal(cc3, pc3[0, 0])


def test_pack_compressed_subtile_stream_drives_eigen_step():
    """A re-based stream must decode identically through the jit'd unpack
    path (shape-driven sub-tile recovery — no side channel)."""
    import jax
    from repro.dist.dspmm import _unpack_edges
    chunk = 32
    e = 3 * chunk
    pr = np.random.default_rng(0).integers(0, 50, (1, 1, e)).astype(np.int32)
    pc = np.sort(np.random.default_rng(1)
                 .integers(0, 200_000, (1, 1, e)).astype(np.int32))
    pv = np.ones((1, 1, e), np.float32)
    packed, bases, _ = pack_compressed_panels(pc, pr, pv, chunk=chunk)
    rr, cc = jax.jit(_unpack_edges)(packed[0, 0], bases[0, 0])
    np.testing.assert_array_equal(np.asarray(rr), pr[0, 0])
    np.testing.assert_array_equal(np.asarray(cc), pc[0, 0])


def test_panel_blocksparse_bridge_matches_scatter():
    """One packed panel driven through kernels/spmm_tile.py (interpret
    mode) agrees with the dense reference — pins the panel format to the
    fixed Pallas kernels layer."""
    n, R, M = 64, 2, 2
    r, c, v = rmat_graph(n, 500, seed=1, symmetric=True)
    n_pad = padded_n(n, R, M)
    perm = vertex_permutation(n_pad, R, M)
    pc, pr, pv, _ = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                     r_groups=R, m_groups=M)
    dense = np.zeros((n_pad, n_pad), np.float32)
    dense[perm[r], perm[c]] = v
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n_pad, 4)).astype(np.float32)
    g, m = 1, 0
    n_rows, n_cols = n_pad // R, n_pad // M
    cols_global = layout.unlocal_col(np.arange(n_cols), m, n_pad, R, M)
    x_panel = x[cols_global]
    y = panel_spmm_blocksparse(pr[g, m], pc[g, m], pv[g, m], x_panel,
                               n_rows, bm=8, bn=8, interpret=True)
    want = dense[g * n_rows:(g + 1) * n_rows][:, cols_global] @ x_panel
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)


# Plain-pytest coverage of the compress point APIs: the property-based
# versions in test_ft.py only run when hypothesis is installed (the whole
# module is collect-ignored otherwise), so the error bounds are pinned here
# too.
def test_int8_roundtrip_error_bound():
    import jax.numpy as jnp
    x = jnp.asarray(np.random.default_rng(42).standard_normal((256,)),
                    jnp.float32)
    q, s = int8_quantize(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(int8_dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


@pytest.mark.parametrize("seed", [0, 3, 1_000_000])
def test_topk_error_feedback_converges(seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    state = topk_init(g)
    acc = np.zeros(64, np.float32)
    t = 24
    for _ in range(t):
        vals, idx, state = topk_compress(g, state, k=8)
        acc += np.asarray(topk_decompress(vals, idx, (64,)))
    np.testing.assert_allclose(acc / t, np.asarray(g), rtol=0.35, atol=0.35)


def test_topk_exact_when_k_full():
    import jax.numpy as jnp
    g = jnp.asarray(np.random.default_rng(1).standard_normal((32,)),
                    jnp.float32)
    vals, idx, state = topk_compress(g, topk_init(g), k=32)
    np.testing.assert_allclose(
        np.asarray(topk_decompress(vals, idx, (32,))), np.asarray(g),
        rtol=1e-6)
    assert float(jnp.max(jnp.abs(state.error))) < 1e-6
