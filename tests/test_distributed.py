"""Distributed-layer tests. Collective tests need >1 device, so they run in
a subprocess with forced host devices (the main test process must keep
seeing 1 device, per the dry-run contract). The subprocess harness is the
shared `run_forced_mesh` fixture in conftest.py."""


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1


def test_distributed_spmm_and_eigenstep(run_forced_mesh):
    out = run_forced_mesh("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, numpy as np, jax.numpy as jnp
        from repro.dist.layout import padded_n, vertex_permutation
        from repro.dist.dspmm import build_dspmm, build_eigen_step, \\
            pack_edge_panels
        from repro.graphs import rmat_graph
        from repro.graphs.synth import to_dense

        mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
        R, M = 4, 2
        n = 500
        r, c, v = rmat_graph(n, 4000, seed=11, symmetric=True)
        n_pad = padded_n(n, R, M)
        perm = vertex_permutation(n_pad, R, M)
        pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                             r_groups=R, m_groups=M)
        rng = np.random.default_rng(0)
        x = np.zeros((n_pad, 4), np.float32)
        x_nat = rng.standard_normal((n, 4)).astype(np.float32)
        x[perm[:n]] = x_nat
        spmm = build_dspmm(mesh, n_pad=n_pad, e_loc=e_loc, b=4)
        y = np.asarray(spmm(jnp.array(pc), jnp.array(pr), jnp.array(pv),
                            jnp.array(x)))
        dense = to_dense(n, r, c, v)
        np.testing.assert_allclose(y[perm[:n]], dense @ x_nat,
                                   rtol=1e-4, atol=1e-4)

        nb_v = 3
        vb = rng.standard_normal((n_pad, nb_v*4)).astype(np.float32)
        qv, _ = np.linalg.qr(vb)
        vstack = np.ascontiguousarray(
            qv.reshape(n_pad, nb_v, 4).transpose(1, 0, 2)).astype(np.float32)
        step = build_eigen_step(mesh, n_pad=n_pad, e_loc=e_loc, b=4,
                                nb_v=nb_v)
        qn, h, rr = step(jnp.array(pc), jnp.array(pr), jnp.array(pv),
                         jnp.array(vstack), jnp.array(x))
        qn, h, rr = map(np.asarray, (qn, h, rr))
        assert np.abs(qn.T @ qn - np.eye(4)).max() < 1e-4
        assert np.abs(qv.astype(np.float32).T @ qn).max() < 1e-4
        ax = np.zeros((n_pad, 4), np.float32)
        ax[perm[:n]] = dense @ x[perm[:n]]
        recon = qv.astype(np.float32) @ h + qn @ rr
        assert np.abs(ax - recon).max() / np.abs(ax).max() < 1e-4
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


def test_dist_operator_single_device_parity():
    """The fused-expand hook end-to-end on the main process's 1-device
    (1,1,1) mesh: eigsh drives build_eigen_step through DistOperator and
    must reproduce the local GraphOperator spectrum to rtol 1e-5."""
    import numpy as np
    from repro.core import GraphOperator, eigsh
    from repro.dist import DistOperator
    from repro.graphs import pack_tiles, rmat_spectral
    n = 500
    r, c, v = rmat_spectral(n, 5000, seed=7)
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    local = eigsh(GraphOperator(tm, impl="ref"), 4, block_size=2,
                  tol=1e-7, max_restarts=100, impl="ref")
    dop = DistOperator(n, r, c, v)
    dist = eigsh(dop, 4, block_size=2, tol=1e-7, max_restarts=100,
                 impl="ref")
    assert dop.n_fused_steps > 0           # really took the fused path
    np.testing.assert_allclose(np.sort(dist.eigenvalues),
                               np.sort(local.eigenvalues), rtol=1e-5)
    # vertex maps: nat<->pad round-trip, and the returned eigenvectors
    # (position space) must satisfy the NATURAL-space eigen equation
    # once mapped back through pad_to_nat
    x = np.random.default_rng(0).standard_normal((n, 3)).astype(np.float32)
    np.testing.assert_array_equal(dop.pad_to_nat(dop.nat_to_pad(x)), x)
    from repro.graphs.synth import to_dense
    a = to_dense(n, r, c, v)
    vec = dop.pad_to_nat(dist.eigenvectors)
    res = np.linalg.norm(a @ vec - vec * dist.eigenvalues[None, :], axis=0)
    assert res.max() < 1e-3, res


def test_dist_eigsh_parity_and_pod_compressed(run_forced_mesh):
    """End-to-end dist-vs-core spectrum parity on an RMAT graph over the
    pinned 8-device (2,2,2) mesh, plus the pod_compressed tolerance check
    over >= 2 full restart cycles (ROADMAP: measure error accumulation)."""
    out = run_forced_mesh("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, numpy as np
        from repro.core import GraphOperator, eigsh
        from repro.dist import DistOperator
        from repro.graphs import pack_tiles, rmat_spectral

        n, nev, bs = 600, 4, 2
        r, c, v = rmat_spectral(n, 6000, seed=1)
        tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64),
                        min_block_nnz=4)
        local = eigsh(GraphOperator(tm, impl="ref"), nev, block_size=bs,
                      tol=1e-7, max_restarts=100, impl="ref")
        w_local = np.sort(local.eigenvalues)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        dop = DistOperator(n, r, c, v, mesh=mesh)
        dist = eigsh(dop, nev, block_size=bs, tol=1e-7, max_restarts=100,
                     impl="ref")
        assert dist.converged and dop.n_fused_steps > 0
        np.testing.assert_allclose(np.sort(dist.eigenvalues), w_local,
                                   rtol=1e-5)

        # pod_compressed: int8 cross-pod reductions; the shared |lambda|
        # deviation methodology (dist.pod_compressed_deviation) must
        # settle, not grow, over >= 2 full restart cycles
        from repro.dist import pod_compressed_deviation
        devs = pod_compressed_deviation(n, r, c, v, w_local, mesh=mesh,
                                        nev=nev, block_size=bs,
                                        max_restarts=3)
        assert len(devs) >= 2, devs
        assert devs[-1] < 2e-2, devs
        assert devs[-1] <= 2.0 * min(devs[1:]) + 1e-12, devs

        # compressed 6-byte/edge stream (bf16 subspace stack): tracks the
        # spectrum to input-rounding tolerance
        dop_z = DistOperator(n, r, c, v, mesh=mesh, compressed=True)
        comp = eigsh(dop_z, nev, block_size=bs, tol=1e-4, max_restarts=20,
                     impl="ref")
        dev_z = np.abs(np.sort(np.abs(comp.eigenvalues))
                       - np.sort(np.abs(w_local))).max()
        assert dev_z < 5e-3, dev_z
        print("DIST_E2E_OK", devs, dev_z)
    """)
    assert "DIST_E2E_OK" in out


def test_compressed_pod_psum(run_forced_mesh):
    out = run_forced_mesh("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist.compress import compressed_psum_pod
        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = np.random.default_rng(0).standard_normal((2, 64)).astype(
            np.float32)
        f = shard_map(lambda v: compressed_psum_pod(v[0], "pod"),
                      mesh=mesh, in_specs=P("pod", None),
                      out_specs=P(None))
        got = np.asarray(jax.jit(f)(jnp.asarray(x)))
        want = x.sum(0)
        # worst case err <= n_pods * scale/2 per element
        bound = 2 * np.abs(x).max() / 127.0
        assert np.abs(got - want).max() <= bound + 1e-6
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_vertex_permutation_bijective():
    import numpy as np
    from repro.dist.layout import padded_n, vertex_permutation
    n_pad = padded_n(1000, 4, 2)
    perm = vertex_permutation(n_pad, 4, 2)
    assert len(np.unique(perm)) == n_pad


def test_pack_edge_panels_conserves_edges():
    import numpy as np
    from repro.dist.layout import padded_n, vertex_permutation
    from repro.dist.dspmm import pack_edge_panels
    from repro.graphs import rmat_graph
    n = 300
    r, c, v = rmat_graph(n, 2000, seed=2, symmetric=True)
    n_pad = padded_n(n, 4, 2)
    perm = vertex_permutation(n_pad, 4, 2)
    pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                         r_groups=4, m_groups=2)
    assert (pv != 0).sum() == len(v)
    assert abs(pv.sum() - v.sum()) < 1e-3
