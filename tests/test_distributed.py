"""Distributed-layer tests. Collective tests need >1 device, so they run in
a subprocess with forced host devices (the main test process must keep
seeing 1 device, per the dry-run contract). The subprocess harness is the
shared `run_forced_mesh` fixture in conftest.py."""


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1


def test_distributed_spmm_and_eigenstep(run_forced_mesh):
    out = run_forced_mesh("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, numpy as np, jax.numpy as jnp
        from repro.dist.layout import padded_n, vertex_permutation
        from repro.dist.dspmm import build_dspmm, build_eigen_step, \\
            pack_edge_panels
        from repro.graphs import rmat_graph
        from repro.graphs.synth import to_dense

        mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
        R, M = 4, 2
        n = 500
        r, c, v = rmat_graph(n, 4000, seed=11, symmetric=True)
        n_pad = padded_n(n, R, M)
        perm = vertex_permutation(n_pad, R, M)
        pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                             r_groups=R, m_groups=M)
        rng = np.random.default_rng(0)
        x = np.zeros((n_pad, 4), np.float32)
        x_nat = rng.standard_normal((n, 4)).astype(np.float32)
        x[perm[:n]] = x_nat
        spmm = build_dspmm(mesh, n_pad=n_pad, e_loc=e_loc, b=4)
        y = np.asarray(spmm(jnp.array(pc), jnp.array(pr), jnp.array(pv),
                            jnp.array(x)))
        dense = to_dense(n, r, c, v)
        np.testing.assert_allclose(y[perm[:n]], dense @ x_nat,
                                   rtol=1e-4, atol=1e-4)

        nb_v = 3
        vb = rng.standard_normal((n_pad, nb_v*4)).astype(np.float32)
        qv, _ = np.linalg.qr(vb)
        vstack = np.ascontiguousarray(
            qv.reshape(n_pad, nb_v, 4).transpose(1, 0, 2)).astype(np.float32)
        step = build_eigen_step(mesh, n_pad=n_pad, e_loc=e_loc, b=4,
                                nb_v=nb_v)
        qn, h, rr = step(jnp.array(pc), jnp.array(pr), jnp.array(pv),
                         jnp.array(vstack), jnp.array(x))
        qn, h, rr = map(np.asarray, (qn, h, rr))
        assert np.abs(qn.T @ qn - np.eye(4)).max() < 1e-4
        assert np.abs(qv.astype(np.float32).T @ qn).max() < 1e-4
        ax = np.zeros((n_pad, 4), np.float32)
        ax[perm[:n]] = dense @ x[perm[:n]]
        recon = qv.astype(np.float32) @ h + qn @ rr
        assert np.abs(ax - recon).max() / np.abs(ax).max() < 1e-4
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


def test_compressed_pod_psum(run_forced_mesh):
    out = run_forced_mesh("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist.compress import compressed_psum_pod
        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = np.random.default_rng(0).standard_normal((2, 64)).astype(
            np.float32)
        f = shard_map(lambda v: compressed_psum_pod(v[0], "pod"),
                      mesh=mesh, in_specs=P("pod", None),
                      out_specs=P(None))
        got = np.asarray(jax.jit(f)(jnp.asarray(x)))
        want = x.sum(0)
        # worst case err <= n_pods * scale/2 per element
        bound = 2 * np.abs(x).max() / 127.0
        assert np.abs(got - want).max() <= bound + 1e-6
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_vertex_permutation_bijective():
    import numpy as np
    from repro.dist.layout import padded_n, vertex_permutation
    n_pad = padded_n(1000, 4, 2)
    perm = vertex_permutation(n_pad, 4, 2)
    assert len(np.unique(perm)) == n_pad


def test_pack_edge_panels_conserves_edges():
    import numpy as np
    from repro.dist.layout import padded_n, vertex_permutation
    from repro.dist.dspmm import pack_edge_panels
    from repro.graphs import rmat_graph
    n = 300
    r, c, v = rmat_graph(n, 2000, seed=2, symmetric=True)
    n_pad = padded_n(n, 4, 2)
    perm = vertex_permutation(n_pad, 4, 2)
    pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                         r_groups=4, m_groups=2)
    assert (pv != 0).sum() == len(v)
    assert abs(pv.sum() - v.sum()) < 1e-3
