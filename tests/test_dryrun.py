"""Deliverable (e): the multi-pod dry-run machinery itself, exercised
end-to-end in a subprocess (512 forced host devices, production meshes)."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_cell_compiles_both_meshes(tmp_path):
    out_file = tmp_path / "cells.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    for extra in ([], ["--multi-pod"]):
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "flasheigen", "--graph", "twitter",
             "--out", str(out_file)] + extra,
            capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
        assert res.returncode == 0, res.stdout + res.stderr
    recs = [json.loads(l) for l in open(out_file)]
    assert {r["mesh"] for r in recs} == {"16x16", "2x16x16"}
    for r in recs:
        assert "error" not in r, r
        assert r["n_devices"] in (256, 512)
        assert r["collective_per_device"]["total"] > 0
        assert r["step_time_bound_s"] > 0
