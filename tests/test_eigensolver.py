"""Eigensolver correctness vs scipy + paper-claim validations."""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.graphs import pack_tiles, knn_band_graph, clustered_web_graph, \
    normalized_adjacency
from repro.core import (DenseOperator, GraphOperator, TieredStore, eigsh,
                        lanczos_eigsh, svds, true_residuals, HvpOperator)


def test_krylov_schur_vs_scipy(small_graph):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore()
    op = GraphOperator(tm, store=store, impl="ref")
    res = eigsh(op, 8, block_size=4, tol=1e-7, max_restarts=200,
                which="LM", store=store, impl="ref")
    w_sc = spla.eigsh(a, k=8, which="LM", return_eigenvectors=False)
    assert res.converged
    np.testing.assert_allclose(np.sort(res.eigenvalues), np.sort(w_sc),
                               rtol=1e-4, atol=1e-4)
    tr = true_residuals(op, jnp.asarray(res.eigenvectors), res.eigenvalues)
    assert tr.max() < 1e-4


def test_block_sizes_converge_to_same_spectrum(small_graph):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    w_sc = np.sort(spla.eigsh(a, k=4, which="LM",
                              return_eigenvectors=False))
    for b in (1, 2, 4):
        op = GraphOperator(tm, impl="ref")
        res = eigsh(op, 4, block_size=b, tol=1e-6, max_restarts=300,
                    which="LM", impl="ref", seed=b)
        np.testing.assert_allclose(np.sort(res.eigenvalues), w_sc,
                                   rtol=1e-3, atol=1e-3)


def test_lanczos_baseline_agrees(small_graph):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    op = GraphOperator(tm, impl="ref")
    res = lanczos_eigsh(op, 4, block_size=4, num_blocks=24, impl="ref")
    w_sc = np.sort(spla.eigsh(a, k=4, which="LM",
                              return_eigenvectors=False))
    np.testing.assert_allclose(np.sort(res.eigenvalues), w_sc,
                               rtol=1e-3, atol=1e-3)


def test_krylov_schur_less_io_than_lanczos(small_graph):
    """The paper picks Krylov–Schur because it generates the least I/O:
    restarts bound the subspace, so reorthogonalization streams fewer
    bytes than an unrestarted Lanczos run of equal accuracy."""
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    st_ks, st_lz = TieredStore(), TieredStore()
    eigsh(GraphOperator(tm, store=st_ks, impl="ref"), 4, block_size=4,
          num_blocks=6, tol=1e-6, max_restarts=100, store=st_ks, impl="ref")
    lanczos_eigsh(GraphOperator(tm, store=st_lz, impl="ref"), 4,
                  block_size=4, num_blocks=40, store=st_lz, impl="ref")
    # same converged spectrum budget; KS should stream less dense-matrix I/O
    ks_io = st_ks.stats.host_bytes_read + st_ks.stats.host_bytes_written
    lz_io = st_lz.stats.host_bytes_read + st_lz.stats.host_bytes_written
    assert ks_io < lz_io


def test_reads_dominate_writes(small_graph):
    """Paper Table 3: 145 TB read vs 4 TB written — the caching + lazy
    discipline makes the SSD tier read-dominated."""
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore()
    op = GraphOperator(tm, store=store, impl="ref")
    res = eigsh(op, 8, block_size=4, tol=1e-6, max_restarts=100,
                store=store, impl="ref")
    s = store.stats
    assert s.host_bytes_read > 10 * s.host_bytes_written


def test_svd_directed_graph():
    n = 800
    r, c, v = clustered_web_graph(n, 6000, seed=2)
    tm_a = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    tm_at = pack_tiles(n, n, c, r, v, block_shape=(64, 64), min_block_nnz=4)
    import scipy.sparse as sp
    a = sp.coo_matrix((v, (r, c)), shape=(n, n)).tocsr()
    res = svds(GraphOperator(tm_a, impl="ref"),
               GraphOperator(tm_at, impl="ref"), 5, block_size=2,
               tol=1e-6, max_restarts=150, impl="ref")
    s_sc = np.sort(spla.svds(a, k=5, return_singular_vectors=False))
    np.testing.assert_allclose(np.sort(res.s), s_sc, rtol=1e-3, atol=1e-3)
    # A v = u s
    err = np.linalg.norm(a @ res.v[:n] - res.u[:n] * res.s[None, :])
    assert err / np.linalg.norm(res.s) < 1e-2


def test_knn_graph_non_powerlaw():
    """The paper's KNN distance graph: banded, weighted, uniform degrees."""
    n = 1500
    r, c, v = knn_band_graph(n, k=6, seed=3)
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r2, c2, v2, block_shape=(64, 64), min_block_nnz=2)
    import scipy.sparse as sp
    a = sp.coo_matrix((v2, (r2, c2)), shape=(n, n)).tocsr()
    res = eigsh(GraphOperator(tm, impl="ref"), 6, block_size=2,
                tol=1e-6, max_restarts=300, which="LA", impl="ref")
    w_sc = np.sort(spla.eigsh(a, k=6, which="LA",
                              return_eigenvectors=False))
    np.testing.assert_allclose(np.sort(res.eigenvalues), w_sc,
                               rtol=1e-3, atol=1e-3)


def test_hvp_operator_quadratic():
    m = 48
    mat = np.random.default_rng(1).standard_normal((m, m)).astype(np.float32)
    h = mat @ mat.T / m
    params = {"w": jnp.zeros((m,), jnp.float32)}

    def loss(p):
        return 0.5 * p["w"] @ jnp.asarray(h) @ p["w"]

    hop = HvpOperator(loss, params, pad_to=8)
    res = eigsh(hop, 3, block_size=1, tol=1e-5, max_restarts=100,
                which="LA", impl="ref")
    w_true = np.sort(np.linalg.eigvalsh(h))[-3:]
    np.testing.assert_allclose(np.sort(res.eigenvalues), w_true,
                               rtol=1e-3, atol=1e-4)


def test_restart_state_is_small(small_graph):
    """Krylov-restart checkpoint = locked Ritz + current block: the paper's
    observation that restart compresses the subspace — the eigensolver's
    fault-tolerance unit is tiny vs the full subspace."""
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore()
    op = GraphOperator(tm, store=store, impl="ref")
    res = eigsh(op, 4, block_size=2, num_blocks=8, tol=1e-6,
                max_restarts=50, store=store, impl="ref")
    m = res.m_subspace
    keep = m // 2
    # compressed restart state vs full subspace storage
    assert keep * tm.shape[0] * 4 < m * tm.shape[0] * 4
