"""LOBPCG + paged-KV serving extensions."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import GraphOperator, TieredStore, eigsh
from repro.core.lobpcg import lobpcg
from repro.graphs import pack_tiles
from repro.serve.paged_kv import PagedConfig, PagedKVCache


def _tiles(small_graph):
    n, r, c, v, a = small_graph
    return n, a, pack_tiles(n, n, r, c, v, block_shape=(64, 64),
                            min_block_nnz=4)


def _lobpcg_expected_io(it: int, n: int, b: int, fused: bool):
    """The module-docstring accounting for a run that converges at
    iteration `it` (≥ 1) with P never fully deflating; B = n·b·4."""
    bb = n * b * 4
    if fused:
        return 3 * it + 1, (10 + 14 * (it - 1) + 2) * bb
    return 8 * it, (16 + 29 * (it - 1) + 2) * bb


def test_lobpcg_vs_scipy(small_graph):
    n, a, tm = _tiles(small_graph)
    res = lobpcg(GraphOperator(tm, impl="ref"), 4, block_size=8,
                 tol=1e-4, max_iters=300, which="LA")
    assert res.converged
    w = np.sort(spla.eigsh(a, k=4, which="LA", return_eigenvectors=False))
    np.testing.assert_allclose(np.sort(res.eigenvalues), w,
                               rtol=1e-3, atol=1e-3)


def test_lobpcg_small_working_set(small_graph):
    """LOBPCG's fast-tier working set is 3 blocks regardless of progress
    (the opposite trade from Krylov–Schur's growing basis)."""
    n, a, tm = _tiles(small_graph)
    res = lobpcg(GraphOperator(tm, impl="ref"), 2, block_size=4,
                 tol=1e-3, max_iters=100, which="LA")
    assert res.m_subspace == 12      # 3·b, constant


def test_lobpcg_pass_accounting_byte_exact(small_graph):
    """Real streamed-pass IOStats, byte-exact against the docstring
    formulas, on both the fused and unfused pass policies — and identical
    spectra (same math, same accumulation order)."""
    n, a, tm = _tiles(small_graph)
    evs = {}
    for fused in (True, False):
        store = TieredStore()
        op = GraphOperator(tm, impl="ref")
        res = lobpcg(op, 4, block_size=8,
                     tol=1e-4, max_iters=300, which="LA", store=store,
                     fused_passes=fused)
        assert res.converged and res.n_restarts >= 2
        # op.n, not the fixture n: pack_tiles pads rows to the tile grid
        exp_passes, exp_bytes = _lobpcg_expected_io(res.n_restarts, op.n, 8,
                                                    fused)
        assert res.io_stats["passes"] == exp_passes
        assert res.io_stats["pass_bytes_read"] == exp_bytes
        evs[fused] = np.sort(res.eigenvalues)
    np.testing.assert_array_equal(evs[True], evs[False])


def test_lobpcg_stall_guard_returns_best_iterate(small_graph):
    """With an unreachable tol the solver must stop at the f32 residual
    floor and return the best iterate — not iterate to max_iters and hand
    back a basis poisoned by noise W blocks (under which='LA' the RR
    garbage otherwise gets SELECTED into X)."""
    n, a, tm = _tiles(small_graph)
    res = lobpcg(GraphOperator(tm, impl="ref"), 4, block_size=8,
                 tol=1e-12, max_iters=120, which="LA", stall_iters=6)
    assert not res.converged
    assert res.n_restarts < 120          # stall guard fired
    w = np.sort(spla.eigsh(a, k=4, which="LA", return_eigenvectors=False))
    np.testing.assert_allclose(np.sort(res.eigenvalues), w,
                               rtol=1e-3, atol=1e-3)


@pytest.mark.disk
def test_lobpcg_safs_byte_exact_and_ram_parity(disk_tmp, small_graph):
    """The acceptance gate: LOBPCG with [X, W, P] genuinely in SAFS page
    files converges, reproduces the RAM-path spectrum to rtol 1e-5, and
    the streamed-pass accounting stays byte-exact (operator tile reads
    share the store but are excluded by the pass watermark)."""
    n, a, tm = _tiles(small_graph)
    evs = {}
    for backend in ("ram", "safs"):
        if backend == "ram":
            store = TieredStore()
        else:
            store = TieredStore(
                device_budget_bytes=2 * n * 4 * 8, backend="safs",
                backend_opts={"root": os.path.join(disk_tmp, "lobpcg"),
                              "cache_bytes": 3 * n * 4 * 8})
        op = GraphOperator(tm, store=store, impl="ref")
        res = lobpcg(op, 4, block_size=8, tol=1e-4, max_iters=300,
                     which="LA", store=store)
        assert res.converged
        exp_passes, exp_bytes = _lobpcg_expected_io(res.n_restarts, op.n, 8,
                                                    fused=True)
        assert res.io_stats["passes"] == exp_passes, backend
        assert res.io_stats["pass_bytes_read"] == exp_bytes, backend
        evs[backend] = np.sort(res.eigenvalues)
        if backend == "safs":
            assert store.backend.stats.host_bytes_read > 0
            store.close()
    np.testing.assert_allclose(evs["safs"], evs["ram"], rtol=1e-5)


def test_paged_kv_matches_dense(rng):
    cfg = PagedConfig(page_size=8, n_kv_heads=2, head_dim=16, hot_pages=2)
    cache = PagedKVCache(cfg)
    cache.start(0)
    s, h = 37, 4
    ks = rng.standard_normal((s, 2, 16)).astype(np.float32)
    vs = rng.standard_normal((s, 2, 16)).astype(np.float32)
    for t in range(s):
        cache.append(0, jnp.asarray(ks[t]), jnp.asarray(vs[t]))
    q = jnp.asarray(rng.standard_normal((h, 16)), jnp.float32)
    out = cache.attend(0, q)
    # dense reference
    qg = np.asarray(q).reshape(2, 2, 16)
    sc = np.einsum("kgd,skd->kgs", qg, ks) / np.sqrt(16)
    w = np.exp(sc - sc.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    ref = np.einsum("kgs,skd->kgd", w, vs).reshape(h, 16)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_paged_kv_spills_cold_pages(rng):
    cfg = PagedConfig(page_size=4, n_kv_heads=1, head_dim=8, hot_pages=2)
    store = TieredStore()
    cache = PagedKVCache(cfg, store)
    cache.start(0)
    for t in range(20):   # 5 pages; only 2 may stay hot
        cache.append(0, jnp.zeros((1, 8)), jnp.zeros((1, 8)))
    tiers = [store.tier_of(nm) for nm in cache._tables[0]]
    assert tiers.count("host") >= 3
    store.reset_stats()
    cache.gather(0)       # reading the full context hits the cold tier
    assert store.stats.host_bytes_read > 0
