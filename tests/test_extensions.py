"""LOBPCG + paged-KV serving extensions."""
import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import GraphOperator, TieredStore, eigsh
from repro.core.lobpcg import lobpcg
from repro.graphs import pack_tiles
from repro.serve.paged_kv import PagedConfig, PagedKVCache


def test_lobpcg_vs_scipy(small_graph):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    res = lobpcg(GraphOperator(tm, impl="ref"), 4, block_size=8,
                 tol=1e-4, max_iters=300, which="LA")
    w = np.sort(spla.eigsh(a, k=4, which="LA", return_eigenvectors=False))
    np.testing.assert_allclose(np.sort(res.eigenvalues), w,
                               rtol=1e-3, atol=1e-3)


def test_lobpcg_small_working_set(small_graph):
    """LOBPCG's fast-tier working set is 3 blocks regardless of progress
    (the opposite trade from Krylov–Schur's growing basis)."""
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    res = lobpcg(GraphOperator(tm, impl="ref"), 2, block_size=4,
                 tol=1e-3, max_iters=100, which="LA")
    assert res.m_subspace == 12      # 3·b, constant


def test_paged_kv_matches_dense(rng):
    cfg = PagedConfig(page_size=8, n_kv_heads=2, head_dim=16, hot_pages=2)
    cache = PagedKVCache(cfg)
    cache.start(0)
    s, h = 37, 4
    ks = rng.standard_normal((s, 2, 16)).astype(np.float32)
    vs = rng.standard_normal((s, 2, 16)).astype(np.float32)
    for t in range(s):
        cache.append(0, jnp.asarray(ks[t]), jnp.asarray(vs[t]))
    q = jnp.asarray(rng.standard_normal((h, 16)), jnp.float32)
    out = cache.attend(0, q)
    # dense reference
    qg = np.asarray(q).reshape(2, 2, 16)
    sc = np.einsum("kgd,skd->kgs", qg, ks) / np.sqrt(16)
    w = np.exp(sc - sc.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    ref = np.einsum("kgs,skd->kgd", w, vs).reshape(h, 16)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_paged_kv_spills_cold_pages(rng):
    cfg = PagedConfig(page_size=4, n_kv_heads=1, head_dim=8, hot_pages=2)
    store = TieredStore()
    cache = PagedKVCache(cfg, store)
    cache.start(0)
    for t in range(20):   # 5 pages; only 2 may stay hot
        cache.append(0, jnp.zeros((1, 8)), jnp.zeros((1, 8)))
    tiers = [store.tier_of(nm) for nm in cache._tables[0]]
    assert tiers.count("host") >= 3
    store.reset_stats()
    cache.gather(0)       # reading the full context hits the cold tier
    assert store.stats.host_bytes_read > 0
