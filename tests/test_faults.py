"""Fault-tolerant solves: seeded fault injection, retry/backoff, and the
crash/resume kill matrix.

Layer coverage:
  * FaultPlan / with_retries unit semantics (deterministic schedules,
    transient-vs-final classification, exhaustion context);
  * SAFS hardening under injected faults — transient EIO absorbed by
    bounded retry with the retries reconciling between `stats_dict()`
    and `safs.retry` trace events, persistent EIO surfacing a typed
    `SafsIOError`, short reads exercising the continuation loop,
    write-behind retire retries, prefetch-worker retries;
  * checkpoint-suspend/resume — in-RAM preemption suspend for both
    methods, and the KILL MATRIX: a seeded `CrashPoint` at every crash
    class (journal commit, write-behind retire, checkpoint save, restart
    boundary) × {eigsh, lobpcg}, resume from the surviving checkpoint,
    final spectrum matching the uninterrupted solve at rtol 1e-5 with at
    most one extra restart.
"""
import os

import numpy as np
import pytest

from repro.core import GraphOperator, TieredStore
from repro.core.solver import solve
from repro.ckpt.solver import CheckpointPolicy, SolveSuspended
from repro.graphs import pack_tiles, rmat_graph, normalized_adjacency
from repro.obs import trace as obs_trace
from repro.safs import WriteBehindError
from repro.safs.faults import (CrashPoint, FaultPlan, FaultRule,
                               RetryPolicy, SafsIOError, TransientIOError,
                               is_transient, with_retries)

# fast backoff for tests — same exhaustion semantics, ~zero sleeping
FAST_RETRY = RetryPolicy(max_attempts=3, base_delay=1e-4, max_delay=1e-3)


# --------------------------------------------------------------- fault plan
def test_fault_rule_schedule_at_times():
    plan = FaultPlan([FaultRule(site="pread", kind="eio", at=2, times=2)])
    assert plan.check("pread") is None                     # hit 1
    for _ in range(2):                                     # hits 2, 3
        with pytest.raises(TransientIOError):
            plan.check("pread")
    assert plan.check("pread") is None                     # hit 4
    assert plan.hits("pread") == 4
    assert len(plan.fired(kind="eio")) == 2


def test_fault_rule_glob_sites_and_files():
    plan = FaultPlan([FaultRule(site="journal.*", kind="crash",
                                file_glob="x.pages")])
    assert plan.check("journal.commit", file="/tmp/y.pages") is None
    with pytest.raises(CrashPoint):
        plan.check("journal.precommit", file="/tmp/x.pages")
    assert plan.fired(site="journal.precommit", kind="crash")


def test_fault_rule_prob_is_seeded():
    def fires(seed):
        plan = FaultPlan([FaultRule(site="pread", kind="eio", prob=0.5)],
                         seed=seed)
        out = []
        for i in range(20):
            try:
                plan.check("pread")
                out.append(False)
            except TransientIOError:
                out.append(True)
        return out
    assert fires(7) == fires(7)          # deterministic under one seed
    assert fires(7) != fires(8)          # and actually seed-dependent


def test_fault_rule_short_read_and_latency():
    plan = FaultPlan([FaultRule(site="pread", kind="short_read"),
                      FaultRule(site="pread", kind="latency", delay=0.0)])
    assert plan.check("pread") == "short_read"
    assert plan.check("pread") is None


def test_fault_rule_unknown_kind_rejected():
    with pytest.raises(ValueError):
        FaultRule(site="pread", kind="disk_on_fire")


# ------------------------------------------------------------ with_retries
def test_with_retries_absorbs_transients_and_reports():
    calls, seen = [0], []
    def fn():
        calls[0] += 1
        if calls[0] < 3:
            raise TransientIOError("flaky")
        return "ok"
    out = with_retries(fn, FAST_RETRY, site="pread", file="f", page=7,
                       on_retry=lambda **kw: seen.append(kw))
    assert out == "ok" and calls[0] == 3
    assert [s["attempt"] for s in seen] == [1, 2]
    assert all(s["site"] == "pread" and s["page"] == 7 for s in seen)


def test_with_retries_exhaustion_carries_context():
    def fn():
        raise TransientIOError("always")
    with pytest.raises(SafsIOError) as ei:
        with_retries(fn, FAST_RETRY, site="pwritev", file="f.pages", page=3)
    e = ei.value
    assert (e.site, e.file, e.page, e.attempts) == ("pwritev", "f.pages",
                                                    3, 3)
    assert isinstance(e.__cause__, TransientIOError)
    assert not is_transient(e)          # exhausted errors are final
    for field in ("site=pwritev", "page=3", "attempts=3"):
        assert field in str(e)


def test_with_retries_passes_final_errors_through():
    def fn():
        raise ValueError("not io")
    with pytest.raises(ValueError):
        with_retries(fn, FAST_RETRY, site="pread")
    def crash():
        raise CrashPoint("kill")
    with pytest.raises(CrashPoint):     # crashes are never retried
        with_retries(crash, FAST_RETRY, site="pread")


def test_with_retries_none_policy_single_attempt():
    calls = [0]
    def fn():
        calls[0] += 1
        raise TransientIOError("x")
    with pytest.raises(TransientIOError):
        with_retries(fn, None, site="pread")
    assert calls[0] == 1


# -------------------------------------------------------- prefetch retries
def test_prefetcher_retries_transient_reader():
    from repro.safs.prefetch import Prefetcher
    calls, hooks = [0], []
    def reader(data_id):
        calls[0] += 1
        if calls[0] == 1:
            raise TransientIOError("first fill flaky")
        return 64
    p = Prefetcher(reader, io_workers=1, retries=2,
                   on_retry=lambda **kw: hooks.append(kw))
    try:
        p.schedule(["f"])
        p.wait("f")
        assert calls[0] == 2
        assert p.stats()["read_retries"] == 1
        assert hooks and hooks[0]["site"] == "prefetch"
    finally:
        p.close()


def test_prefetcher_gives_up_on_final_error():
    from repro.safs.prefetch import PrefetchError, Prefetcher
    def reader(data_id):
        raise ValueError("not transient")
    p = Prefetcher(reader, io_workers=1, retries=3)
    try:
        p.schedule(["f"])
        with pytest.raises(PrefetchError):
            p.wait("f")
        assert p.stats()["read_retries"] == 0
    finally:
        p.close()


# ------------------------------------------------------------ safs hardening
def _mk_backend(root, plan, *, retry=FAST_RETRY, **opts):
    from repro.safs.backend import SafsBackend
    opts.setdefault("cache_bytes", 1 << 20)
    opts.setdefault("enable_prefetch", False)
    return SafsBackend(root, faults=plan, retry=retry, **opts)


@pytest.mark.disk
def test_pread_transient_eio_absorbed_and_counted(disk_tmp):
    plan = FaultPlan([FaultRule(site="pread", kind="eio", at=1, times=2)])
    b = _mk_backend(os.path.join(disk_tmp, "s"), plan, write_behind=False)
    a = np.arange(4096, dtype=np.float32).reshape(64, 64)
    tracer = obs_trace.Tracer()
    with obs_trace.tracing(tracer):
        b.store("x", a)
        b.flush()
        b.cache.invalidate("x", drop_dirty=True)
        got = b.load("x")
    np.testing.assert_array_equal(got, a)
    events = [r for r in tracer.records() if r.get("name") == "safs.retry"]
    assert b.stats.retries == 2 == len(events)
    assert b.stats_dict()["io"]["retries"] == 2
    assert all(e["args"]["site"] == "pread" for e in events)
    b.close()


@pytest.mark.disk
def test_pread_exhaustion_raises_typed_error(disk_tmp):
    plan = FaultPlan([FaultRule(site="pread", kind="eio", times=None)])
    b = _mk_backend(os.path.join(disk_tmp, "s"), plan, write_behind=False)
    a = np.zeros((64, 64), np.float32)
    b.store("x", a)
    b.flush()
    b.cache.invalidate("x", drop_dirty=True)
    with pytest.raises(SafsIOError) as ei:
        b.load("x")
    assert ei.value.site == "pread"
    assert ei.value.attempts == FAST_RETRY.max_attempts
    assert ei.value.file and ei.value.file.endswith(".pages")
    assert ei.value.page is not None
    # the absorbed retries before exhaustion are still counted
    assert b.stats.retries == FAST_RETRY.max_attempts - 1


@pytest.mark.disk
def test_short_read_injection_hits_continuation_loop(disk_tmp):
    plan = FaultPlan([FaultRule(site="pread", kind="short_read")])
    b = _mk_backend(os.path.join(disk_tmp, "s"), plan, write_behind=False)
    a = np.arange(32768, dtype=np.float32)      # many pages in one run
    b.store("y", a)
    b.flush()
    b.cache.invalidate("y", drop_dirty=True)
    np.testing.assert_array_equal(b.load("y"), a)
    assert plan.fired(kind="short_read")
    b.close()


@pytest.mark.disk
def test_pwritev_transient_eio_absorbed(disk_tmp):
    plan = FaultPlan([FaultRule(site="pwritev", kind="eio", at=1, times=1)])
    b = _mk_backend(os.path.join(disk_tmp, "s"), plan, write_behind=False)
    a = np.arange(4096, dtype=np.float32)
    b.store("x", a)
    b.flush()
    b.cache.invalidate("x", drop_dirty=True)
    np.testing.assert_array_equal(b.load("x"), a)
    assert b.stats.retries >= 1
    b.close()


@pytest.mark.disk
def test_wb_retire_retries_then_exhausts(disk_tmp):
    # one transient: absorbed, batch retires
    plan = FaultPlan([FaultRule(site="wb.retire", kind="eio", times=1)])
    b = _mk_backend(os.path.join(disk_tmp, "a"), plan, write_behind=True)
    a = np.arange(4096, dtype=np.float32)
    b.store("x", a)
    b.flush()
    assert b.writebehind.stats_dict()["retries"] == 1
    assert b.stats.retries == 1                 # backend counter mirrors
    b.cache.invalidate("x", drop_dirty=True)
    np.testing.assert_array_equal(b.load("x"), a)
    b.close()

    # persistent: exhausts into SafsIOError, surfaces as WriteBehindError
    # (with the typed error chained) at the drain barrier
    plan2 = FaultPlan([FaultRule(site="wb.retire", kind="eio", times=None)])
    b2 = _mk_backend(os.path.join(disk_tmp, "b"), plan2, write_behind=True)
    b2.store("x", a)
    with pytest.raises(WriteBehindError) as ei:
        b2.flush()
    assert isinstance(ei.value.__cause__, SafsIOError)
    assert ei.value.__cause__.site == "wb.retire"


@pytest.mark.disk
def test_journal_crash_recovers_on_reopen(disk_tmp):
    """CrashPoint at journal.commit = the journal is durable but the in-
    place patch never ran — reopen must replay it (PR 4 contract, now
    drivable from a FaultPlan instead of the ad-hoc crash hooks)."""
    root = os.path.join(disk_tmp, "s")
    plan = FaultPlan([FaultRule(site="journal.commit", kind="crash")])
    b = _mk_backend(root, plan, write_behind=False)
    a = np.arange(4096, dtype=np.float32)
    b.store("z", a)
    with pytest.raises(CrashPoint):
        b.flush()
    b2 = _mk_backend(root, None, write_behind=False)
    np.testing.assert_array_equal(b2.load("z"), a)
    b2.close()


# ------------------------------------------------- solves under fault plans
def _small_graph_op():
    n = 400
    r, c, v = rmat_graph(n, 4000, seed=5, symmetric=True)
    r, c, v = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    return n, tm


def _safs_store(root, *, plan=None, retry=FAST_RETRY, write_behind=True,
                cache_bytes=1 << 18, **opts):
    return TieredStore(backend="safs", backend_opts={
        "root": root, "cache_bytes": cache_bytes,
        "write_behind": write_behind, "faults": plan, "retry": retry,
        **opts})


@pytest.mark.disk
def test_transient_fault_solve_completes_with_exact_accounting(disk_tmp):
    """A solve through a flaky 'device' (scheduled EIO bursts on reads
    AND writes) must converge to the clean spectrum, absorb every fault
    as counted retries (stats ↔ trace reconciliation), and keep the
    byte accounting identical to the fault-free run — failed attempts
    never double-count bytes."""
    n, tm = _small_graph_op()

    def run(plan, trace=None):
        # synchronous writes + no readahead: the pread/pwritev hit order
        # is then deterministic, so the scheduled offsets below always
        # land and the counters can be compared exactly; the tiny device
        # budget + page cache force the subspace through real disk I/O
        # (~300 pread / ~200 pwritev chunks over this solve)
        store = TieredStore(
            device_budget_bytes=2 * n * 4 * 4, backend="safs",
            backend_opts={"root": os.path.join(disk_tmp, f"r{id(plan)}"),
                          "cache_bytes": 1 << 14, "write_behind": False,
                          "enable_prefetch": False, "faults": plan,
                          "retry": FAST_RETRY})
        res = solve(GraphOperator(tm, impl="ref"), 4, method="krylov_schur",
                    tol=1e-6, max_iters=100, impl="ref", store=store,
                    trace=trace)
        return res, store

    clean, clean_store = run(None)
    plan = FaultPlan([FaultRule(site="pread", kind="eio", at=3, times=2),
                      FaultRule(site="pread", kind="eio", at=11, times=1),
                      FaultRule(site="pwritev", kind="eio", at=5, times=2)])
    tracer = obs_trace.Tracer()
    faulty, faulty_store = run(plan, trace=tracer)

    assert clean.converged and faulty.converged
    np.testing.assert_allclose(faulty.eigenvalues, clean.eigenvalues,
                               rtol=1e-5)
    phys = faulty_store.backend.stats_dict()["io"]
    events = [r for r in tracer.records() if r.get("name") == "safs.retry"]
    assert phys["retries"] == 5 == len(events)   # all scheduled faults hit
    # byte-exactness: logical AND physical traffic identical to fault-free
    clean_phys = clean_store.backend.stats_dict()["io"]
    for k in ("host_bytes_read", "host_bytes_written"):
        assert phys[k] == clean_phys[k], k
        assert faulty.io_stats[k] == clean.io_stats[k], k


# -------------------------------------------------------- suspend / resume
class _Guard:
    """Stand-in for ft.PreemptionGuard with a test-armed flag."""
    def __init__(self, after):
        self.after = after
        self.n = 0
        self.armed = False
    def requested(self):
        return self.armed
    def cb(self, step, theta, res):
        self.n += 1
        if self.n == self.after:
            self.armed = True


@pytest.mark.parametrize("method,nev,kw", [
    ("krylov_schur", 4, {"tol": 1e-6}),
    ("lobpcg", 4, {"tol": 1e-5, "seed": 3}),
])
def test_preemption_suspend_resume_ram(tmp_path, method, nev, kw):
    """In-RAM backend: guard fires mid-solve → SolveSuspended after the
    boundary checkpoint commits → resumed solve converges to the clean
    spectrum (bit-identical continuation) with ≤ 1 extra step."""
    _n, tm = _small_graph_op()
    def op():
        return GraphOperator(tm, impl="ref")
    ref = solve(op(), nev, method=method, max_iters=100, impl="ref", **kw)
    assert ref.converged

    g = _Guard(after=2)
    root = str(tmp_path / "ck")
    with pytest.raises(SolveSuspended) as ei:
        solve(op(), nev, method=method, max_iters=100, impl="ref",
              checkpoint=CheckpointPolicy(root=root, every_restarts=1,
                                          guard=g),
              callback=g.cb, **kw)
    assert ei.value.root == root

    res = solve(op(), nev, method=method, max_iters=100, impl="ref",
                resume=root, **kw)
    assert res.converged
    assert res.resumed_step == ei.value.step
    np.testing.assert_allclose(np.sort(res.eigenvalues),
                               np.sort(ref.eigenvalues), rtol=1e-5)
    assert res.n_restarts <= ref.n_restarts + 1


def test_resume_rejects_other_solve_shape(tmp_path):
    _n, tm = _small_graph_op()
    root = str(tmp_path / "ck")
    g = _Guard(after=1)
    with pytest.raises(SolveSuspended):
        solve(GraphOperator(tm, impl="ref"), 4, method="krylov_schur",
              tol=1e-6, max_iters=100, impl="ref", callback=g.cb,
              checkpoint=CheckpointPolicy(root=root, guard=g))
    with pytest.raises(ValueError, match="params mismatch"):
        solve(GraphOperator(tm, impl="ref"), 5, method="krylov_schur",
              tol=1e-6, max_iters=100, impl="ref", resume=root)
    with pytest.raises(ValueError, match="method"):
        solve(GraphOperator(tm, impl="ref"), 4, method="lobpcg",
              tol=1e-6, max_iters=100, impl="ref", resume=root)


def test_checkpoint_unsupported_method_rejected():
    _n, tm = _small_graph_op()
    with pytest.raises(ValueError, match="checkpoint/resume"):
        solve(GraphOperator(tm, impl="ref"), 4, method="lanczos",
              checkpoint=CheckpointPolicy(root="/nonexistent"))


def test_resume_from_empty_root_starts_fresh(tmp_path):
    """Crash before the first snapshot: resume root exists but holds no
    committed checkpoint — the solve silently starts from scratch."""
    _n, tm = _small_graph_op()
    ref = solve(GraphOperator(tm, impl="ref"), 4, method="krylov_schur",
                tol=1e-6, max_iters=100, impl="ref")
    res = solve(GraphOperator(tm, impl="ref"), 4, method="krylov_schur",
                tol=1e-6, max_iters=100, impl="ref",
                resume=str(tmp_path / "never_written"))
    assert res.resumed_step is None
    np.testing.assert_allclose(res.eigenvalues, ref.eigenvalues, rtol=1e-5)


# ------------------------------------------------------------- kill matrix
# Crash classes: every site is hit well after several checkpoints have
# committed (the probe counts for this problem size: journal.commit ≈ 2
# per eigsh restart / 6 per lobpcg iteration, wb.retire similar,
# solve.restart / ckpt.save once per boundary) and well before
# convergence (~48 boundaries).
_CRASH_SCENARIOS = [
    ("journal.commit", dict(at=30), {"write_behind": False}),
    ("wb.retire", dict(at=30), {"write_behind": True}),
    ("ckpt.save", dict(at=10), {"write_behind": True}),
    ("solve.restart", dict(at=10), {"write_behind": True}),
]
_METHODS = [("krylov_schur", 4, {"tol": 1e-6}),
            ("lobpcg", 4, {"tol": 1e-5, "seed": 3})]


@pytest.mark.disk
@pytest.mark.parametrize("site,sched,bopts", _CRASH_SCENARIOS,
                         ids=[s[0] for s in _CRASH_SCENARIOS])
@pytest.mark.parametrize("method,nev,kw", _METHODS,
                         ids=[m[0] for m in _METHODS])
def test_kill_matrix_crash_anywhere_resume_matches(disk_tmp, site, sched,
                                                   bopts, method, nev, kw):
    """THE headline guarantee: inject a hard CrashPoint at any I/O or
    checkpoint boundary mid-solve, abandon the wreck, resume from the
    surviving checkpoint into a FRESH safs root — the final spectrum
    matches the uninterrupted solve at rtol 1e-5 and the resumed run pays
    at most one extra restart."""
    _n, tm = _small_graph_op()
    def op():
        return GraphOperator(tm, impl="ref")
    ref = solve(op(), nev, method=method, max_iters=100, impl="ref",
                store=_safs_store(os.path.join(disk_tmp, "ref"), **bopts),
                **kw)
    assert ref.converged

    ck_root = os.path.join(disk_tmp, "ck")
    plan = FaultPlan([FaultRule(site=site, kind="crash", **sched)])
    crash_store = _safs_store(os.path.join(disk_tmp, "crash"), plan=plan,
                              **bopts)
    with pytest.raises((CrashPoint, WriteBehindError, SafsIOError)):
        # the wb-thread CrashPoint surfaces as WriteBehindError at the
        # next drain barrier (checkpoint flush); foreground sites raise
        # CrashPoint directly
        solve(op(), nev, method=method, max_iters=100, impl="ref",
              store=crash_store,
              checkpoint=CheckpointPolicy(root=ck_root, every_restarts=1),
              **kw)
    assert plan.fired(kind="crash"), "scheduled crash never fired"

    # resume into a fresh store: the crashed root is dead hardware
    resumed = solve(op(), nev, method=method, max_iters=100, impl="ref",
                    store=_safs_store(os.path.join(disk_tmp, "fresh"),
                                      **bopts),
                    resume=ck_root, **kw)
    assert resumed.converged
    assert resumed.resumed_step is not None, \
        "crash landed before any committed checkpoint — tune the schedule"
    np.testing.assert_allclose(np.sort(resumed.eigenvalues),
                               np.sort(ref.eigenvalues), rtol=1e-5)
    assert resumed.n_restarts <= ref.n_restarts + 1


@pytest.mark.disk
def test_ckpt_save_crash_leaves_previous_checkpoint_usable(disk_tmp):
    """The crash window between the page snapshot and the state commit:
    the orphaned page snapshot is skipped and the previous committed
    checkpoint resumes — directly, without a full solve around it."""
    from repro.ckpt import checkpoint as ck
    _n, tm = _small_graph_op()
    ck_root = os.path.join(disk_tmp, "ck")
    plan = FaultPlan([FaultRule(site="ckpt.save", kind="crash", at=3)])
    st = _safs_store(os.path.join(disk_tmp, "s"), plan=plan)
    with pytest.raises(CrashPoint):
        solve(GraphOperator(tm, impl="ref"), 4, method="krylov_schur",
              tol=1e-6, max_iters=100, impl="ref", store=st,
              checkpoint=CheckpointPolicy(root=ck_root, every_restarts=1))
    state_steps = ck.valid_steps(os.path.join(ck_root, "state"))
    pages_steps = ck.valid_steps(os.path.join(ck_root, "pages"))
    assert state_steps == [1, 2]        # third state commit never happened
    assert 3 in pages_steps             # ...but its page half exists
    resumed = solve(GraphOperator(tm, impl="ref"), 4,
                    method="krylov_schur", tol=1e-6, max_iters=100,
                    impl="ref",
                    store=_safs_store(os.path.join(disk_tmp, "f")),
                    resume=ck_root)
    assert resumed.resumed_step == 2    # orphan at 3 skipped
    assert resumed.converged


# ----------------------------------------------------- coordinator hardening
def test_coordinator_tolerates_corrupt_heartbeat(tmp_path):
    """A node killed mid-heartbeat-write leaves a truncated/empty JSON
    file. That is a dead member, not a coordinator crash: live_members
    must skip it (and junk like a wrong-schema or non-numeric file)
    without raising, and generation() must see the membership shrink."""
    import unittest.mock as mock
    from repro.ft import Coordinator
    c = Coordinator(str(tmp_path), timeout=10.0)
    c.heartbeat(0, now=100.0)
    c.heartbeat(1, now=100.0)
    with mock.patch("time.time", return_value=101.0):
        g1, m1 = c.generation()
    assert m1 == [0, 1]
    hb = tmp_path / "hb"
    (hb / "1.json").write_text('{"t": 1')          # truncated mid-write
    (hb / "2.json").write_text("")                  # zero-byte create
    (hb / "3.json").write_text('{"x": 5}')          # wrong schema
    (hb / "nope.json").write_text('{"t": 101.0}')   # unparseable member id
    with mock.patch("time.time", return_value=102.0):
        g2, m2 = c.generation()
    assert m2 == [0]                   # corrupt heartbeats are dead members
    assert g2 == g1 + 1
