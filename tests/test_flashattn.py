"""Flash-attention Pallas kernel: shape/dtype/block sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn import flash_attention, flash_attention_single
from repro.kernels.flashattn_ref import attention_ref


@pytest.mark.parametrize("sq,sk,d,bq,bk,causal", [
    (128, 128, 32, 32, 32, True),
    (256, 256, 16, 64, 64, True),
    (64, 128, 32, 32, 32, False),
    (128, 128, 64, 128, 32, True),
    (96, 96, 16, 32, 48, True),
])
def test_flash_vs_ref(sq, sk, d, bq, bk, causal, rng):
    q = jnp.asarray(rng.standard_normal((sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((sk, d)), jnp.float32)
    out = flash_attention_single(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    out = flash_attention_single(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=3e-2, atol=3e-2)


def test_flash_batched_heads(rng):
    b, h, s, d = 2, 3, 64, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    for bi in range(b):
        for hi in range(h):
            ref = attention_ref(q[bi, hi], k[bi, hi], v[bi, hi],
                                causal=True)
            np.testing.assert_allclose(np.asarray(out[bi, hi]),
                                       np.asarray(ref), rtol=2e-5,
                                       atol=2e-5)
