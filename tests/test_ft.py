"""Fault-tolerance machinery: straggler decisions, coordinator membership,
preemption guard, gradient compression."""
import signal

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.dist.compress import (int8_dequantize, int8_quantize, topk_init,
                                 topk_compress, topk_decompress)
from repro.ft.coordinator import Coordinator
from repro.ft.preemption import PreemptionGuard
from repro.ft.straggler import StragglerTracker


def test_straggler_detection():
    t = StragglerTracker(min_steps=3)
    for step in range(6):
        for p in range(8):
            t.record(p, 1.0 if p != 5 else 1.5)    # p5 runs 1.5×
    ds = t.decisions()
    assert len(ds) == 1 and ds[0].participant == 5
    assert ds[0].action == "rebalance"


def test_straggler_evict_threshold():
    t = StragglerTracker(min_steps=3)
    for step in range(6):
        for p in range(4):
            t.record(p, 1.0 if p != 2 else 5.0)
    ds = {d.participant: d for d in t.decisions()}
    assert ds[2].action == "evict"


def test_coordinator_generations(tmp_path):
    c = Coordinator(str(tmp_path), timeout=10.0)
    c.heartbeat(0, now=100.0)
    c.heartbeat(1, now=100.0)
    import unittest.mock as mock
    with mock.patch("time.time", return_value=101.0):
        g1, m1 = c.generation()
        assert m1 == [0, 1]
    # node 1 dies (no heartbeat within timeout)
    with mock.patch("time.time", return_value=115.0):
        c.heartbeat(0)
        g2, m2 = c.generation()
    assert m2 == [0] and g2 == g1 + 1


def test_preemption_guard_flag():
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
        assert not g.requested()
        signal.raise_signal(signal.SIGUSR1)
        assert g.requested()


# -------------------------------------------------------- compression
def test_int8_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
    q, s = int8_quantize(x)
    err = np.abs(np.asarray(int8_dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


@given(st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_topk_error_feedback_converges(seed):
    """Error feedback: repeatedly compressing the same gradient transmits
    it fully over time (sum of decompressed ≈ t·g for large t)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    state = topk_init(g)
    acc = np.zeros(64, np.float32)
    t = 24
    for _ in range(t):
        vals, idx, state = topk_compress(g, state, k=8)
        acc += np.asarray(topk_decompress(vals, idx, (64,)))
    np.testing.assert_allclose(acc / t, np.asarray(g), rtol=0.35, atol=0.35)


def test_topk_exact_when_k_full(rng):
    g = jnp.asarray(rng.standard_normal((32,)), jnp.float32)
    vals, idx, state = topk_compress(g, topk_init(g), k=32)
    np.testing.assert_allclose(np.asarray(topk_decompress(vals, idx, (32,))),
                               np.asarray(g), rtol=1e-6)
    assert float(jnp.max(jnp.abs(state.error))) < 1e-6
