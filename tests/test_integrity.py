"""End-to-end data integrity: checksums, corruption repair, self-healing.

The contract under test, from the storage layer up to the serve loop:
**seeded corruption at any read site is never served** — it is either
healed (transient transfer flip / torn-read race), repaired from a
*verified* checkpoint snapshot, or surfaced as a typed error — and every
detection, scrub pass and repair is counted AND trace-announced exactly
once (byte-exact counter ↔ event reconciliation).

Layers:
  * PageFile checksum sidecar — detect at-rest bitflips, heal transient
    transfer flips, persist sums across the journal's crash windows;
  * seeded `bitflip`/`torn_page` FaultRules — persistent medium faults
    detected on the next read, never returned to the caller;
  * the kill matrix — corruption × {steady-state read, journal replay,
    checkpoint resume, scrub} (satellite: detection-never-served);
  * scrub + repair_from_checkpoint — quarantine, re-fill from the newest
    snapshot that verifies, byte-identical content after repair;
  * checkpoint fallback — `latest`-step resume skips corrupt/torn
    snapshots down to the next older verified step;
  * serve — corruption recovery bounded by the JobSpec retry budget, the
    watchdog deadline (suspend → abandon), the crashed-worker reap fix,
    and the startup orphan-namespace GC;
  * `RetryPolicy.max_total_sleep` — cumulative backoff capped per op.
"""
import json
import os
import threading
import time
import types

import numpy as np
import pytest

from repro.core import GraphOperator, TieredStore
from repro.core.solver import solve
from repro.ckpt import checkpoint as ck
from repro.ckpt.solver import CheckpointPolicy
from repro.graphs import normalized_adjacency, pack_tiles, rmat_graph
from repro.obs import trace as obs_trace
from repro.obs import report as obs_report
from repro.safs import (CorruptPageError, FaultPlan, FaultRule, PageFile,
                        RetryPolicy, SafsBackend, Scrubber, TransientIOError,
                        flip_bit, newest_verified_step, page_crc,
                        repair_from_checkpoint, with_retries)
from repro.safs.scrub import main as scrub_main

FAST_RETRY = RetryPolicy(max_attempts=3, base_delay=1e-4, max_delay=1e-3)


# ---------------------------------------------------------------- helpers
def _tracer():
    return obs_trace.install(obs_trace.Tracer())


def _events(tr, name):
    return [r for r in tr.records()
            if r["type"] == "event" and r["name"] == name]


def _reconciled(tr, backend):
    """crc_failures ↔ safs.corrupt, scrub_passes ↔ safs.scrub,
    pages_repaired ↔ safs.repair must pair EXACTLY."""
    integ = backend.stats_dict()["integrity"]
    assert integ["crc_failures"] == len(_events(tr, "safs.corrupt"))
    assert integ["scrub_passes"] == len(_events(tr, "safs.scrub"))
    assert integ["pages_repaired"] == len(_events(tr, "safs.repair"))
    return integ


def _backend(root, **kw):
    kw.setdefault("write_behind", False)
    kw.setdefault("retry", FAST_RETRY)
    return SafsBackend(root, **kw)


def _fill(backend, name="a", n=3000, seed=0):
    arr = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    backend.store(name, arr)
    backend.flush()
    return arr


def _small_graph_op():
    n = 400
    r, c, v = rmat_graph(n, 4000, seed=5, symmetric=True)
    r, c, v = normalized_adjacency(n, r, c, v)
    return GraphOperator(pack_tiles(n, n, r, c, v, block_shape=(64, 64),
                                    min_block_nnz=4), impl="ref")


def _safs_store(root, *, plan=None, **opts):
    return TieredStore(backend="safs", backend_opts={
        "root": root, "cache_bytes": 1 << 18, "write_behind": False,
        "faults": plan, "retry": FAST_RETRY, **opts})


# ====================================================== checksum sidecar
@pytest.mark.disk
def test_sums_sidecar_roundtrip_and_legacy_adopt(disk_tmp):
    path = os.path.join(disk_tmp, "a.pages")
    arr = np.arange(4000, dtype=np.float32)
    pf = PageFile(path, shape=arr.shape, dtype="float32")
    pf.write_pages(pf.split(arr))
    pf.close()
    assert os.path.exists(path + ".sums")
    # cold reopen loads the sidecar and every page verifies
    pf2 = PageFile(path)
    assert pf2.verify_pages() == []
    np.testing.assert_array_equal(
        pf2.assemble(pf2.read_pages_batch(pf2.page_indices())), arr)
    pf2.close()
    # legacy store (no sidecar): adopt current content, then verify
    os.unlink(path + ".sums")
    pf3 = PageFile(path)
    assert os.path.exists(path + ".sums")
    np.testing.assert_array_equal(
        pf3.assemble(pf3.read_pages_batch(pf3.page_indices())), arr)
    pf3.delete()
    assert not os.path.exists(path + ".sums")


@pytest.mark.disk
def test_journal_replay_rederives_sums(disk_tmp):
    """Crash mid-patch AFTER the journal committed: replay rewrites the
    pages AND re-derives their checksums — the recovered file verifies
    clean and serves the NEW content (the sidecar's crash window is
    exactly the journal's replay window)."""
    from repro.safs import CrashPoint
    path = os.path.join(disk_tmp, "j.pages")
    old = np.zeros((64, 64), np.float32)
    new = np.full((64, 64), 7.0, np.float32)
    pf = PageFile(path, shape=old.shape, dtype="float32")
    pf.write_pages(pf.split(old))
    with pytest.raises(CrashPoint):
        pf.write_pages(pf.split(new), crash_after_pages=1)
    pf.close()
    pf2 = PageFile(path)           # recovery replays, sums re-derived
    assert pf2.verify_pages() == []
    got = pf2.assemble(pf2.read_pages_batch(pf2.page_indices()))
    np.testing.assert_array_equal(got, new)
    pf2.close()


# ================================ kill matrix: corruption at every read site
@pytest.mark.disk
def test_steady_read_bitflip_detected_never_served(disk_tmp):
    """At-rest flip under a cold cache: the backend read path raises
    typed instead of returning rotten bytes, and the detection is
    counted + announced exactly once."""
    tr = _tracer()
    try:
        root = os.path.join(disk_tmp, "pages")
        b = _backend(root)
        _fill(b, "a")
        b.close()
        flip_bit(os.path.join(root, "a.pages"), 1)
        b2 = _backend(root)        # cold cache: reads hit the medium
        with pytest.raises(CorruptPageError) as ei:
            b2.load("a")
        assert ei.value.site == "pread" and ei.value.page == 1
        assert b2.quarantined() == [("a", 1)]
        integ = _reconciled(tr, b2)
        assert integ["crc_failures"] == 1
        b2.close()
    finally:
        obs_trace.uninstall()


@pytest.mark.disk
def test_transient_transfer_bitflip_heals(disk_tmp):
    """A single-shot seeded transfer flip (bad DMA, not bad medium) is
    healed by re-read arbitration: correct data served, crc_retries
    counted, NO corruption event."""
    tr = _tracer()
    try:
        root = os.path.join(disk_tmp, "pages")
        arr = _fill(_b0 := _backend(root), "a")
        _b0.close()
        plan = FaultPlan([FaultRule(site="pread", kind="bitflip", times=1)])
        b = _backend(root, faults=plan)
        np.testing.assert_array_equal(b.load("a"), arr)   # served clean
        integ = b.stats_dict()["integrity"]
        assert integ["crc_retries"] >= 1
        assert integ["crc_failures"] == 0
        assert _events(tr, "safs.corrupt") == []
        b.close()
    finally:
        obs_trace.uninstall()


@pytest.mark.disk
@pytest.mark.parametrize("kind", ["bitflip", "torn_page"])
def test_persistent_write_fault_detected_on_read(disk_tmp, kind):
    """Seeded medium corruption at the pwritev site (flipped bit /
    half-persisted page): the NEXT cold read detects it — the write
    itself cannot (the rot is on the platter), but the checksum block
    carries the intended content's CRC."""
    tr = _tracer()
    try:
        root = os.path.join(disk_tmp, "pages")
        plan = FaultPlan([FaultRule(site="pwritev", kind=kind, at=1,
                                    times=1)])
        b = _backend(root, faults=plan)
        _fill(b, "a")
        b.close()                  # drops the clean cached copies
        b2 = _backend(root)
        with pytest.raises(CorruptPageError):
            b2.load("a")
        integ = _reconciled(tr, b2)
        assert integ["crc_failures"] >= 1
        b2.close()
    finally:
        obs_trace.uninstall()


@pytest.mark.disk
def test_scrub_detects_repairs_and_reconciles(disk_tmp):
    """Scrub site of the matrix: at-rest flip under a page nobody reads →
    the paced pass (on the prefetch pool) quarantines it, repair re-fills
    byte-identically from the verified snapshot, a second pass is clean,
    and counters reconcile with events to the unit."""
    tr = _tracer()
    try:
        root = os.path.join(disk_tmp, "pages")
        ckroot = os.path.join(disk_tmp, "ck")
        b = _backend(root, enable_prefetch=True)
        arr = _fill(b, "a")
        st = types.SimpleNamespace(backend=b)
        ck.save_safs(ckroot, 1, st, extra={})
        flip_bit(b._files["a"].path, 1)

        sc = Scrubber(b, use_pool=True)
        s1 = sc.run_once()
        assert s1["corrupt"] == [("a", 1)]
        assert b.quarantined() == [("a", 1)]
        assert b.prefetcher.stats()["tasks_run"] >= 1   # pool, not ad-hoc

        rep = repair_from_checkpoint(b, ckroot)
        assert rep["repaired"] == [("a", 1)] and not rep["unrepaired"]
        assert sc.run_once()["corrupt"] == [] and not b.quarantined()
        np.testing.assert_array_equal(b.load("a"), arr)  # byte-identical

        integ = _reconciled(tr, b)
        assert integ["scrub_passes"] == 2
        assert integ["scrub_corrupt"] == integ["crc_failures"] == 1
        assert integ["pages_repaired"] == 1
        b.close()
    finally:
        obs_trace.uninstall()


@pytest.mark.disk
def test_repair_without_covering_snapshot_stays_quarantined(disk_tmp):
    root = os.path.join(disk_tmp, "pages")
    b = _backend(root)
    _fill(b, "a")
    flip_bit(b._files["a"].path, 0)
    assert b.scrub_file("a") == [0]
    rep = repair_from_checkpoint(b, os.path.join(disk_tmp, "no_ck"))
    assert rep["step"] is None and rep["unrepaired"] == [("a", 0)]
    assert b.quarantined() == [("a", 0)]       # never silently cleared
    b.close()


@pytest.mark.disk
def test_ckpt_resume_falls_back_past_corrupt_snapshot(disk_tmp):
    """Checkpoint-resume site of the matrix: the newest snapshot is
    corrupt/torn → resume must fall back to the next older step that
    VERIFIES, and the resumed spectrum still matches the uninterrupted
    run at rtol 1e-5."""
    tr = _tracer()
    try:
        ref = solve(_small_graph_op(), 4, method="krylov_schur", tol=1e-6,
                    max_iters=100, impl="ref",
                    store=_safs_store(os.path.join(disk_tmp, "ref")))
        assert ref.converged

        ck_root = os.path.join(disk_tmp, "ck")
        full = solve(_small_graph_op(), 4, method="krylov_schur", tol=1e-6,
                     max_iters=100, impl="ref",
                     store=_safs_store(os.path.join(disk_tmp, "s")),
                     checkpoint=CheckpointPolicy(root=ck_root,
                                                 every_restarts=1, keep=3))
        steps = ck.valid_steps(os.path.join(ck_root, "state"))
        assert len(steps) >= 2, "need two committed steps for the fallback"
        newest = steps[-1]
        snap = os.path.join(ck_root, "pages", f"step_{newest:010d}")
        victim = sorted(f for f in os.listdir(snap)
                        if f.endswith(".pages"))[0]
        flip_bit(os.path.join(snap, victim), 0)
        assert ck.verify_safs_snapshot(snap)    # hash check sees the rot

        resumed = solve(_small_graph_op(), 4, method="krylov_schur",
                        tol=1e-6, max_iters=100, impl="ref",
                        store=_safs_store(os.path.join(disk_tmp, "f")),
                        resume=ck_root)
        assert resumed.resumed_step == steps[-2]      # fell back one step
        assert [e["args"]["step"]
                for e in _events(tr, "ckpt.corrupt_snapshot")] == [newest]
        assert resumed.converged
        np.testing.assert_allclose(np.sort(resumed.eigenvalues),
                                   np.sort(ref.eigenvalues), rtol=1e-5)
        assert resumed.n_restarts <= full.n_restarts + 1
    finally:
        obs_trace.uninstall()


@pytest.mark.disk
def test_restore_safs_refuses_corrupt_snapshot(disk_tmp):
    root = os.path.join(disk_tmp, "pages")
    b = _backend(root)
    _fill(b, "a")
    st = types.SimpleNamespace(backend=b)
    ck.save_safs(os.path.join(disk_tmp, "ck"), 1, st, extra={})
    b.close()
    snap = os.path.join(disk_tmp, "ck", "step_0000000001")
    flip_bit(os.path.join(snap, "a.pages"), 0)
    with pytest.raises(ck.CorruptSnapshotError):
        ck.restore_safs(os.path.join(disk_tmp, "ck"), 1,
                        os.path.join(disk_tmp, "dest"))
    assert newest_verified_step(os.path.join(disk_tmp, "ck")) is None


@pytest.mark.disk
def test_scrub_cli_detect_and_repair(disk_tmp):
    """The tier-1 smoke's tool: one CLI invocation verifies the store at
    rest, repairs from the checkpoint, and exits 0 only when nothing
    stays corrupt."""
    root = os.path.join(disk_tmp, "pages")
    ckroot = os.path.join(disk_tmp, "ck")
    b = _backend(root)
    arr = _fill(b, "a")
    ck.save_safs(ckroot, 1, types.SimpleNamespace(backend=b), extra={})
    b.close()
    flip_bit(os.path.join(root, "a.pages"), 2)
    assert scrub_main([root]) == 1                       # detect only
    assert scrub_main([root, "--repair-from", ckroot]) == 0
    assert scrub_main([root]) == 0                       # now clean
    b2 = _backend(root)
    np.testing.assert_array_equal(b2.load("a"), arr)
    b2.close()


# ======================================== satellite: retry-sleep budget cap
def test_retry_sleep_capped_and_reported():
    policy = RetryPolicy(max_attempts=50, base_delay=0.01, max_delay=10.0,
                         multiplier=2.0, jitter=0.0, max_total_sleep=0.02)
    slept = []

    def boom():
        raise TransientIOError("injected")

    t0 = time.monotonic()
    with pytest.raises(Exception):
        with_retries(boom, policy, site="pread",
                     on_retry=lambda **kw: slept.append(kw["slept_ms"]))
    wall = time.monotonic() - t0
    # cumulative backoff clamped to the budget, not 50 growing sleeps
    assert sum(slept) <= policy.max_total_sleep * 1e3 + 1e-6
    assert wall < 1.0
    assert len(slept) == policy.max_attempts - 1
    assert all(ms >= 0.0 for ms in slept)


@pytest.mark.disk
def test_backend_accounts_retry_sleep_ms(disk_tmp):
    plan = FaultPlan([FaultRule(site="pread", kind="eio", at=1, times=1)])
    b = _backend(os.path.join(disk_tmp, "pages"), faults=plan)
    arr = _fill(b, "a")
    b.cache.invalidate("a", drop_dirty=True)
    np.testing.assert_array_equal(b.load("a"), arr)      # retried through
    io = b.stats_dict()["io"]
    assert io["retries"] >= 1
    assert io["retry_sleep_ms"] > 0.0
    b.close()


# =========================================== satellite: orphan-namespace GC
@pytest.mark.disk
def test_orphan_namespace_gc_on_service_startup(disk_tmp):
    """A serve root reused after a kill: aged per-session subdirs are
    swept at EigenService startup; young ones and live ones survive."""
    from repro.serve import build_service
    root = os.path.join(disk_tmp, "pages")
    b = _backend(root)
    b.store("dead-job::V/b0", np.zeros(600, np.float32))
    b.store("young-job::V/b0", np.zeros(600, np.float32))
    b.flush()
    b.close()
    old = time.time() - 7200
    os.utime(os.path.join(root, "dead-job"), (old, old))

    svc = build_service(backend="safs", root=root, device_budget=4 << 20,
                        orphan_grace_s=3600.0)
    try:
        assert svc.orphans_swept == ["dead-job"]
        assert not os.path.isdir(os.path.join(root, "dead-job"))
        assert os.path.isdir(os.path.join(root, "young-job"))
        assert svc.report()["orphans_swept"] == ["dead-job"]
    finally:
        svc.close()


# ===================================== satellite: crashed-worker accounting
class _CrashingSession:
    """Duck-typed session whose worker thread dies with an escaped
    BaseException — the bug class `_reap` must account as FAILED."""

    def __init__(self, jid):
        self.spec = types.SimpleNamespace(job_id=jid, priority=0,
                                          preemptible=True)
        self.state = "pending"
        self.guard = None
        self.error = None
        self.preemptions = 0

    def mark_queued(self):
        pass

    def mark_dequeued(self):
        pass

    @property
    def can_preempt(self):
        return False

    def progress(self):
        return {"state": self.state}

    def run(self):
        self.state = "running"
        raise KeyboardInterrupt("worker killed mid-solve")


def _mini_sched(**kw):
    from repro.serve import BudgetArbiter, SolveScheduler
    store = TieredStore(device_budget_bytes=8 << 20)
    arb = BudgetArbiter(store, device_budget=8 << 20)
    return SolveScheduler(store, arb, max_concurrent=1,
                          poll_interval=0.002, **kw)


def test_reap_accounts_dead_worker_as_failed():
    """Single-stepped tick(): the dead worker's session surfaces FAILED
    with the traceback in the report, namespace + arbiter released
    exactly once, nothing left running/pending."""
    sched = _mini_sched()
    s = _CrashingSession("boom")
    sched.submit(s)
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline:
        sched.tick()
        if sched.completed:
            break
        time.sleep(0.002)
    assert sched.completed == [s]
    assert s.state == "failed"
    assert "KeyboardInterrupt" in s.error      # full traceback captured
    assert sched.worker_crashes == 1
    assert not sched._running and not sched._pending
    a = sched.arbiter.stats_dict()
    assert a["admits"] == a["releases"] == 1 and not a["live_sessions"]
    assert sched.stats_dict()["worker_crashes"] == 1


# ================================================ tentpole: serve watchdog
class _TimedSession:
    """Duck-typed session with a deadline; `cooperative` decides whether
    the guard's suspend request is honored (graceful) or ignored (hung)."""

    def __init__(self, jid, *, deadline_s, cooperative):
        from repro.serve import PreemptFlag
        self.spec = types.SimpleNamespace(job_id=jid, priority=0,
                                          preemptible=True,
                                          deadline_s=deadline_s)
        self.state = "pending"
        self.guard = PreemptFlag()
        self.error = None
        self.preemptions = 0
        self.wall_s = 0.0
        self.cooperative = cooperative
        self.stop = threading.Event()

    def mark_queued(self):
        pass

    def mark_dequeued(self):
        pass

    @property
    def can_preempt(self):
        return False                 # watchdog only, no priority preempt

    def progress(self):
        return {"state": self.state}

    def run(self):
        self.state = "running"
        while not self.stop.is_set():
            if self.cooperative and self.guard.requested():
                self.state = "suspended"
                return
            time.sleep(0.002)


def test_watchdog_deadline_suspends_cooperative_worker():
    """Past its deadline a cooperative job checkpoints out SUSPENDED and
    is NOT requeued (deadline-expired suspension is terminal), freeing
    the slot and its shares."""
    sched = _mini_sched(deadline_grace_s=5.0)
    s = _TimedSession("slow", deadline_s=0.05, cooperative=True)
    sched.submit(s)
    done = sched.drain()
    assert done == [s] and s.state == "suspended"
    assert sched.timeouts == 1 and sched.abandoned == 0
    assert sched.requeues == 0                 # not resurrected
    a = sched.arbiter.stats_dict()
    assert a["admits"] == a["releases"] == 1


def test_watchdog_abandons_hung_worker():
    """A worker that ignores the suspend request past the grace is
    abandoned: FAILED with a deadline error, shares released exactly
    once, and drain() terminates instead of spinning forever."""
    sched = _mini_sched(deadline_grace_s=0.05)
    hung = _TimedSession("hung", deadline_s=0.05, cooperative=False)
    sched.submit(hung)
    t0 = time.monotonic()
    done = sched.drain()
    assert time.monotonic() - t0 < 10
    assert done == [hung] and hung.state == "failed"
    assert "deadline exceeded" in hung.error
    assert sched.timeouts == 1 and sched.abandoned == 1
    a = sched.arbiter.stats_dict()
    assert a["admits"] == a["releases"] == 1 and not a["live_sessions"]
    hung.stop.set()                            # let the daemon thread die


def test_scheduler_default_deadline_applies_when_spec_has_none():
    sched = _mini_sched(default_deadline_s=0.05, deadline_grace_s=0.05)
    s = _TimedSession("d", deadline_s=None, cooperative=True)
    sched.submit(s)
    sched.drain()
    assert s.state == "suspended" and sched.timeouts == 1


# ====================================== tentpole: session corruption retry
def _corrupting_session(tmp_path, budget, fail_times):
    """Real SolveSession against a RAM store, with build_problem patched
    to raise CorruptPageError the first `fail_times` runs — exercising
    the recovery path without a disk solve."""
    from repro.serve import SolveSession
    from repro.serve.session import JobSpec
    spec = JobSpec("c", kind="eigsh", n=120, nnz=800, nev=2, tol=1e-3,
                   max_iters=20, max_corruption_retries=budget)
    store = TieredStore(device_budget_bytes=8 << 20)
    sess = SolveSession(spec, store, str(tmp_path))
    calls = {"n": 0}
    import repro.serve.session as sess_mod
    real = sess_mod.build_problem

    def flaky(spec_, store_):
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise CorruptPageError(site="pread", file="V/b0", page=3)
        return real(spec_, store_)

    return sess, sess_mod, flaky, real


def test_session_corruption_recovery_within_budget(tmp_path, monkeypatch):
    tr = _tracer()
    try:
        sess, mod, flaky, real = _corrupting_session(tmp_path, 1, 1)
        monkeypatch.setattr(mod, "build_problem", flaky)
        assert sess.run() == "suspended"       # recovery, not failure
        assert sess.corruption_recoveries == 1
        assert sess.preemptions == 0           # distinct counters
        assert len(_events(tr, "serve.corruption_recovery")) == 1
        assert sess.run() == "done"            # requeued run succeeds
        assert sess.resumes == 1               # resumed via ckpt_root
        assert sess.report()["corruption_recoveries"] == 1
    finally:
        obs_trace.uninstall()


def test_session_corruption_budget_exhausted_fails_typed(tmp_path,
                                                         monkeypatch):
    sess, mod, flaky, real = _corrupting_session(tmp_path, 1, 5)
    monkeypatch.setattr(mod, "build_problem", flaky)
    assert sess.run() == "suspended"
    assert sess.run() == "failed"              # budget of 1 exhausted
    assert "CorruptPageError" in sess.error
    sess2, mod2, flaky2, _ = _corrupting_session(tmp_path / "z", 0, 5)
    monkeypatch.setattr(mod2, "build_problem", flaky2)
    assert sess2.run() == "failed"             # zero budget: typed at once
    assert "CorruptPageError" in sess2.error


# ================================== report --validate: integrity reconcile
def _trace_records(integrity, n_corrupt, n_scrub, n_repair):
    recs = [{"type": "meta", "schema": obs_report.SCHEMA, "unit": "us",
             "threads": {}},
            {"type": "span", "name": "pass.subspace", "ts": 0.0,
             "dur": 1.0, "args": {}},
            {"type": "metrics", "name": "solve", "ts": 1.0,
             "data": {"end": {"backend": {"integrity": integrity}}}}]
    for name, n in (("safs.corrupt", n_corrupt), ("safs.scrub", n_scrub),
                    ("safs.repair", n_repair)):
        recs += [{"type": "event", "name": name, "ts": 2.0, "args": {}}
                 for _ in range(n)]
    recs.append({"type": "summary", "spans": 1,
                 "events": n_corrupt + n_scrub + n_repair,
                 "metrics": 1, "dropped": 0})
    return recs


def test_report_validate_integrity_reconciliation():
    integ = {"crc_failures": 2, "scrub_passes": 1, "pages_repaired": 2}
    good = _trace_records(integ, 2, 1, 2)
    assert obs_report.validate(good) == []
    rec = obs_report.integrity_reconcile(good)
    assert rec["exact"] and rec["lossless"]
    bad = _trace_records(integ, 1, 1, 2)       # one detection unannounced
    assert any("integrity accounting mismatch" in p
               for p in obs_report.validate(bad))
    # ram backend (integrity: None) → reconciliation is simply absent
    none = _trace_records(None, 0, 0, 0)
    none[2]["data"]["end"]["backend"]["integrity"] = None
    assert obs_report.integrity_reconcile(none) is None
    assert obs_report.validate(none) == []
