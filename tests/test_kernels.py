"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import rmat_graph, pack_tiles
from repro.graphs.synth import to_dense
from repro.kernels import ops
from repro.kernels.spmm_ref import spmm_ref
from repro.kernels.spmm_tile import spmm_blocksparse


@pytest.mark.parametrize("n,nnz,bm,k", [
    (256, 2000, 16, 4), (512, 4000, 32, 8), (300, 1500, 16, 2),
    (1024, 8000, 64, 1),
])
def test_spmm_kernel_vs_ref(n, nnz, bm, k, rng):
    r, c, v = rmat_graph(n, nnz, seed=n, symmetric=True)
    tm = pack_tiles(n, n, r, c, v, block_shape=(bm, bm), min_block_nnz=1)
    brs = jnp.asarray(ops.block_rows_from_ptr(np.asarray(tm.row_ptr)))
    mask = jnp.asarray(ops.empty_row_mask(np.asarray(tm.row_ptr), bm))
    x = jnp.asarray(rng.standard_normal((tm.shape[1], k)), jnp.float32)
    y_ref = spmm_ref(jnp.asarray(tm.blocks), jnp.asarray(tm.block_cols),
                     brs, tm.n_block_rows, x)
    y_pal = spmm_blocksparse(jnp.asarray(tm.blocks),
                             jnp.asarray(tm.block_cols), brs, x,
                             n_block_rows=tm.n_block_rows, interpret=True)
    y_pal = jnp.where(mask[:, None], y_pal, 0.0)
    y_ref = jnp.where(mask[:, None], y_ref, 0.0)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype, rng):
    n, bm = 256, 16
    r, c, v = rmat_graph(n, 1500, seed=9, symmetric=True)
    tm = pack_tiles(n, n, r, c, v, block_shape=(bm, bm), min_block_nnz=1)
    brs = jnp.asarray(ops.block_rows_from_ptr(np.asarray(tm.row_ptr)))
    x = jnp.asarray(rng.standard_normal((tm.shape[1], 4)), dtype)
    blocks = jnp.asarray(tm.blocks, dtype)
    y_ref = spmm_ref(blocks, jnp.asarray(tm.block_cols), brs,
                     tm.n_block_rows, x)
    y_pal = spmm_blocksparse(blocks, jnp.asarray(tm.block_cols), brs, x,
                             n_block_rows=tm.n_block_rows, interpret=True)
    mask = ops.empty_row_mask(np.asarray(tm.row_ptr), bm)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_pal)[mask], np.asarray(y_ref)[mask],
                               rtol=tol, atol=tol)


def test_spmm_full_hybrid_vs_dense(rng):
    n = 600
    r, c, v = rmat_graph(n, 5000, seed=7, symmetric=True)
    tm = pack_tiles(n, n, r, c, v, block_shape=(16, 16), min_block_nnz=2)
    x = rng.standard_normal((tm.shape[1], 4)).astype(np.float32)
    x[n:] = 0
    for impl in ("ref", "interpret"):
        y = ops.spmm(tm, jnp.asarray(x), impl=impl)
        np.testing.assert_allclose(np.asarray(y)[:n],
                                   to_dense(n, r, c, v) @ x[:n],
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,m,b,ri", [
    (1024, 24, 4, 256), (512, 8, 8, 128), (768, 64, 2, 256), (256, 4, 1, 64),
])
def test_tsgemm_sweep(n, m, b, ri, rng):
    a = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    small = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    c0 = jnp.asarray(rng.standard_normal((n, b)), jnp.float32)
    want = 1.5 * np.asarray(a) @ np.asarray(small) + 0.5 * np.asarray(c0)
    for impl in ("ref", "interpret"):
        out = ops.tsgemm(a, small, alpha=1.5, beta=0.5, c0=c0, impl=impl,
                         row_interval=ri if impl != "ref" else None)
        np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,m,b,ri", [
    (1024, 24, 4, 256), (512, 16, 16, 512), (640, 8, 2, 128),
])
def test_gram_sweep(n, m, b, ri, rng):
    a = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((n, b)), jnp.float32)
    want = 2.0 * np.asarray(a).T @ np.asarray(bb)
    for impl in ("ref", "interpret"):
        out = ops.gram(a, bb, alpha=2.0, impl=impl,
                       row_interval=ri if impl != "ref" else None)
        np.testing.assert_allclose(np.asarray(out), want, rtol=3e-4, atol=3e-4)


def test_pick_row_interval():
    from repro.kernels.ops import _pick_row_interval
    assert _pick_row_interval(1024) == 512
    assert _pick_row_interval(300, cap=128) == 100
    assert 1000 % _pick_row_interval(1000) == 0
