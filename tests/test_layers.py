"""Layer-level correctness: SSD vs naive recurrence, RG-LRU scan vs loop,
chunked attention vs full, grouped MoE vs dense-expert reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod


# ------------------------------------------------------------------ SSD
def _naive_ssm(x, a_dt, b_mat, c_mat):
    """Sequential recurrence oracle: h_t = e^{aΔ} h + Δ-scaled B x."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    state = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, l, h, p))
    for t in range(l):
        da = np.exp(a_dt[:, t])                        # (B,H)
        state = state * da[..., None, None] + np.einsum(
            "bhp,bn->bhpn", x[:, t], b_mat[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, c_mat[:, t])
    return ys, state


@pytest.mark.parametrize("l,chunk", [(16, 4), (24, 8), (13, 8)])
def test_ssd_chunked_vs_naive(l, chunk, rng):
    bsz, h, p, n = 2, 3, 4, 5
    x = rng.standard_normal((bsz, l, h, p)).astype(np.float32)
    a_dt = -np.abs(rng.standard_normal((bsz, l, h))).astype(np.float32) * 0.3
    b_mat = rng.standard_normal((bsz, l, n)).astype(np.float32)
    c_mat = rng.standard_normal((bsz, l, n)).astype(np.float32)
    y, state = ssm_mod._ssd_chunked(jnp.asarray(x), jnp.asarray(a_dt),
                                    jnp.asarray(b_mat), jnp.asarray(c_mat),
                                    chunk)
    y_ref, state_ref = _naive_ssm(x, a_dt, b_mat, c_mat)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(state), state_ref,
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunk_size_invariance(rng):
    bsz, l, h, p, n = 1, 32, 2, 4, 4
    x = rng.standard_normal((bsz, l, h, p)).astype(np.float32)
    a_dt = -np.abs(rng.standard_normal((bsz, l, h))).astype(np.float32) * 0.2
    b_mat = rng.standard_normal((bsz, l, n)).astype(np.float32)
    c_mat = rng.standard_normal((bsz, l, n)).astype(np.float32)
    outs = [np.asarray(ssm_mod._ssd_chunked(
        jnp.asarray(x), jnp.asarray(a_dt), jnp.asarray(b_mat),
        jnp.asarray(c_mat), ch)[0]) for ch in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- RG-LRU
def test_rglru_scan_vs_sequential(rng):
    cfg = configs.reduced("recurrentgemma-2b")
    p = rg.init_rglru(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 10, cfg.d_model)), jnp.float32)
    y_full, h_last = rg.rglru_forward(cfg, p, x, return_state=True)
    cache = rg.init_rglru_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(10):
        y, cache = rg.rglru_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(np.asarray(y))
    y_seq = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_seq, np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(h_last),
                               rtol=2e-3, atol=2e-3)


# -------------------------------------------------------------- attention
def test_chunked_attention_matches_full(rng):
    cfg = configs.reduced("yi-9b")
    p = att.init_attn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 2048, cfg.d_model)),
                    jnp.float32)
    pos = jnp.arange(2048, dtype=jnp.float32)
    full = att.attn_forward(cfg, p, x[:, :att.Q_CHUNK], pos[:att.Q_CHUNK])
    chunked_prefix = att.attn_forward(cfg, p, x, pos)[:, :att.Q_CHUNK]
    np.testing.assert_allclose(np.asarray(chunked_prefix), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_swa_masks_far_tokens(rng):
    cfg = dataclasses.replace(configs.reduced("h2o-danube-3-4b"), window=4)
    p = att.init_attn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
    pos = jnp.arange(16, dtype=jnp.float32)
    y_swa = att.attn_forward(cfg, p, x, pos, kind="swa")
    # perturb a token >window away from the last position: no effect
    x2 = x.at[:, 2].add(10.0)
    y2 = att.attn_forward(cfg, p, x2, pos, kind="swa")
    np.testing.assert_allclose(np.asarray(y_swa[:, -1]),
                               np.asarray(y2[:, -1]), rtol=1e-4, atol=1e-4)
    # causal attention *does* see it
    y_c = att.attn_forward(cfg, p, x, pos, kind="causal")
    y_c2 = att.attn_forward(cfg, p, x2, pos, kind="causal")
    assert float(jnp.max(jnp.abs(y_c[:, -1] - y_c2[:, -1]))) > 1e-4


# ------------------------------------------------------------------- MoE
def test_moe_matches_dense_expert_reference(rng):
    cfg = configs.reduced("grok-1-314b")   # cf=8 → no drops at this scale
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    out = moe_mod.moe_forward(cfg, p, x)
    # dense reference: route every token through its top-k experts directly
    from repro.models.modules import apply_linear, act_fn
    logits = apply_linear(p["router"], x)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = np.zeros(x.shape, np.float32)
    xn = np.asarray(x)
    for b in range(x.shape[0]):
        for s in range(x.shape[1]):
            acc = np.zeros(cfg.d_model, np.float32)
            for k in range(cfg.top_k):
                e = int(gi[b, s, k])
                h = xn[b, s] @ np.asarray(p["up"][e])
                h = np.asarray(act_fn(cfg)(
                    jnp.asarray(xn[b, s] @ np.asarray(p["gate"][e])))) * h
                acc += float(gv[b, s, k]) * (h @ np.asarray(p["down"][e]))
            ref[b, s] = acc
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens(rng):
    cfg = dataclasses.replace(configs.reduced("grok-1-314b"),
                              capacity_factor=0.25)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), jnp.float32)
    out = moe_mod.moe_forward(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()
    # some token outputs must be exactly zero (dropped)
    norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
    assert (norms < 1e-7).any()
