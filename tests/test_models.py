"""Per-arch smoke tests (reduced configs): one train step on CPU, output
shapes + finite values; decode-vs-forward consistency for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import steps as S
from repro.models import transformer as tf

ARCHS = list(configs.ARCHS)


def _batch(cfg, rng, b=2, l=16):
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, l, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, l)), jnp.int32)
    if cfg.frontend == "patch":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    batch["targets"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, l)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = configs.reduced(arch)
    params, opt = S.init_all(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    step = jax.jit(S.build_train_step(cfg))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    logits = jax.jit(S.build_prefill_step(cfg))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get(a).decoder])
def test_decode_matches_forward(arch, rng):
    cfg = configs.reduced(arch)
    params = tf.init_model(jax.random.PRNGKey(1), cfg)
    b, l = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, l)), jnp.int32)
    enc = None
    if cfg.frontend == "patch":
        enc = jnp.asarray(rng.standard_normal(
            (b, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    full = tf.logits_fn(params, cfg, toks, encoder=enc)
    p0 = l - 4
    pl, cache = tf.prefill_with_cache(params, cfg, toks[:, :p0],
                                      encoder=enc, cache_len=l)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(full[:, :p0]),
                               rtol=2e-3, atol=2e-3)
    dec = jax.jit(S.build_decode_step(cfg))
    for t in range(p0, l):
        logits, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-2, atol=2e-2)


def test_swa_ring_buffer_caps_cache(rng):
    """long-context decode for SWA archs stores only the window."""
    cfg = configs.reduced("h2o-danube-3-4b")
    cache = tf.init_cache(cfg, 2, min(500000, cfg.window))
    assert cache["stack"]["l0"]["k"].shape[2] == cfg.window


def test_microbatched_train_matches_full(rng):
    cfg = configs.reduced("yi-9b")
    params, opt = S.init_all(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng, b=4, l=8)
    s1 = jax.jit(S.build_train_step(cfg, num_microbatches=1))
    s2 = jax.jit(S.build_train_step(cfg, num_microbatches=2))
    _, _, m1 = s1(params, opt, batch)
    _, _, m2 = s2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-3)


def test_param_counts_match_names():
    expect = {
        "grok-1-314b": 314e9, "arctic-480b": 480e9, "yi-9b": 9e9,
        "qwen2-1.5b": 1.5e9, "h2o-danube-3-4b": 4e9,
        "mistral-large-123b": 123e9, "hubert-xlarge": 1e9,
        "mamba2-780m": 780e6,
    }
    for arch, want in expect.items():
        got = configs.get(arch).param_count()
        assert 0.7 * want <= got <= 1.35 * want, (arch, got, want)


def test_moe_active_params_smaller():
    for arch in ("grok-1-314b", "arctic-480b"):
        cfg = configs.get(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_shape_applicability_table():
    from repro.configs.base import SHAPES, shape_applicable
    cells = [(a, s) for a in configs.ARCHS for s in SHAPES
             if shape_applicable(configs.get(a), SHAPES[s])[0]]
    skipped = 10 * 4 - len(cells)
    assert skipped == 8            # DESIGN.md §5: exactly 8 documented skips
    ok, why = shape_applicable(configs.get("hubert-xlarge"),
                               SHAPES["decode_32k"])
    assert not ok and "encoder-only" in why
    ok, why = shape_applicable(configs.get("yi-9b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    for a in ("mamba2-780m", "recurrentgemma-2b", "h2o-danube-3-4b"):
        assert shape_applicable(configs.get(a), SHAPES["long_500k"])[0]
