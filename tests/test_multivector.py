"""MultiVector (Table 1) ops vs dense numpy + tiering/laziness invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MultiVector, TieredStore, HOST, DEVICE


def make_mv(store, n=256, widths=(4, 4, 2), seed=0, group_size=8):
    rng = np.random.default_rng(seed)
    mv = MultiVector(store, n, group_size=group_size, impl="ref")
    blocks = [rng.standard_normal((n, w)).astype(np.float32) for w in widths]
    for b in blocks:
        mv.append_block(jnp.asarray(b))
    return mv, np.concatenate(blocks, axis=1)


def test_mv_times_mat_grouping_invariance(rng):
    store = TieredStore()
    mv, dense = make_mv(store, widths=(4, 4, 4, 2, 2))
    small = rng.standard_normal((16, 3)).astype(np.float32)
    outs = []
    for gs in (1, 2, 8):
        mv.group_size = gs
        outs.append(np.asarray(mv.mv_times_mat(jnp.asarray(small))))
    np.testing.assert_allclose(outs[0], dense @ small, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6, atol=1e-6)


def test_mv_trans_mv(rng):
    store = TieredStore()
    mv, dense = make_mv(store)
    other = rng.standard_normal((256, 5)).astype(np.float32)
    g = np.asarray(mv.mv_trans_mv(jnp.asarray(other), alpha=1.5))
    np.testing.assert_allclose(g, 1.5 * dense.T @ other, rtol=1e-4, atol=1e-4)


def test_lazy_scale_zero_io(rng):
    store = TieredStore()
    mv, dense = make_mv(store)
    # demote everything to "SSD", reset counters
    for i in range(mv.nblocks):
        store.unpin(mv._block_name(i))
        store.demote(mv._block_name(i))
    store.reset_stats()
    mv.mv_scale(2.0)                      # lazy: no bytes moved
    assert store.stats.host_bytes_read == 0
    assert store.stats.host_bytes_written == 0
    small = rng.standard_normal((10, 2)).astype(np.float32)
    out = np.asarray(mv.mv_times_mat(jnp.asarray(small)))
    np.testing.assert_allclose(out, 2.0 * dense @ small, rtol=1e-5, atol=1e-5)


def test_most_recent_block_pinned():
    store = TieredStore()
    mv, _ = make_mv(store)
    names = [mv._block_name(i) for i in range(mv.nblocks)]
    assert store.tier_of(names[-1]) == DEVICE       # newest pinned
    assert store.tier_of(names[0]) == HOST          # older demoted


def test_mv_dot_norm_scale_diag(rng):
    store = TieredStore()
    mv, dense = make_mv(store)
    mv2, dense2 = make_mv(store, seed=1)
    np.testing.assert_allclose(np.asarray(mv.mv_dot(mv2)),
                               np.sum(dense * dense2, axis=0), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(mv.mv_norm()),
                               np.linalg.norm(dense, axis=0), rtol=1e-5)
    d = rng.standard_normal(10).astype(np.float32)
    mv.mv_scale_diag(jnp.asarray(d))
    np.testing.assert_allclose(np.asarray(mv.to_dense()), dense * d[None, :],
                               rtol=1e-5, atol=1e-5)


def test_clone_view_and_compress(rng):
    store = TieredStore()
    mv, dense = make_mv(store)
    view = np.asarray(mv.clone_view([1, 4, 9]))
    np.testing.assert_allclose(view, dense[:, [1, 4, 9]], rtol=1e-6)
    q = rng.standard_normal((10, 4)).astype(np.float32)
    out = mv.compress(jnp.asarray(q), [2, 2])
    np.testing.assert_allclose(np.asarray(out.to_dense()), dense @ q,
                               rtol=1e-4, atol=1e-4)


def test_device_budget_eviction():
    store = TieredStore(device_budget_bytes=256 * 4 * 6)  # ~1.5 blocks
    mv, _ = make_mv(store, widths=(4, 4, 4))
    dev_bytes = store.device_bytes()
    assert dev_bytes <= 256 * 4 * 8  # pinned newest + at most slack
    # reading an evicted block counts as SSD read
    store.reset_stats()
    mv.block(0)
    assert store.stats.host_bytes_read > 0


def test_write_avoidance_on_clean_demote():
    store = TieredStore()
    store.put("x", jnp.ones((64, 4)))
    store.demote("x")
    w1 = store.stats.host_bytes_written
    store.promote("x")
    store.demote("x")     # not dirty — must not write again
    assert store.stats.host_bytes_written == w1


def test_readonly_entry_rejects_overwrite():
    from repro.core import ReadOnlyError
    store = TieredStore()
    store.put("img/c0", jnp.ones((32, 4)), tier=HOST, readonly=True)
    with pytest.raises(ReadOnlyError, match="read-only"):
        store.put("img/c0", jnp.zeros((32, 4)))
    # unchanged — the guard fired before any bytes moved
    np.testing.assert_array_equal(np.asarray(store.get("img/c0")),
                                  np.ones((32, 4), np.float32))
    store.delete("img/c0")              # delete stays allowed (delete_image)
    store.put("img/c0", jnp.zeros((32, 4)))   # fresh entry is writable again
