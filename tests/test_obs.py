"""repro.obs — span tracer, metrics registry, solve timelines, report.

Covers the observability contract end-to-end:

  * tracer mechanics: nesting, thread attribution, the disabled-is-free
    no-op guard, record cap accounting, both exporters;
  * metrics snapshots: the duck-typed `snapshot_counters` over every
    counter spelling in the repo, recursive `delta` with derived-field
    recomputation, `gauges`, the registry's error isolation;
  * the `callback` seam across all four solvers through `solve()`
    dispatch (monotone steps, nev-length arrays, mutation safety) on ram
    and safs backends;
  * `solve(..., trace=...)`: the complete timeline (operator applies,
    subspace passes, SAFS fill/prefetch-wait/write-behind-retire,
    convergence events) and the byte-exact reconciliation of pass.subspace
    span bytes against the store's own IOStats;
  * `repro.obs.report` validation, for the CI gate in run_tier1.sh.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import GraphOperator, IOStats, TieredStore, solve
from repro.graphs import pack_tiles
from repro.obs import (MetricsRegistry, NULL_SPAN, SCHEMA, Tracer,
                       delta, derive, gauges, snapshot_counters,
                       snapshot_store, trace, tracing)
from repro.obs import report
from repro.obs.progress import ConvergenceTracker


def _op(small_graph, store=None):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    return GraphOperator(tm, store=store, impl="ref")


# ---------------------------------------------------------------- tracer
def test_span_nesting_and_attrs():
    t = Tracer()
    with t.span("outer", a=1):
        with t.span("inner") as sp:
            sp.set(bytes=42)
    recs = t.records()
    assert [r["name"] for r in recs] == ["inner", "outer"]  # close order
    inner, outer = recs
    assert inner["args"]["bytes"] == 42
    assert outer["args"]["a"] == 1
    assert inner["dur"] >= 0 and outer["dur"] >= inner["dur"]
    # inner nests inside outer on the timeline
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0


def test_span_records_error_on_exception():
    t = Tracer()
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    (rec,) = t.records()
    assert rec["args"]["error"] == "RuntimeError"


def test_disabled_tracing_is_noop():
    assert trace.active() is None
    # module-level span() with no tracer installed returns the shared
    # singleton — the whole cost of a disabled build is one None check
    sp = trace.span("anything", bytes=1)
    assert sp is NULL_SPAN
    with sp as s:
        s.set(more=2)             # swallowed
    trace.event("anything")       # no-op, no error


def test_tracing_contextmanager_installs_and_restores():
    t1, t2 = Tracer(), Tracer()
    assert trace.active() is None
    with tracing(t1):
        assert trace.active() is t1
        with trace.span("a"):
            pass
        with tracing(t2):          # nested solves stack
            assert trace.active() is t2
            with trace.span("b"):
                pass
        assert trace.active() is t1
    assert trace.active() is None
    assert [r["name"] for r in t1.records()] == ["a"]
    assert [r["name"] for r in t2.records()] == ["b"]


def test_thread_attribution():
    t = Tracer()

    def worker():
        with t.span("off-thread"):
            pass

    with t.span("main"):
        th = threading.Thread(target=worker, name="bg")
        th.start()
        th.join()
    tids = {r["name"]: r["tid"] for r in t.records()}
    assert tids["off-thread"] != tids["main"]
    meta = t.export_records()[0]
    assert "bg" in meta["threads"].values()


def test_record_cap_counts_dropped():
    t = Tracer(max_records=2)
    for i in range(5):
        t.event("e", i=i)
    assert len(t.records()) == 2 and t.dropped == 3
    summ = t.export_records()[-1]
    assert summ["type"] == "summary" and summ["dropped"] == 3


def test_jsonl_export_layout(tmp_path):
    t = Tracer()
    with t.span("s", x=np.int64(7)):      # numpy attrs must serialize
        pass
    t.event("ev", arr=np.arange(3))
    t.metric("m", {"a": {"b": 1}})
    path = str(tmp_path / "t.jsonl")
    t.write_jsonl(path)
    recs = [json.loads(l) for l in open(path)]
    assert recs[0]["type"] == "meta" and recs[0]["schema"] == SCHEMA
    assert recs[-1]["type"] == "summary"
    assert recs[-1] == {"type": "summary", "spans": 1, "events": 1,
                        "metrics": 1, "dropped": 0}
    by = {r["type"]: r for r in recs[1:-1]}
    assert by["span"]["args"]["x"] == 7
    assert by["event"]["args"]["arr"] == [0, 1, 2]
    assert by["metrics"]["data"] == {"a": {"b": 1}}


def test_chrome_export(tmp_path):
    t = Tracer()
    with t.span("s"):
        pass
    t.event("e")
    path = str(tmp_path / "t.json")
    t.write_chrome(path)
    doc = json.load(open(path))
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "M"} <= phases
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert x["name"] == "s" and x["dur"] >= 0


# --------------------------------------------------------------- metrics
def test_snapshot_counters_duck_typing():
    assert snapshot_counters(None) is None
    assert snapshot_counters({"a": 1}) == {"a": 1}
    st = IOStats()
    st.cache_hits = 3
    snap = snapshot_counters(st)                  # via as_dict()
    assert snap["cache_hits"] == 3 and "hit_rate" in snap

    class HasStatsAttr:
        stats = st
    assert snapshot_counters(HasStatsAttr())["cache_hits"] == 3

    class HasStatsMethod:
        def stats(self):
            return {"x": 1}
    assert snapshot_counters(HasStatsMethod()) == {"x": 1}

    with pytest.raises(TypeError, match="counter surface"):
        snapshot_counters(object())


def test_iostats_as_dict_types_and_hit_rate():
    """Satellite: the declared Dict[str, float] return is now honest, and
    hit_rate is a uniform derived field."""
    st = IOStats()
    st.cache_hits, st.cache_misses = 3, 1
    st.pass_bytes_read, st.passes = 100, 4
    d = st.as_dict()
    assert d["hit_rate"] == pytest.approx(0.75)
    assert d["bytes_per_pass"] == pytest.approx(25.0)
    assert all(isinstance(v, (int, float)) for v in d.values())
    assert st.hit_rate() == pytest.approx(0.75)
    empty = IOStats()
    assert empty.hit_rate() == 0.0                # no div-by-zero


def test_delta_recurses_and_recomputes_derived():
    before = {"logical": {"cache_hits": 10, "cache_misses": 10,
                          "hit_rate": 0.5, "passes": 2,
                          "pass_bytes_read": 200, "bytes_per_pass": 100.0},
              "tag": "x"}
    after = {"logical": {"cache_hits": 40, "cache_misses": 20,
                         "hit_rate": 2 / 3, "passes": 4,
                         "pass_bytes_read": 600, "bytes_per_pass": 150.0},
             "tag": "x"}
    d = delta(before, after)
    assert d["logical"]["cache_hits"] == 30
    # derived fields recomputed from the subtracted counters, NOT subtracted
    assert d["logical"]["hit_rate"] == pytest.approx(30 / 40)
    assert d["logical"]["bytes_per_pass"] == pytest.approx(400 / 2)
    assert d["tag"] == "x"                        # non-numeric passthrough
    assert derive({"cache_hits": 1, "cache_misses": 3})["hit_rate"] == 0.25


def test_gauges_from_store_snapshot():
    store = TieredStore()
    store.put("a", np.ones((16, 4), np.float32))
    store.demote("a")
    store.get("a")
    snap = snapshot_store(store)
    g = gauges(snap)
    assert 0.0 <= g["logical_hit_rate"] <= 1.0
    assert g["overlap_fraction"] == 0.0           # ram backend: no prefetch
    assert g["write_read_ratio"] >= 0.0


def test_metrics_registry_isolation():
    reg = MetricsRegistry()
    reg.register("good", lambda: {"v": 1})
    reg.register("bad", lambda: 1 / 0)
    reg.register("stats_obj", IOStats())
    snap = reg.snapshot()
    assert snap["good"] == {"v": 1}
    assert "ZeroDivisionError" in snap["bad"]["error"]
    assert "host_bytes_read" in snap["stats_obj"]
    reg.unregister("bad")
    assert reg.names() == ["good", "stats_obj"]


def test_ram_backend_stats_dict_shape():
    store = TieredStore()
    snap = store.backend.stats_dict()
    assert set(snap) == {"io", "cache", "prefetch", "write_behind",
                         "namespaces", "integrity"}
    assert snap["cache"] is None and snap["prefetch"] is None
    assert snap["integrity"] is None       # checksums are a safs feature


# ------------------------------------------------------- convergence/ETA
def test_convergence_tracker_eta_decay():
    t = Tracer()
    c = ConvergenceTracker(t, tol=1e-8, nev=2, method="test")
    r = 1.0
    etas = []
    for k in range(6):
        c.update(k, np.array([1.0, 1.0]), np.array([r, r / 2]))
        etas.append(c.eta_steps())
        r *= 0.1
    assert etas[0] is None                        # single point: no rate yet
    assert etas[-1] is not None and etas[-1] < etas[1]
    evs = [r for r in t.records() if r["name"] == "convergence.step"]
    assert len(evs) == 6
    assert evs[-1]["args"]["eta_steps"] == etas[-1]


def test_convergence_tracker_converged_and_stagnant():
    c = ConvergenceTracker(None, tol=1e-6, nev=1)
    c.update(0, np.array([1.0]), np.array([1e-9]))
    assert c.eta_steps() == 0                     # already below tol
    c2 = ConvergenceTracker(None, tol=1e-12, nev=1)
    for k in range(5):
        c2.update(k, np.array([1.0]), np.array([1e-3]))  # flat: no decay
    assert c2.eta_steps() is None


def test_convergence_tracker_chain_calls_user_callback():
    seen = []
    c = ConvergenceTracker(None, tol=1e-6, nev=1)
    cb = c.chain(lambda k, th, r: seen.append(k))
    cb(0, np.array([1.0]), np.array([0.5]))
    assert seen == [0] and len(c.history) == 1


# -------------------------------------------------- callback seam (4 solvers)
def _callback_recorder(nev):
    steps, arrays = [], []

    def cb(step, theta, res):
        steps.append(step)
        arrays.append((theta.copy(), res.copy()))
        assert theta.shape == (nev,) and res.shape == (nev,)
        theta[:] = -1e9            # mutation must not corrupt the solver
        res[:] = -1e9
    return cb, steps, arrays


@pytest.mark.parametrize("method,kw", [
    ("krylov_schur", dict(block_size=4, max_iters=100)),
    ("lanczos", dict(block_size=4, num_blocks=40)),
    ("lobpcg", dict(block_size=8, max_iters=300)),
])
def test_callback_all_eig_methods(small_graph, method, kw):
    nev = 4
    cb, steps, _ = _callback_recorder(nev)
    res = solve(_op(small_graph), nev, method=method, which="LA",
                tol=1e-5, callback=cb, **kw)
    assert len(steps) > 0
    assert steps == sorted(steps) and len(set(steps)) == len(steps)
    # callbacks received copies: the poisoned arrays must not leak back
    assert np.all(np.abs(res.eigenvalues) < 1e8)
    assert np.all(res.residuals > -1e8)


def test_callback_svd_method(small_graph):
    nev = 3
    cb, steps, arrays = _callback_recorder(nev)
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    op = GraphOperator(tm, impl="ref")
    at = GraphOperator(tm, impl="ref")
    res = solve(op, nev, method="svd", at_op=at, tol=1e-6, max_iters=60)
    res_cb = solve(op, nev, method="svd", at_op=at, tol=1e-6, max_iters=60,
                   callback=cb)
    assert len(steps) > 0 and steps == sorted(steps)
    # svd callback reports σ-space values: non-negative, and the final
    # callback σ's match the returned singular values
    sig_last = arrays[-1][0]
    np.testing.assert_allclose(np.sort(sig_last)[::-1][:nev],
                               res_cb.eigenvalues, rtol=1e-4)
    np.testing.assert_allclose(res_cb.eigenvalues, res.eigenvalues,
                               rtol=1e-6)                 # cb didn't perturb


@pytest.mark.disk
def test_callback_on_safs_backend(small_graph, disk_tmp):
    nev = 4
    cb, steps, _ = _callback_recorder(nev)
    store = TieredStore(backend="safs",
                        backend_opts={"root": os.path.join(disk_tmp, "p"),
                                      "cache_bytes": 1 << 20})
    res = solve(_op(small_graph, store=store), nev, method="krylov_schur",
                which="LA", tol=1e-5, max_iters=100, block_size=4,
                store=store, callback=cb)
    store.close()
    assert len(steps) > 0 and steps == sorted(steps)
    assert np.all(np.abs(res.eigenvalues) < 1e8)


# ------------------------------------------------------- traced solves
def test_traced_solve_ram_reconciles(small_graph, tmp_path):
    path = str(tmp_path / "solve.jsonl")
    res = solve(_op(small_graph), 4, method="krylov_schur", which="LA",
                tol=1e-5, max_iters=100, block_size=4, trace=path)
    assert isinstance(res.trace, Tracer)
    assert trace.active() is None          # uninstalled after the solve
    records = report.load(path)
    assert report.validate(records) == []
    names = {r["name"] for r in records if r.get("type") == "span"}
    assert {"solve", "pass.subspace", "operator.matmat"} <= names
    assert len(report.events(records, "convergence.step")) == res.n_restarts + 1
    rec = report.reconcile(records)
    assert rec["exact"] and rec["lossless"]
    assert rec["span_pass_count"] == rec["iostats_passes"] > 0
    assert rec["span_pass_bytes"] == rec["iostats_pass_bytes_read"] > 0
    # the root span carries the solve outcome
    root = next(r for r in records
                if r.get("type") == "span" and r["name"] == "solve")
    assert root["args"]["converged"] == res.converged
    assert root["args"]["nev"] == 4


def test_traced_solve_accepts_tracer_instance(small_graph):
    t = Tracer()
    res = solve(_op(small_graph), 2, method="lobpcg", tol=1e-4,
                max_iters=300, block_size=8, trace=t)
    assert res.trace is t
    assert t.counts()["spans"] > 0
    assert any(r["name"] == "convergence.step" for r in t.records())


def test_untraced_solve_has_no_trace(small_graph):
    res = solve(_op(small_graph), 2, method="krylov_schur", which="LA",
                tol=1e-4, max_iters=60)
    assert res.trace is None


@pytest.mark.disk
def test_traced_solve_safs_full_timeline(small_graph, disk_tmp, tmp_path):
    """The acceptance timeline: one traced safs solve contains operator
    applies, subspace passes, prefetch waits and write-behind retires,
    plus convergence events — and reconciles byte-exactly."""
    n = small_graph[0]
    store = TieredStore(
        device_budget_bytes=2 * n * 4 * 4, backend="safs",
        backend_opts={"root": os.path.join(disk_tmp, "pages"),
                      "cache_bytes": 3 * n * 4 * 4})
    path = str(tmp_path / "safs_solve.jsonl")
    res = solve(_op(small_graph, store=store), 4, method="krylov_schur",
                which="LA", tol=1e-6, max_iters=100, block_size=4,
                group_size=2, store=store, trace=path)
    snap = store.backend.stats_dict()
    store.close()
    assert set(snap) == {"io", "cache", "prefetch", "write_behind",
                         "namespaces", "integrity"}
    assert snap["integrity"]["pages_verified"] > 0
    assert snap["integrity"]["crc_failures"] == 0
    assert snap["prefetch"]["files_prefetched"] > 0
    assert snap["write_behind"]["pages_retired"] > 0

    records = report.load(path)
    assert report.validate(records) == []
    names = {r["name"] for r in records if r.get("type") == "span"}
    assert {"solve", "operator.matmat", "pass.subspace", "safs.fill",
            "safs.prefetch_wait", "safs.wb.retire"} <= names
    assert len(report.events(records, "convergence.step")) > 0
    rec = report.reconcile(records)
    assert rec["exact"], rec
    # off-thread SAFS work attributed to non-main tids
    wb = [r for r in records if r.get("type") == "span"
          and r["name"] == "safs.wb.retire"]
    solve_span = next(r for r in records if r.get("type") == "span"
                      and r["name"] == "solve")
    assert any(r["tid"] != solve_span["tid"] for r in wb)
    assert res.converged


# ---------------------------------------------------------------- report
def test_report_validate_catches_problems(tmp_path):
    assert report.validate([]) == ["empty trace"]
    bad = [{"type": "meta", "schema": "other/v9"},
           {"type": "span", "name": "s", "ts": 0.0, "dur": -5.0, "args": {}}]
    problems = report.validate(bad)
    assert any("schema" in p for p in problems)
    assert any("negative duration" in p for p in problems)
    # lossless trace with a metrics record that disagrees with its spans
    lying = [
        {"type": "meta", "schema": SCHEMA},
        {"type": "span", "name": report.PASS_SPAN, "ts": 0.0, "dur": 1.0,
         "args": {"bytes": 100}},
        {"type": "metrics", "name": "solve.io", "ts": 2.0,
         "data": {"delta": {"logical": {"passes": 2,
                                        "pass_bytes_read": 999}}}},
        {"type": "summary", "spans": 1, "events": 0, "metrics": 1,
         "dropped": 0},
    ]
    assert any("mismatch" in p for p in report.validate(lying))
    # the same disagreement on a lossy trace is skipped, not failed
    lying[-1]["dropped"] = 7
    assert report.validate(lying) == []


def test_report_cli_roundtrip(small_graph, tmp_path, capsys):
    path = str(tmp_path / "cli.jsonl")
    chrome = str(tmp_path / "cli_chrome.json")
    solve(_op(small_graph), 2, method="krylov_schur", which="LA",
          tol=1e-4, max_iters=60, trace=path)
    assert report.main([path, "--validate", "--chrome", chrome]) == 0
    out = capsys.readouterr().out
    assert "validation OK" in out and "phase breakdown" in out
    doc = json.load(open(chrome))
    assert any(e["ph"] == "X" for e in doc["traceEvents"])
