"""Orthogonalization invariants (property-based)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import MultiVector, TieredStore, bcgs2, cholqr, svqb, \
    ortho_error


@given(st.integers(64, 512), st.integers(1, 8), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_cholqr_invariants(n, b, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, b)), jnp.float32)
    q, r = cholqr(x, impl="ref")
    assert ortho_error(q) < 1e-4
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(x),
                               rtol=1e-3, atol=1e-3)
    # R upper triangular
    assert np.allclose(np.tril(np.asarray(r), -1), 0, atol=1e-5)


def test_cholqr_ill_conditioned():
    """κ(X) ≈ 2e5 exceeds CholeskyQR²'s f32 guarantee (κ ≲ 1e4): the
    shifted Cholesky must stay finite and bounded (no NaN blowup); the
    rank-revealing SVQB path is the designed handler for such blocks."""
    rng = np.random.default_rng(0)
    base = rng.standard_normal((256, 1)).astype(np.float32)
    x = np.concatenate([base, base + 1e-5 * rng.standard_normal((256, 1))
                        .astype(np.float32)], axis=1)
    q, _ = cholqr(jnp.asarray(x), impl="ref")
    err = ortho_error(q)
    assert np.isfinite(err) and err < 0.15
    q2, rank = svqb(jnp.asarray(x), impl="ref")
    # 1 - cos(1e-5) ≈ 5e-11 < f32 eps: the pair is numerically rank 1 and
    # SVQB must say so (the solver then refreshes the dead direction)
    assert rank == 1
    g = np.asarray(q2).T @ np.asarray(q2)
    keep = np.diag(g) > 0.5
    assert abs(g[np.ix_(keep, keep)]
               - np.eye(int(keep.sum()))).max() < 5e-2


def test_svqb_rank_detection():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((128, 2)).astype(np.float32)
    x = np.concatenate([a, a @ np.ones((2, 2), np.float32)], axis=1)  # rank 2
    q, rank = svqb(jnp.asarray(x), impl="ref")
    assert rank == 2


@given(st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_bcgs2_against_basis(seed):
    rng = np.random.default_rng(seed)
    n, bw = 256, 4
    store = TieredStore()
    basis = MultiVector(store, n, impl="ref")
    qs = np.linalg.qr(rng.standard_normal((n, 8)))[0].astype(np.float32)
    basis.append_block(jnp.asarray(qs[:, :4]))
    basis.append_block(jnp.asarray(qs[:, 4:]))
    w = jnp.asarray(rng.standard_normal((n, bw)), jnp.float32)
    q, h, r = bcgs2(basis, w, impl="ref")
    assert ortho_error(q) < 1e-4
    # orthogonal to the basis
    assert float(jnp.max(jnp.abs(basis.mv_trans_mv(q)))) < 1e-4
    # reconstruction: W = V h + Q r
    recon = qs @ np.asarray(h) + np.asarray(q) @ np.asarray(r)
    np.testing.assert_allclose(recon, np.asarray(w), rtol=2e-3, atol=2e-3)
