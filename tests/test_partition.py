"""Load-balance partitioner properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.graphs.partition import balance_tile_rows, imbalance, \
    tile_row_costs


@given(st.lists(st.floats(0.1, 100.0), min_size=8, max_size=200),
       st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_contiguous_partition_valid(costs, n_shards):
    costs = np.array(costs)
    a = balance_tile_rows(costs, n_shards)
    # contiguous and non-decreasing shard ids
    assert (np.diff(a) >= 0).all()
    assert a.min() == 0 and a.max() <= n_shards - 1
    # bottleneck within 2x of the lower bound max(mean, max_single)
    loads = np.zeros(n_shards)
    np.add.at(loads, a, costs)
    lb = max(costs.sum() / n_shards, costs.max())
    assert loads.max() <= 2.0 * lb + 1e-6


@given(st.lists(st.floats(0.1, 100.0), min_size=8, max_size=200),
       st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_lpt_beats_or_ties_naive(costs, n_shards):
    costs = np.array(costs)
    a = balance_tile_rows(costs, n_shards, contiguous=False)
    naive = np.arange(len(costs)) % n_shards
    assert imbalance(costs, a, n_shards) <= \
        imbalance(costs, naive, n_shards) + 0.5


def test_powerlaw_balance():
    """Power-law tile rows (the paper's skew case): LPT is near the
    theoretical lower bound max(mean, largest single row)."""
    rng = np.random.default_rng(0)
    costs = rng.zipf(1.5, size=512).astype(np.float64)
    a = balance_tile_rows(costs, 48, contiguous=False)
    mean_load = costs.sum() / 48
    lb = max(1.0, costs.max() / mean_load)   # a giant row forces imbalance
    assert imbalance(costs, a, 48) <= 1.05 * lb + 0.1


def test_tile_row_costs_from_ptr():
    row_ptr = np.array([0, 2, 2, 5])
    np.testing.assert_array_equal(tile_row_costs(row_ptr), [2, 0, 3])
