"""§Perf hillclimb features must be exact (not approximations)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import steps as S
from repro.models import transformer as tf


def test_moe_decode_regroup_exact(rng):
    cfg0 = configs.reduced("arctic-480b")
    cfg1 = dataclasses.replace(cfg0, moe_decode_regroup=True)
    params = tf.init_model(jax.random.PRNGKey(1), cfg0)
    b, l = 4, 10
    toks = jnp.asarray(rng.integers(0, cfg0.vocab_size, (b, l)), jnp.int32)
    _, cache = tf.prefill_with_cache(params, cfg0, toks[:, :l - 1],
                                     cache_len=l)
    l0, _ = jax.jit(S.build_decode_step(cfg0))(params, cache,
                                               toks[:, l - 1:],
                                               jnp.int32(l - 1))
    l1, _ = jax.jit(S.build_decode_step(cfg1))(params, cache,
                                               toks[:, l - 1:],
                                               jnp.int32(l - 1))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-3, atol=2e-3)


def test_prefill_last_only_matches_full(rng):
    base = configs.reduced("recurrentgemma-2b")
    opt = dataclasses.replace(base, prefill_last_only=True)
    params = tf.init_model(jax.random.PRNGKey(2), base)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, base.vocab_size, (2, 12)), jnp.int32)}
    full = S.build_prefill_step(base)(params, batch)
    last = S.build_prefill_step(opt)(params, batch)
    assert last.shape == (2, 1, base.vocab_size)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-4, atol=1e-4)


def test_bf16_residual_close_to_f32(rng):
    base = dataclasses.replace(configs.reduced("yi-9b"),
                               param_dtype="bfloat16")
    opt = dataclasses.replace(base, bf16_residual=True)
    params = tf.init_model(jax.random.PRNGKey(3), base)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 8)), jnp.int32)
    a = tf.logits_fn(params, base, toks)
    b = tf.logits_fn(params, opt, toks)
    # bf16 residual rounding: small relative error, not exact
    denom = np.maximum(np.abs(np.asarray(a)), 1.0)
    assert (np.abs(np.asarray(a) - np.asarray(b)) / denom).max() < 0.1


def test_compressed_eigen_step_matches_baseline(run_forced_mesh):
    """The uint16-packed + bf16 compressed Krylov step (page-cell variant)
    must agree with the baseline step to bf16 tolerance. Runs in the shared
    forced-device subprocess harness (conftest.run_forced_mesh)."""
    code = """
        import warnings; warnings.filterwarnings('ignore')
        import jax, numpy as np, jax.numpy as jnp
        import ml_dtypes
        from repro.dist.layout import padded_n, vertex_permutation
        from repro.dist.dspmm import (build_eigen_step,
            build_eigen_step_compressed, pack_edge_panels,
            pack_compressed_panels)
        from repro.graphs import rmat_graph
        mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
        R, M, n, b, nb_v = 4, 2, 400, 2, 2
        r, c, v = rmat_graph(n, 3000, seed=4, symmetric=True)
        n_pad = padded_n(n, R, M)
        perm = vertex_permutation(n_pad, R, M)
        pc, pr, pv, e_loc = pack_edge_panels(n_pad, perm[r], perm[c], v,
                                             r_groups=R, m_groups=M)
        packed, bases, valsb = pack_compressed_panels(pc, pr, pv)
        rng = np.random.default_rng(0)
        vb = np.linalg.qr(rng.standard_normal((n_pad, nb_v*b)))[0]
        vstack = np.ascontiguousarray(
            vb.reshape(n_pad, nb_v, b).transpose(1,0,2)).astype(np.float32)
        x = rng.standard_normal((n_pad, b)).astype(np.float32)
        f0 = build_eigen_step(mesh, n_pad=n_pad, e_loc=e_loc, b=b, nb_v=nb_v)
        q0, h0, r0 = f0(jnp.array(pc), jnp.array(pr), jnp.array(pv),
                        jnp.array(vstack), jnp.array(x))
        f1, n_chunks, e_pad = build_eigen_step_compressed(
            mesh, n_pad=n_pad, e_loc=e_loc, b=b, nb_v=nb_v)
        q1, h1, r1 = f1(jnp.array(packed), jnp.array(bases),
                        jnp.array(valsb),
                        jnp.array(vstack.astype(ml_dtypes.bfloat16)),
                        jnp.array(x.astype(ml_dtypes.bfloat16)))
        rel = np.abs(np.asarray(q0)-np.asarray(q1)).max()
        hrel = np.abs(np.asarray(h0)-np.asarray(h1)).max() / \\
            max(np.abs(np.asarray(h0)).max(), 1e-9)
        assert rel < 0.15 and hrel < 0.05, (rel, hrel)   # bf16 tolerance
        print("COMPRESSED_OK")
    """
    assert "COMPRESSED_OK" in run_forced_mesh(code)
