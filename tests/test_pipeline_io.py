"""Data pipeline determinism + graph image serialization + HLO parser."""
import numpy as np

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.graphs import pack_tiles, rmat_graph
from repro.graphs.gio import load_image, save_image, stream_tile_rows
from repro.utils.hlo_analysis import collective_bytes


def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch(13), p2.batch(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    assert not np.array_equal(p1.batch(14)["tokens"], b1["tokens"])
    # targets are next-token shifted
    full1 = np.concatenate([b1["tokens"], b1["targets"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full1[:, 1:], b1["targets"])


def test_host_sharding_partitions():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    p = TokenPipeline(cfg)
    b = p.batch(0)
    parts = [p.host_shard(b, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), b["tokens"])


def test_graph_image_roundtrip(tmp_path):
    r, c, v = rmat_graph(400, 3000, seed=1, symmetric=True)
    tm = pack_tiles(400, 400, r, c, v, block_shape=(16, 16), min_block_nnz=2)
    save_image(str(tmp_path / "img"), tm)
    tm2 = load_image(str(tmp_path / "img"))
    np.testing.assert_allclose(tm.to_dense(), tm2.to_dense())
    # streaming visits every tile row once, bytes sum to the image blocks
    total = sum(nb for _, _, _, nb in stream_tile_rows(tm2))
    assert total >= tm.blocks.nbytes


def test_collective_bytes_parser():
    hlo = """
  %all_gather.3 = f32[512,2]{1,0} all-gather(%param.9), channel_id=1, replica_groups={{0,2,4,6},{1,3,5,7}}, dimensions={0}
  %reduce_scatter = f32[128,2]{1,0} reduce-scatter(%x), replica_groups=[4,2]<=[8], dimensions={0}
  %all_reduce = f32[8,8]{1,0} all-reduce(%y), replica_groups=[1,8]<=[8]
  %cp = bf16[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %fusion = f32[8]{0} fusion(%w), kind=kLoop, calls=%foo
"""
    out = collective_bytes(hlo, 8)
    assert out["all-gather"] == 512 * 2 * 4 * 3 / 4
    assert out["reduce-scatter"] == 128 * 2 * 4 * 1
    assert out["all-reduce"] == 2 * 8 * 8 * 4 * 7 / 8
    assert out["collective-permute"] == 64 * 2
    assert out["count_all-gather"] == 1
