"""repro.safs — page store, cache, crash consistency, backend equivalence.

Everything filesystem-touching is `@pytest.mark.disk` and runs inside the
size-guarded `disk_tmp` fixture (conftest): scripts/run_tier1.sh re-runs
this subset in a bounded TMPDIR.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MultiVector, TieredStore, DEVICE, HOST
from repro.safs import (CrashPoint, PageCache, PageFile, PrefetchError,
                        Prefetcher, SafsBackend, WriteBehind,
                        WriteBehindError, coalesce_runs)
from repro.ckpt import checkpoint as ck

pytestmark = pytest.mark.disk


# ------------------------------------------------------------------ pagefile
def test_pagefile_roundtrip_and_cold_reopen(disk_tmp):
    path = os.path.join(disk_tmp, "a.pages")
    arr = np.arange(5000, dtype=np.float32).reshape(100, 50)
    pf = PageFile(path, page_size=4096, shape=arr.shape, dtype="float32")
    pf.write_pages(pf.split(arr))
    np.testing.assert_array_equal(pf.assemble(
        {i: pf.read_page(i) for i in pf.page_indices()}), arr)
    pf.close()
    # cold reopen recovers shape/dtype from the sidecar
    pf2 = PageFile(path)
    assert pf2.shape == (100, 50) and pf2.dtype == np.float32
    np.testing.assert_array_equal(pf2.assemble(
        {i: pf2.read_page(i) for i in pf2.page_indices()}), arr)
    pf2.delete()
    assert not os.path.exists(path)


def test_crash_after_journal_commit_redoes_on_reopen(disk_tmp):
    """Kill mid-flush AFTER the journal committed: reopening must replay
    the journal, so every page shows the NEW contents."""
    path = os.path.join(disk_tmp, "c.pages")
    old = np.zeros((64, 64), np.float32)
    new = np.full((64, 64), 7.0, np.float32)
    pf = PageFile(path, page_size=4096, shape=old.shape, dtype="float32")
    pf.write_pages(pf.split(old))
    with pytest.raises(CrashPoint):
        pf.write_pages(pf.split(new), crash_after_pages=1)  # died mid-patch
    pf.close()
    pf2 = PageFile(path)   # recovery replays the committed journal
    got = pf2.assemble({i: pf2.read_page(i) for i in pf2.page_indices()})
    np.testing.assert_array_equal(got, new)
    assert not os.path.exists(path + ".journal")
    pf2.close()


def test_crash_before_journal_commit_keeps_old_pages(disk_tmp):
    """Kill mid-flush BEFORE the commit trailer: the uncommitted journal is
    discarded and every page shows the OLD contents (no torn pages)."""
    path = os.path.join(disk_tmp, "d.pages")
    old = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    new = old + 100.0
    pf = PageFile(path, page_size=4096, shape=old.shape, dtype="float32")
    pf.write_pages(pf.split(old))
    with pytest.raises(CrashPoint):
        pf.write_pages(pf.split(new), crash_in_journal=True)
    pf.close()
    pf2 = PageFile(path)
    got = pf2.assemble({i: pf2.read_page(i) for i in pf2.page_indices()})
    np.testing.assert_array_equal(got, old)
    assert not os.path.exists(path + ".journal")
    pf2.close()


# --------------------------------------------------------- batched/vectored
def test_coalesce_runs_merges_adjacent_and_dedups():
    assert coalesce_runs([]) == []
    assert coalesce_runs([3]) == [(3, 1)]
    assert coalesce_runs([5, 0, 1, 2, 7, 6, 2]) == [(0, 3), (5, 3)]


def test_read_pages_batch_matches_per_page_reads(disk_tmp):
    """The vectored engine returns byte-identical pages to the PR-2
    single-pread path, across runs longer than one iovec batch."""
    path = os.path.join(disk_tmp, "b.pages")
    arr = np.random.default_rng(0).standard_normal(70000).astype(np.float32)
    pf = PageFile(path, page_size=4096, shape=arr.shape, dtype="float32")
    pf.write_pages(pf.split(arr))
    idxs = [0, 1, 2, 40, 41, 5, pf.n_pages - 1]
    got = pf.read_pages_batch(idxs)
    assert sorted(got) == sorted(set(idxs))
    for i in got:
        assert got[i] == pf.read_page(i)
    # whole-file batch assembles back to the array
    np.testing.assert_array_equal(
        pf.assemble(pf.read_pages_batch(pf.page_indices())), arr)
    pf.delete()


# --------------------------------------------------------------- page cache
def _cache(capacity_pages=4, page_size=64):
    written = []

    def writer(data_id, pages):
        written.append((data_id, dict(pages)))
        return len(pages) * page_size

    return PageCache(capacity_pages * page_size, page_size, writer), written


def test_cache_lru_eviction_and_dirty_writeback():
    c, written = _cache(capacity_pages=2)
    c.put("a", 0, b"x" * 64, dirty=True)
    c.put("a", 1, b"y" * 64, dirty=False)
    c.put("b", 0, b"z" * 64, dirty=True)      # evicts ("a",0) → write-back
    assert written == [("a", {0: b"x" * 64})]
    assert c.get("a", 0) is None              # miss: evicted
    assert c.get("b", 0) == b"z" * 64         # hit
    assert c.stats.host_bytes_written == 64
    # clean eviction writes nothing (write-avoidance / endurance)
    c.put("b", 1, b"w" * 64, dirty=False)     # evicts clean ("a",1)
    assert len(written) == 1


def test_cache_pinning_protects_recent_block():
    c, written = _cache(capacity_pages=2)
    c.put("recent", 0, b"r" * 64, dirty=True)
    c.pin("recent")
    c.put("other", 0, b"o" * 64, dirty=False)
    c.put("other", 1, b"p" * 64, dirty=False)  # pressure: must skip pinned
    assert c.peek("recent", 0)                 # survived (no LRU touch)
    c.unpin("recent")
    c.put("other", 2, b"q" * 64, dirty=False)  # now evictable → write-back
    assert written and written[0][0] == "recent"


def test_cache_flush_batches_per_file():
    c, written = _cache(capacity_pages=8)
    for i in range(3):
        c.put("f", i, bytes([i]) * 64, dirty=True)
    n = c.flush()
    assert n == 3 * 64
    assert written == [("f", {0: b"\0" * 64, 1: b"\1" * 64, 2: b"\2" * 64})]
    assert c.flush() == 0                      # idempotent: now clean


# ----------------------------------------------------- backend equivalence
def _twin_mvs(disk_tmp, n=384, widths=(4, 4, 2), seed=0, cache_pages=2,
              sub="pages"):
    """Identical MultiVectors on ram and safs stores (+ the dense oracle).
    Each call gets its own page-store root (`sub`): a SafsBackend owns its
    root exclusively — two live backends over one directory would race
    recovery against each other's async write-behind."""
    rng = np.random.default_rng(seed)
    blocks = [rng.standard_normal((n, w)).astype(np.float32)
              for w in widths]
    ram = MultiVector(TieredStore(), n, group_size=2, impl="ref")
    safs = MultiVector(
        TieredStore(backend="safs",
                    backend_opts={"root": os.path.join(disk_tmp, sub),
                                  "cache_bytes": cache_pages * 4096}),
        n, group_size=2, impl="ref")
    for b in blocks:
        ram.append_block(jnp.asarray(b))
        safs.append_block(jnp.asarray(b))
    return ram, safs, np.concatenate(blocks, axis=1)


def test_backend_equivalence_all_eleven_ops(disk_tmp):
    """The eleven Table-1 MultiVector ops agree byte-for-byte between the
    ram emulation and the file-backed safs store (tiny page cache, so the
    safs side genuinely round-trips the filesystem)."""
    rng = np.random.default_rng(3)
    ram, safs, dense = _twin_mvs(disk_tmp)
    n, m = dense.shape
    small = jnp.asarray(rng.standard_normal((m, 3)), jnp.float32)
    other = jnp.asarray(rng.standard_normal((n, 5)), jnp.float32)
    diag = jnp.asarray(rng.standard_normal(m), jnp.float32)

    def both(f):
        a, b = np.asarray(f(ram)), np.asarray(f(safs))
        np.testing.assert_array_equal(a, b)
        return a

    # 1 MvTimesMatAddMv  2 MvTransMv  3 MvDot  4 MvNorm  5 CloneView
    both(lambda mv: mv.mv_times_mat(small))
    both(lambda mv: mv.mv_trans_mv(other, alpha=1.5))
    other_mv_r, other_mv_s, _ = _twin_mvs(disk_tmp, n=n, widths=(4, 4, 2),
                                          seed=7, sub="pages2")
    np.testing.assert_array_equal(np.asarray(ram.mv_dot(other_mv_r)),
                                  np.asarray(safs.mv_dot(other_mv_s)))
    both(lambda mv: mv.mv_norm())
    both(lambda mv: mv.clone_view([0, 3, 9]))
    # 6 ConvLayout
    both(lambda mv: mv.conv_layout())
    # 7 MvScale (lazy) + 8 MvScale-diag (materializing)
    ram.mv_scale(0.5), safs.mv_scale(0.5)
    ram.mv_scale_diag(diag), safs.mv_scale_diag(diag)
    both(lambda mv: mv.to_dense())
    # 9 MvAddMv
    np.testing.assert_array_equal(
        np.asarray(ram.mv_add_mv(2.0, other_mv_r, -1.0).to_dense()),
        np.asarray(safs.mv_add_mv(2.0, other_mv_s, -1.0).to_dense()))
    # 10 SetBlock
    blk = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    ram.set_block(1, blk), safs.set_block(1, blk)
    both(lambda mv: mv.to_dense())
    # 11 MvRandom (same key → same blocks on both backends)
    key = jax.random.PRNGKey(11)
    ram.mv_random(key, [4, 4]), safs.mv_random(key, [4, 4])
    both(lambda mv: mv.to_dense())
    # restart compression (the big out-of-core GEMM) rides on ops 1
    q = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ram.compress(q, [4]).to_dense()),
        np.asarray(safs.compress(q, [4]).to_dense()))
    # the safs side actually touched the medium
    assert safs.store.backend.stats.host_bytes_read > 0
    safs.store.close()


def test_safs_streams_from_disk_under_tiny_cache(disk_tmp):
    """Cache smaller than one block: every grouped pass re-reads pages from
    the file, and the result still matches the dense oracle."""
    rng = np.random.default_rng(5)
    n, widths = 512, (4, 4, 4, 4)
    store = TieredStore(
        device_budget_bytes=2 * n * 4 * 4, backend="safs",
        backend_opts={"root": os.path.join(disk_tmp, "p"),
                      "cache_bytes": 2 * 4096})
    mv = MultiVector(store, n, group_size=2, impl="ref")
    blocks = [rng.standard_normal((n, w)).astype(np.float32) for w in widths]
    for b in blocks:
        mv.append_block(jnp.asarray(b))
    # drain the write-behind queue: otherwise its victim buffer (legally)
    # serves the evicted pages and no read ever needs the medium
    store.flush()
    dense = np.concatenate(blocks, axis=1)
    small = rng.standard_normal((16, 3)).astype(np.float32)
    out = np.asarray(mv.mv_times_mat(jnp.asarray(small)))
    np.testing.assert_allclose(out, dense @ small, rtol=1e-5, atol=1e-5)
    d = store.backend.stats
    assert d.host_bytes_read > 0 and d.host_bytes_written > 0
    store.close()


def test_recent_block_pin_survives_flood_and_hits_on_reread(disk_tmp):
    """§3.4.4 regression: the most recently appended-then-demoted subspace
    block's pages must stay pinned through a sequential scan larger than
    the cache (LRU's pathological flood) and hit on the reorth re-read.
    Pre-fix, every demotion re-pinned — unrelated LRU spills stole the pin
    and the solver-path hit rate collapsed to ~0.02 (BENCH_safs.json)."""
    rng = np.random.default_rng(7)
    n, b, nblocks = 2048, 4, 8
    store = TieredStore(
        device_budget_bytes=2 * n * 4 * b, backend="safs",
        backend_opts={"root": os.path.join(disk_tmp, "pin"),
                      "cache_bytes": 3 * n * 4 * b, "page_size": 4096,
                      "enable_prefetch": False})
    mv = MultiVector(store, n, group_size=2, impl="ref")
    for _ in range(nblocks):
        mv.append_block(jnp.asarray(rng.standard_normal((n, b)), np.float32))
    cache = store.backend.cache
    recent = mv.block_names()[-2]          # newest on-"SSD" block
    assert cache.pinned() == {recent}
    # flood: a full sequential scan (8 blocks through a 3-block cache)
    small = jnp.asarray(rng.standard_normal((nblocks * b, 2)), jnp.float32)
    mv.mv_times_mat(small)
    d = store.backend.stats
    hits0, misses0 = d.cache_hits, d.cache_misses
    # the pinned block's pages must all still be resident: pure hits
    np.asarray(store.get(recent))
    pf = store.backend.pagefile(recent)
    assert d.cache_hits == hits0 + pf.n_pages
    assert d.cache_misses == misses0
    # unrelated demotion churn must NOT steal the pin (the pre-fix bug):
    # spill a pile of non-subspace entries through the device budget
    for k in range(6):
        store.put(f"scratch/{k}", jnp.asarray(
            rng.standard_normal((n, b)), np.float32))
    assert cache.pinned() == {recent}
    # ...until the next append supersedes it
    mv.append_block(jnp.asarray(rng.standard_normal((n, b)), np.float32))
    assert cache.pinned() == {mv.block_names()[-2]}
    store.close()


def test_tier_semantics_identical_across_backends(disk_tmp):
    """Pin/demote/write-avoidance logic is backend-independent."""
    store = TieredStore(backend="safs",
                        backend_opts={"root": os.path.join(disk_tmp, "t")})
    store.put("x", jnp.ones((64, 4)))
    store.demote("x")
    assert store.tier_of("x") == HOST
    w1 = store.stats.host_bytes_written
    store.promote("x")
    assert store.tier_of("x") == DEVICE
    store.demote("x")     # not dirty — must not write again
    assert store.stats.host_bytes_written == w1
    np.testing.assert_array_equal(np.asarray(store.get("x")),
                                  np.ones((64, 4), np.float32))
    store.close()


# ----------------------------------------------------------------- prefetch
def test_prefetch_staging_is_correct_and_counted(disk_tmp):
    store = TieredStore(backend="safs",
                        backend_opts={"root": os.path.join(disk_tmp, "pf"),
                                      "cache_bytes": 1 << 20})
    arrs = {f"v{i}": np.random.default_rng(i).standard_normal(
        (256, 4)).astype(np.float32) for i in range(4)}
    for k, a in arrs.items():
        store.put(k, jnp.asarray(a), tier=HOST)
    store.flush()
    # fully cache-resident files are SKIPPED in O(1) (a fused full-pass
    # announcement must not burn the readahead window on no-op fills) ...
    store.prefetch(list(arrs))
    store.backend.prefetcher.drain()
    assert store.backend.prefetcher.stats()["files_prefetched"] == 0
    # ... and once the pages are gone, the same announcement stages them
    for k in arrs:
        store.backend.cache.invalidate(k)
    store.prefetch(list(arrs))
    store.backend.prefetcher.drain()
    assert store.backend.prefetcher.stats()["files_prefetched"] >= 1
    for k, a in arrs.items():
        np.testing.assert_array_equal(np.asarray(store.get(k)), a)
    store.close()


def test_prefetch_wait_propagates_reader_exception(disk_tmp):
    """A reader that dies mid-read must surface at wait(), not hang the
    consumer (PR-2's worker swallowed the exception silently)."""
    calls = []

    def reader(data_id):
        calls.append(data_id)
        if data_id == "bad":
            raise IOError("device gone")
        return 7

    pf = Prefetcher(reader, io_workers=1, depth=4)
    pf.schedule(["ok", "bad"])
    assert pf.wait("ok") >= 0.0
    with pytest.raises(PrefetchError):
        pf.wait("bad")
    assert pf.stats()["read_errors"] == 1
    # a re-offer after the failure is accepted again (error state cleared)
    pf.schedule(["bad"])
    with pytest.raises(PrefetchError):
        pf.wait("bad")
    pf.close()


def test_prefetch_wait_detects_dead_worker_pool():
    """wait() on a pool whose workers have exited raises instead of
    blocking forever (the satellite's hang case)."""
    pf = Prefetcher(lambda d: 0, io_workers=1, depth=2)
    with pf._cv:                      # simulate a crashed worker thread
        pf._done["never"] = __import__("threading").Event()
    pf.close()                        # workers exit; "never" still unset
    with pytest.raises(PrefetchError):
        pf.wait("never", poll=0.01)


def test_prefetch_depth_bounds_queue():
    """Ids offered past the readahead window are dropped, not queued."""
    import threading
    gate = threading.Event()
    pf = Prefetcher(lambda d: gate.wait(5) and 0, io_workers=1, depth=2)
    pf.schedule([f"f{i}" for i in range(8)])   # 1 in flight + 2 queued max
    st = pf.stats()
    assert st["files_dropped"] >= 5
    gate.set()
    pf.drain()
    pf.close()


# ------------------------------------------------------------ write-behind
def test_write_behind_ack_survives_kill_mid_demotion(disk_tmp):
    """Kill mid-demotion with a populated write-behind queue: every *acked*
    page (journal committed for its batch) must be recovered by journal
    replay on reopen; un-acked queued pages are simply lost (the sync
    barrier is flush/drain, which the kill precedes)."""
    path = os.path.join(disk_tmp, "wb.pages")
    old = np.zeros((128, 32), np.float32)
    new = np.full((128, 32), 9.0, np.float32)
    pf = PageFile(path, page_size=4096, shape=old.shape, dtype="float32")
    pf.write_pages(pf.split(old))

    # the drain thread's journaled writer dies after the journal committed
    # but mid in-place patch — the acked-but-torn state of a real kill
    def writer(data_id, pages):
        return pf.write_pages(pages, crash_after_pages=1)

    wb = WriteBehind(writer, max_pages=1024)
    wb.submit("wb", pf.split(new))            # demotion enters the queue
    with pytest.raises(WriteBehindError) as ei:
        wb.drain()
    assert isinstance(ei.value.__cause__, CrashPoint)
    wb.close()
    pf.close()

    pf2 = PageFile(path)    # process restart: replay the committed journal
    got = pf2.assemble({i: pf2.read_page(i) for i in pf2.page_indices()})
    np.testing.assert_array_equal(got, new)   # every acked page recovered
    assert not os.path.exists(path + ".journal")
    pf2.delete()


def test_write_behind_serves_queued_pages_and_orders_rewrites(disk_tmp):
    """The queue is a victim buffer: evicted-but-unwritten pages are served
    by lookup (never stale disk bytes), and a page resubmitted with newer
    bytes retires with the newer bytes."""
    import threading
    path = os.path.join(disk_tmp, "vb.pages")
    arr = np.arange(2048, dtype=np.float32)
    pf = PageFile(path, page_size=4096, shape=arr.shape, dtype="float32")
    gate = threading.Event()

    def slow_writer(data_id, pages):
        gate.wait(5)
        return pf.write_pages(pages)

    wb = WriteBehind(slow_writer, max_pages=64)
    pages_v1 = pf.split(arr)
    pages_v2 = pf.split(arr + 100.0)
    wb.submit("vb", pages_v1)
    wb.submit("vb", pages_v2)      # newer bytes for the same pages
    assert wb.lookup("vb", 0) == pages_v2[0]   # newest wins pre-retire
    assert wb.lookup("vb", 99) is None
    gate.set()
    wb.drain()
    assert wb.lookup("vb", 0) is None          # retired: disk is current
    np.testing.assert_array_equal(
        pf.assemble({i: pf.read_page(i) for i in pf.page_indices()}),
        arr + 100.0)
    wb.close()
    pf.delete()


def test_backend_read_your_evictions_via_write_behind(disk_tmp):
    """End-to-end: a dirty block evicted from a tiny cache into the
    write-behind queue reads back its newest bytes immediately."""
    store = TieredStore(backend="safs", backend_opts={
        "root": os.path.join(disk_tmp, "rye"), "cache_bytes": 2 * 4096})
    a = np.random.default_rng(1).standard_normal((600, 4)).astype(np.float32)
    b = np.random.default_rng(2).standard_normal((600, 4)).astype(np.float32)
    store.put("x", jnp.asarray(a), tier=HOST)
    store.put("y", jnp.asarray(b), tier=HOST)   # evicts x's dirty pages
    np.testing.assert_array_equal(np.asarray(store.get("x")), a)
    np.testing.assert_array_equal(np.asarray(store.get("y")), b)
    store.close()


def test_stale_clean_fill_cannot_outlive_write_behind_entry(disk_tmp):
    """Race reconciliation: a clean fill that reads old disk bytes while a
    concurrent eviction pushes newer bytes into the write-behind queue
    must not publish a stale clean line — while the batch is queued the
    queue shadows it, but once it retires the line would be served
    forever. The interleaving (evict wins the lock just before the
    reader's guarded insert) is forced by intercepting put_clean_if."""
    backend = SafsBackend(os.path.join(disk_tmp, "race"),
                          write_behind=True)
    old = np.arange(1024, dtype=np.float32)          # exactly one page
    new = np.full(1024, 7.0, dtype=np.float32)
    backend.store("x", old)
    backend.flush()                                   # disk holds `old`
    backend.cache.invalidate("x")                     # force a disk fill
    new_payload = backend.pagefile("x").split(new)[0]

    real_pci = backend.cache.put_clean_if
    fired = []

    def racing_pci(data_id, page, data, fresh):
        if data_id == "x" and page == 0 and not fired:
            fired.append(True)   # the eviction wins the lock first
            backend.writebehind.submit("x", {0: new_payload})
        return real_pci(data_id, page, data, fresh)

    backend.cache.put_clean_if = racing_pci
    np.testing.assert_array_equal(backend.load("x"), new)   # not stale
    backend.cache.put_clean_if = real_pci
    backend.writebehind.drain()       # batch retires: queue stops shadowing
    np.testing.assert_array_equal(backend.load("x"), new)
    backend.close()


def test_stale_clean_fill_guard_covers_retired_batch(disk_tmp):
    """The harder interleaving: the racing batch both lands AND retires
    inside the reader's read+insert window — a queue lookup alone comes
    back empty (the entry is gone) while the disk already holds the newer
    bytes, so only the submit-generation check can flag the stale fill."""
    backend = SafsBackend(os.path.join(disk_tmp, "race2"),
                          write_behind=True)
    old = np.arange(1024, dtype=np.float32)          # exactly one page
    new = np.full(1024, 9.0, dtype=np.float32)
    backend.store("x", old)
    backend.flush()
    backend.cache.invalidate("x")
    new_payload = backend.pagefile("x").split(new)[0]

    real_pci = backend.cache.put_clean_if
    fired = []

    def racing_pci(data_id, page, data, fresh):
        if data_id == "x" and page == 0 and not fired:
            fired.append(True)
            backend.writebehind.submit("x", {0: new_payload})
            backend.writebehind.drain()   # batch fully retires to disk
        return real_pci(data_id, page, data, fresh)

    backend.cache.put_clean_if = racing_pci
    np.testing.assert_array_equal(backend.load("x"), new)   # re-read disk
    backend.cache.put_clean_if = real_pci
    assert not backend.cache.peek("x", 0)   # stale fill was never inserted
    np.testing.assert_array_equal(backend.load("x"), new)
    backend.close()


def test_stale_fill_guard_generation_captured_before_probe(disk_tmp):
    """Ordering of the guard itself: the generation must be captured
    BEFORE the staleness probes. If an evict lands between a page's probe
    and a capture taken afterwards, and its batch retires while the disk
    read is in flight, both the queue lookup (entry gone) and a late-
    captured generation compare (bump already included) would pass on
    stale bytes. Forced here: the evict fires during another page's
    probe, the retire during the disk read."""
    backend = SafsBackend(os.path.join(disk_tmp, "race3"),
                          write_behind=True)
    old = np.arange(2048, dtype=np.float32)          # exactly two pages
    new_page0 = np.full(1024, 3.0, dtype=np.float32)
    backend.store("x", old)
    backend.flush()
    backend.cache.invalidate("x")
    pf = backend.pagefile("x")
    want = old.copy()
    want[:1024] = new_page0
    new_payload = pf.split(want)[0]

    real_get = backend.cache.get
    fired = []

    def probing_get(data_id, page, **kw):
        if data_id == "x" and page == 1 and not fired:
            fired.append(True)   # evict lands between probe(0) and capture
            backend.writebehind.submit("x", {0: new_payload})
        return real_get(data_id, page, **kw)

    real_read = pf.read_pages_batch

    def draining_read(idxs):
        out = real_read(idxs)        # reads the pre-retire (stale) bytes
        backend.writebehind.drain()  # batch retires mid-read
        return out

    backend.cache.get = probing_get
    pf.read_pages_batch = draining_read
    np.testing.assert_array_equal(backend.load("x"), want)
    backend.cache.get = real_get
    pf.read_pages_batch = real_read
    np.testing.assert_array_equal(backend.load("x"), want)
    backend.close()


# --------------------------------------------------- SSD-streamed SpMM image
def test_graph_operator_streams_image_from_safs(disk_tmp, small_graph):
    """stream_image=True spills the edge tiles into the page store and
    matmat reproduces the RAM-resident operator exactly while the tier
    accounts the streamed image reads."""
    from repro.graphs import pack_tiles
    from repro.core import GraphOperator
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore(backend="safs", backend_opts={
        "root": os.path.join(disk_tmp, "img"), "cache_bytes": 8 * 4096})
    op_stream = GraphOperator(tm, store=store, impl="ref",
                              stream_image=True, image_chunk_bytes=1 << 16)
    # drain the write-behind queue: until the spilled chunks retire, its
    # victim buffer (legally) serves every miss and no read needs the disk
    store.flush()
    op_ram = GraphOperator(tm, impl="ref")
    x = jnp.asarray(np.random.default_rng(3)
                    .standard_normal((tm.shape[0], 4)), jnp.float32)
    y_stream = np.asarray(op_stream.matmat(x))
    np.testing.assert_allclose(y_stream, np.asarray(op_ram.matmat(x)),
                               rtol=1e-6, atol=1e-6)
    r0 = store.stats.host_bytes_read
    assert r0 > 0                         # image chunks counted as reads
    assert store.backend.stats.host_bytes_read > 0   # really hit the medium
    np.testing.assert_allclose(np.asarray(op_stream.matmat(x)), y_stream,
                               rtol=0, atol=0)
    assert store.stats.host_bytes_read > r0   # re-streamed per matmat
    op_stream.delete_image()
    assert not [d for d in store.backend.data_ids() if "tiles" in d]
    store.close()


def test_streamed_image_chunks_are_readonly(disk_tmp, small_graph):
    """The streamed image has no per-chunk dirty tracking: writing through
    a chunk name must raise, not silently diverge from the on-disk image."""
    from repro.graphs import pack_tiles
    from repro.core import GraphOperator, ReadOnlyError
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore(backend="safs", backend_opts={
        "root": os.path.join(disk_tmp, "ro")})
    op = GraphOperator(tm, store=store, impl="ref", stream_image=True,
                       image_chunk_bytes=1 << 16)
    chunk = next(nm for nm in store.names() if "/tiles/" in nm)
    with pytest.raises(ReadOnlyError, match="read-only"):
        store.put(chunk, jnp.zeros((8, 8)))
    if op._has_coo:
        with pytest.raises(ReadOnlyError, match="read-only"):
            store.put(f"{op._name}/coo_vals", jnp.zeros(4))
    x = jnp.asarray(np.random.default_rng(4)
                    .standard_normal((tm.shape[0], 2)), jnp.float32)
    y0 = np.asarray(op.matmat(x))        # image unharmed by the attempts
    np.testing.assert_allclose(
        y0, np.asarray(GraphOperator(tm, impl="ref").matmat(x)),
        rtol=1e-6, atol=1e-6)
    op.delete_image()                    # delete path still allowed
    store.close()


def test_normal_operator_streams_both_images(disk_tmp):
    """NormalOperator.from_tiles forwards the streamed-image machinery to
    BOTH constituent operators (an SVD solve otherwise keeps two full
    images in RAM) and delete_image drops both spills."""
    from repro.graphs import pack_tiles, clustered_web_graph
    from repro.core import NormalOperator, svds
    n = 600
    r, c, v = clustered_web_graph(n, 4000, seed=2)
    tm_a = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    tm_at = pack_tiles(n, n, c, r, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore(backend="safs", backend_opts={
        "root": os.path.join(disk_tmp, "svd")})
    gram = NormalOperator.from_tiles(tm_a, tm_at, store=store, impl="ref",
                                     stream_image=True,
                                     image_chunk_bytes=1 << 16, name="pg")
    assert gram.stream_image
    store.flush()
    spilled = [d for d in store.backend.data_ids() if "tiles" in d]
    assert any(d.startswith("pg/A/") for d in spilled)
    assert any(d.startswith("pg/At/") for d in spilled)   # transpose too
    res = svds(gram.a, gram.at, 3, block_size=2, tol=1e-6,
               max_restarts=120, store=store, impl="ref")
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla
    a = sp.coo_matrix((v, (r, c)), shape=(n, n)).tocsr()
    s_sc = np.sort(spla.svds(a, k=3, return_singular_vectors=False))
    np.testing.assert_allclose(np.sort(res.s), s_sc, rtol=1e-3, atol=1e-3)
    gram.delete_image()
    assert not [d for d in store.backend.data_ids() if "tiles" in d]
    store.close()


# --------------------------------------------------------------- checkpoint
def test_checkpoint_direct_from_pages_roundtrip(disk_tmp):
    """save_safs snapshots the page files themselves; restore_safs reopens
    them with contents intact — no array ever assembled for the copy."""
    root = os.path.join(disk_tmp, "live")
    store = TieredStore(backend="safs", backend_opts={"root": root})
    a = np.random.default_rng(9).standard_normal((300, 4)).astype(np.float32)
    b = np.random.default_rng(10).standard_normal((300, 2)).astype(np.float32)
    d = np.random.default_rng(11).standard_normal((300, 4)).astype(np.float32)
    store.put("mv/b0", jnp.asarray(a), tier=HOST)
    store.put("mv/b1", jnp.asarray(b), tier=HOST)
    # device-tier, never demoted (the pinned newest block of §3.4.4): the
    # snapshot must write it through rather than silently drop it
    store.put("mv/b2", jnp.asarray(d))
    store.pin("mv/b2")
    path = ck.save_safs(os.path.join(disk_tmp, "ck"), 7, store,
                        extra={"nev": 8})
    assert os.path.basename(path) == "step_0000000007"
    assert store.tier_of("mv/b2") == DEVICE      # residency unchanged
    backend, extra = ck.restore_safs(os.path.join(disk_tmp, "ck"), 7,
                                     os.path.join(disk_tmp, "restored"))
    assert extra == {"nev": 8}
    assert sorted(backend.data_ids()) == ["mv/b0", "mv/b1", "mv/b2"]
    np.testing.assert_array_equal(backend.load("mv/b0"), a)
    np.testing.assert_array_equal(backend.load("mv/b1"), b)
    np.testing.assert_array_equal(backend.load("mv/b2"), d)
    backend.close()
    store.close()


def test_checkpoint_safs_rejects_ram_store(disk_tmp):
    with pytest.raises(TypeError):
        ck.save_safs(os.path.join(disk_tmp, "ck"), 0, TieredStore())


# -------------------------------------------------------------- end to end
def test_eigsh_safs_matches_ram_backend(disk_tmp, small_graph):
    """The acceptance bar at test scale: Krylov–Schur with the subspace in
    page files converges to the same spectrum as the ram emulation, and the
    tier stays read-dominated (Table 3)."""
    from repro.graphs import pack_tiles
    from repro.core import GraphOperator, eigsh
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)

    def run(backend, opts):
        store = TieredStore(device_budget_bytes=2 * n * 4 * 4,
                            backend=backend, backend_opts=opts)
        op = GraphOperator(tm, store=store, impl="ref")
        res = eigsh(op, 4, block_size=4, tol=1e-7, max_restarts=60,
                    store=store, impl="ref", group_size=2)
        return res, store

    res_ram, _ = run("ram", None)
    res_safs, store = run("safs", {"root": os.path.join(disk_tmp, "sub"),
                                   "cache_bytes": 6 * 4096})
    np.testing.assert_allclose(np.sort(res_safs.eigenvalues),
                               np.sort(res_ram.eigenvalues), rtol=1e-5)
    s = store.stats
    assert s.host_bytes_read > 10 * s.host_bytes_written
    assert store.backend.stats.host_bytes_read > 0   # really hit the medium
    store.close()
