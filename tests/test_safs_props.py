"""Property-based tests for the SAFS page cache (hypothesis).

Auto-skipped at collection when hypothesis is absent (see conftest.py and
requirements-dev.txt), like the other property-test modules. These pin the
cache invariants under arbitrary op interleavings:

  * a get after a put returns the last payload put (cache coherence);
  * unpinned residency never exceeds the byte budget;
  * pinned files are never evicted, whatever the pressure;
  * every dirty page is accounted exactly once — written back on eviction
    or flush, or still resident-dirty (endurance accounting is lossless).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.safs import PageCache

PAGE = 64
NFILES = 3
NPAGES = 4

ops = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, NFILES - 1),
                  st.integers(0, NPAGES - 1), st.integers(0, 255),
                  st.booleans()),
        st.tuples(st.just("get"), st.integers(0, NFILES - 1),
                  st.integers(0, NPAGES - 1)),
        st.tuples(st.just("pin"), st.integers(0, NFILES - 1)),
        st.tuples(st.just("unpin"), st.integers(0, NFILES - 1)),
        st.tuples(st.just("flush"),),
        st.tuples(st.just("invalidate"), st.integers(0, NFILES - 1)),
    ),
    max_size=60)


def _run(op_list, capacity_pages):
    written = {}          # (file, page) -> last payload written back

    def writer(data_id, pages):
        for p, data in pages.items():
            written[(data_id, p)] = data
        return len(pages) * PAGE

    c = PageCache(capacity_pages * PAGE, PAGE, writer)
    shadow = {}           # (file, page) -> last payload put (ground truth)
    for op in op_list:
        kind = op[0]
        if kind == "put":
            _, f, p, byte, dirty = op
            data = bytes([byte]) * PAGE
            c.put(f"f{f}", p, data, dirty=dirty)
            shadow[(f"f{f}", p)] = data
        elif kind == "get":
            _, f, p = op
            got = c.get(f"f{f}", p)
            if got is not None:       # resident ⇒ must be the latest put
                assert got == shadow[(f"f{f}", p)]
        elif kind == "pin":
            c.pin(f"f{op[1]}")
        elif kind == "unpin":
            c.unpin(f"f{op[1]}")
        elif kind == "flush":
            c.flush()
        elif kind == "invalidate":
            f = f"f{op[1]}"
            c.invalidate(f)           # keeps dirty data via write-back
            for key in list(shadow):
                if key[0] == f:
                    del shadow[key]
    return c, shadow, written


@settings(max_examples=60, deadline=None)
@given(op_list=ops, capacity_pages=st.integers(1, NFILES * NPAGES))
def test_cache_coherent_and_budgeted(op_list, capacity_pages):
    c, shadow, _ = _run(op_list, capacity_pages)
    # residency bound: unpinned bytes fit the budget (pinned may exceed)
    unpinned = sum(1 for (d, p) in list(c._lines) if d not in c.pinned())
    if not c.pinned():
        assert unpinned * PAGE <= capacity_pages * PAGE
    # every resident line equals the ground truth
    for (d, p) in list(c._lines):
        assert c._lines[(d, p)].data == shadow[(d, p)]


@settings(max_examples=60, deadline=None)
@given(op_list=ops, capacity_pages=st.integers(1, 4))
def test_pinned_files_never_evicted(op_list, capacity_pages):
    # pin f0 up front, replay arbitrary traffic, then check f0 pages that
    # were put after the pin are all still resident
    written = {}

    def writer(data_id, pages):
        for p, data in pages.items():
            written[(data_id, p)] = data
        return len(pages) * PAGE

    c = PageCache(capacity_pages * PAGE, PAGE, writer)
    c.pin("f0")
    put_f0 = set()
    for op in op_list:
        if op[0] == "put":
            _, f, p, byte, dirty = op
            c.put(f"f{f}", p, bytes([byte]) * PAGE, dirty=dirty)
            if f == 0:
                put_f0.add(p)
        elif op[0] == "get":
            c.get(f"f{op[1]}", op[2])
    for p in put_f0:
        assert c.peek("f0", p), "pinned page was evicted"
    assert all(k[0] != "f0" for k in written), "pinned page written back"


@settings(max_examples=60, deadline=None)
@given(op_list=ops, capacity_pages=st.integers(1, NFILES * NPAGES))
def test_no_dirty_byte_lost(op_list, capacity_pages):
    """Endurance accounting is lossless: after a final flush, the latest
    payload of every surviving dirty page is either in `written` (went to
    the medium) or was superseded/invalidated — never silently dropped."""
    c, shadow, written = _run(op_list, capacity_pages)
    c.flush()
    for key, data in shadow.items():
        resident = c._lines.get(key)
        if resident is not None:
            assert not resident.dirty          # flush left nothing dirty
        # if the last put was dirty it must have reached the writer
        # (we can't know per-key dirtiness here without replay, so check
        # the weaker global invariant: no line anywhere remains dirty)
    assert all(not line.dirty for line in c._lines.values())
