"""Multi-tenant serving layer: namespaces, arbiter, scheduler, service.

Covers the three layers of the eigensolver-as-a-service stack plus its
cross-cutting invariants:

  * `TieredStore.namespace()` isolation + per-namespace accounting, with
    parent == Σ namespaces reconciliation (logical AND physical);
  * concurrent-hammer reconciliation of the shared IOStats / LRU
    bookkeeping (the thread-safety fix: counters must balance EXACTLY);
  * `BudgetArbiter` priority splits and the fused-compress cap riding a
    session's allotment (a small-budget session chunks its compress);
  * `SolveScheduler` priority dispatch, admission control and
    checkpoint-based preemption (deterministic stub sessions);
  * the disk-marked E2E: 4 mixed jobs (eigsh + lobpcg + cluster) over ONE
    shared SafsBackend with ≥1 preempt/resume, spectra matching serial
    runs at rtol 1e-5 and exact per-namespace byte reconciliation.
"""
import json
import threading
import time
import types

import numpy as np
import pytest

from repro.core.tiered import IOStats, TieredStore
from repro.serve import (AdmissionError, BudgetArbiter, JobSpec,
                         PagedConfig, PagedKVCache, PreemptFlag,
                         SolveScheduler, SolveSession, build_service,
                         validate_report)
from repro.serve.session import DONE, PENDING, RUNNING, SUSPENDED


# ===================================================== namespaces (resource)
def test_namespace_isolation_and_accounting():
    store = TieredStore(device_budget_bytes=1 << 20)
    a = store.namespace("a")
    b = store.namespace("b")
    a.put("x", np.full((64,), 1.0, np.float32))
    b.put("x", np.full((64,), 2.0, np.float32))
    assert float(np.asarray(a.get("x"))[0]) == 1.0
    assert float(np.asarray(b.get("x"))[0]) == 2.0
    assert a.names() == ["x"] and b.names() == ["x"]
    # host-tier traffic lands in the owning session's bucket and the
    # parent's counters alike: parent == Σ namespaces, field by field
    a.demote("x"), b.demote("x")
    a.get("x"), b.get("x")
    ns = store.namespace_stats()
    for field in ("host_bytes_written", "host_bytes_read",
                  "host_writes", "host_reads"):
        total = sum(d[field] for d in ns.values())
        assert total == getattr(store.stats, field) > 0, field


def test_namespace_drop_reclaims_but_stats_survive():
    store = TieredStore(device_budget_bytes=1 << 20)
    a = store.namespace("a")
    a.put("x", np.zeros(64, np.float32))
    a.demote("x")
    written = store.namespace_stats()["a"]["host_bytes_written"]
    assert written > 0
    a.close()
    assert store.names() == []
    # post-mortem accounting survives the drop (the serve report needs it)
    assert store.namespace_stats()["a"]["host_bytes_written"] == written
    # a fresh facade under the same id starts empty
    assert store.namespace("a").names() == []


def test_namespace_budget_evicts_own_entries_only():
    store = TieredStore(device_budget_bytes=1 << 30)
    a, b = store.namespace("a"), store.namespace("b")
    blk = np.zeros((1024,), np.float32)          # 4 KiB each
    for i in range(4):
        a.put(f"v{i}", blk + i)
        b.put(f"v{i}", blk + i)
    store.set_namespace_budget("a", 8 << 10)     # room for 2 of a's blocks
    assert sum(a.tier_of(f"v{i}") == "device" for i in range(4)) <= 2
    assert all(b.tier_of(f"v{i}") == "device" for i in range(4))
    # values survive eviction (demoted, not dropped)
    assert float(np.asarray(a.get("v0"))[0]) == 0.0


# ================================================ satellite b: thread safety
def test_iostats_concurrent_hammer_reconciles_exactly():
    stats = IOStats()
    n_threads, n_iter = 8, 2000

    def hammer():
        for _ in range(n_iter):
            stats.add(host_reads=1, host_bytes_read=128)

    ts = [threading.Thread(target=hammer) for _ in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert stats.host_reads == n_threads * n_iter
    assert stats.host_bytes_read == n_threads * n_iter * 128


def test_store_concurrent_sessions_reconcile_exactly():
    """N threads, one store, one namespace each: per-ns logical sums must
    equal the parent's counters to the byte (the unsynchronized-increment
    bug this PR fixes would lose updates here)."""
    store = TieredStore(device_budget_bytes=32 << 10)   # force LRU churn
    n_threads, n_iter = 6, 120
    blk = np.zeros(512, np.float32)                     # 2 KiB

    def worker(sid):
        ns = store.namespace(sid)
        for i in range(n_iter):
            ns.put(f"v{i % 8}", blk + i)
            ns.get(f"v{i % 8}")

    ts = [threading.Thread(target=worker, args=(f"s{k}",))
          for k in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    ns = store.namespace_stats()
    for field in ("host_bytes_written", "host_bytes_read",
                  "host_reads", "host_writes",
                  "cache_hits", "cache_misses"):
        assert sum(d[field] for d in ns.values()) == \
            getattr(store.stats, field), field
    # device residency bookkeeping also balances
    assert store.device_bytes() <= 32 << 10


# ======================================================= arbiter + budgets
def test_arbiter_priority_split_and_recompute():
    store = TieredStore(device_budget_bytes=12 << 20)
    arb = BudgetArbiter(store, device_budget=12 << 20)
    s_lo = arb.admit("lo", priority=0)
    assert s_lo == 12 << 20                      # alone: the whole budget
    s_hi = arb.admit("hi", priority=3)
    # weights 1:4 over 12 MiB (floor division per share)
    assert arb.allotment("lo") == (12 << 20) * 1 // 5
    assert arb.allotment("hi") == (12 << 20) * 4 // 5
    assert s_hi == arb.allotment("hi")
    assert store.namespace_budget("lo") == arb.allotment("lo")
    arb.release("hi")
    assert arb.allotment("lo") == 12 << 20       # share redistributed
    assert store.namespace_budget("hi") is None
    st = arb.stats_dict()
    assert st["admits"] == 2 and st["releases"] == 1


def test_arbiter_min_share_floor():
    store = TieredStore(device_budget_bytes=4 << 20)
    arb = BudgetArbiter(store, device_budget=4 << 20, min_share=1 << 20)
    arb.admit("lo", priority=0)
    arb.admit("hi", priority=100)
    assert arb.allotment("lo") == 1 << 20        # floored, not starved
    assert arb.stats_dict()["oversubscribed"] in (True, False)


# ================================== satellite a: compress cap ← allotment
def test_compress_chunks_under_small_session_allotment():
    """A session whose arbiter allotment is small must chunk its fused
    compress pass (multiple SubspacePass runs) instead of materializing
    k_keep·n·4 transient accumulator bytes; an uncapped session does the
    whole compress in ONE pass."""
    from repro.core.multivector import MultiVector
    n, widths = 40_000, (4, 4, 4)                # 640 KiB per output block
    q = np.eye(12, dtype=np.float32)

    def run(budget):
        store = TieredStore(device_budget_bytes=1 << 30)
        ns = store.namespace("s")
        if budget is not None:
            store.set_namespace_budget("s", budget)
        mv = MultiVector(ns, n, name="V")
        rng = np.random.default_rng(0)
        for w in widths:
            mv.append_block(rng.standard_normal((n, w)).astype(np.float32))
        before = store.stats.passes
        out = mv.compress(q, widths)
        return store.stats.passes - before, out.to_dense()

    p_big, d_big = run(None)
    # 2 MiB allotment → 1 MiB compress cap → 3 single-width pass groups
    p_small, d_small = run(2 << 20)
    assert p_big == 1
    assert p_small == 3
    np.testing.assert_allclose(d_small, d_big, rtol=1e-6)


# ============================================================== job specs
def test_jobspec_validation():
    with pytest.raises(ValueError, match="unknown kind"):
        JobSpec("j", kind="svd")
    with pytest.raises(ValueError, match="unknown job-spec fields"):
        JobSpec.from_dict({"job_id": "j", "frobnicate": 1})
    with pytest.raises(ValueError, match="job_id"):
        JobSpec.from_dict({"kind": "eigsh"})
    assert JobSpec("c", kind="cluster").graph == "planted"
    assert JobSpec("l", kind="lobpcg").method == "lobpcg"


# ============================================== scheduler (stub sessions)
class _StubSession:
    """Duck-typed SolveSession: runs until released (or preempted), so
    scheduler decisions can be single-stepped deterministically."""

    def __init__(self, jid, priority, *, instant=False):
        self.spec = types.SimpleNamespace(job_id=jid, priority=priority,
                                          preemptible=True)
        self.state = PENDING
        self.guard = PreemptFlag()
        self.ckpt_root = "stub"
        self.preemptions = 0
        self.release = threading.Event()
        if instant:
            self.release.set()

    def mark_queued(self):
        pass

    def mark_dequeued(self):
        pass

    @property
    def can_preempt(self):
        return self.state == RUNNING and not self.guard.requested()

    def progress(self):
        return {"state": self.state}

    def run(self):
        self.state = RUNNING
        while not self.release.is_set():
            if self.guard.requested():
                self.preemptions += 1
                self.state = SUSPENDED
                return
            time.sleep(0.002)
        self.state = DONE


def _mini_sched(max_concurrent=1, max_queued=64):
    store = TieredStore(device_budget_bytes=8 << 20)
    arb = BudgetArbiter(store, device_budget=8 << 20)
    return SolveScheduler(store, arb, max_concurrent=max_concurrent,
                          max_queued=max_queued, poll_interval=0.002)


def test_scheduler_runs_in_priority_order():
    sched = _mini_sched(max_concurrent=1)
    jobs = {p: _StubSession(f"p{p}", p, instant=True) for p in (0, 2, 1)}
    for s in jobs.values():
        sched.submit(s)
    done = sched.drain()
    assert [s.spec.job_id for s in done] == ["p2", "p1", "p0"]


def test_scheduler_admission_control():
    sched = _mini_sched(max_queued=2)
    sched.submit(_StubSession("a", 0, instant=True))
    sched.submit(_StubSession("b", 0, instant=True))
    with pytest.raises(AdmissionError):
        sched.submit(_StubSession("c", 0, instant=True))


def test_scheduler_preempts_for_higher_priority():
    sched = _mini_sched(max_concurrent=1)
    low = _StubSession("low", 0)
    sched.submit(low)
    for _ in range(200):                 # let the low job occupy the slot
        sched.tick()
        if low.state == RUNNING:
            break
        time.sleep(0.002)
    assert low.state == RUNNING
    high = _StubSession("high", 5, instant=True)
    sched.submit(high)
    deadline = time.monotonic() + 5
    while high.state != DONE and time.monotonic() < deadline:
        sched.tick()
        time.sleep(0.002)
    assert high.state == DONE            # jumped the queue via preemption
    assert sched.preempt_requests == 1 and sched.requeues == 1
    assert low.preemptions == 1
    low.release.set()                    # let the requeued victim finish
    done = sched.drain()
    assert {s.spec.job_id for s in done} == {"low", "high"}
    assert low.state == DONE
    # every admit was released (namespace + share teardown balanced)
    a = sched.arbiter.stats_dict()
    assert a["admits"] == a["releases"] == 3 and not a["live_sessions"]


def test_equal_priority_never_preempts():
    sched = _mini_sched(max_concurrent=1)
    a = _StubSession("a", 1)
    sched.submit(a)
    for _ in range(200):
        sched.tick()
        if a.state == RUNNING:
            break
        time.sleep(0.002)
    sched.submit(_StubSession("b", 1, instant=True))
    for _ in range(20):
        sched.tick()
        time.sleep(0.002)
    assert sched.preempt_requests == 0 and a.state == RUNNING
    a.release.set()
    sched.drain()


# ===================================================== service (ram, fast)
@pytest.fixture(scope="module")
def ram_service_report():
    svc = build_service(backend="ram", device_budget=8 << 20,
                        max_concurrent=2)
    svc.submit(JobSpec("embed", kind="eigsh", n=300, nnz=3000, nev=3,
                       tol=1e-6, max_iters=60))
    svc.submit(JobSpec("pcg", kind="lobpcg", n=200, nnz=2000, nev=2,
                       tol=1e-4, max_iters=50, priority=1))
    svc.drain()
    rep = svc.report()
    svc.close()
    return rep


def test_service_report_valid_and_json(ram_service_report):
    rep = ram_service_report
    assert validate_report(rep) == []
    assert {j["job_id"] for j in rep["jobs"]} == {"embed", "pcg"}
    for j in rep["jobs"]:
        assert j["state"] == DONE and j["spectrum"]["sha"]
        assert j["wall_s"] > 0 and j["queue_wait_s"] >= 0
    assert rep["arbiter"]["admits"] == 2
    # the report is the machine-readable surface: must be JSON-clean
    json.dumps(rep, default=str)


def test_validate_report_catches_violations(ram_service_report):
    rep = json.loads(json.dumps(ram_service_report, default=str))
    rep["jobs"][0]["state"] = "failed"
    rep["backend"]["namespaces"]["embed"]["host_bytes_written"] = \
        rep["backend"]["namespaces"].get("embed", {}).get(
            "host_bytes_written", 0) + 7
    errs = validate_report(rep)
    assert any("lost" in e for e in errs)
    assert any("accounting leak" in e for e in errs)
    assert validate_report({"jobs": [], "scheduler": {}}) != []


def test_service_rejects_duplicate_job_id():
    svc = build_service(backend="ram", device_budget=4 << 20)
    svc.submit(JobSpec("a", n=100, nnz=600, nev=2, tol=1e-3, max_iters=10))
    with pytest.raises(ValueError, match="duplicate"):
        svc.submit(JobSpec("a"))
    svc.drain()
    svc.close()


# ============================================ satellite f: paged KV rides
def test_paged_kv_namespaced_coexistence():
    store = TieredStore(device_budget_bytes=4 << 20)
    solver_ns = store.namespace("solve")
    solver_ns.put("V/b0", np.zeros(256, np.float32))
    cfg = PagedConfig(page_size=8, n_kv_heads=2, head_dim=4, hot_pages=2)
    kv = PagedKVCache(cfg, store, session_id="kv")
    kv.start(0)
    for t in range(20):
        k = np.full((2, 4), t, np.float32)
        kv.append(0, k, k)
    assert kv.length(0) == 20
    # pages are namespaced on the SHARED store, solver blocks untouched
    assert all(n.startswith("kv/") for n in kv._tables[0])
    assert any(n.startswith("kv/") for n in store.namespace("kv").names())
    assert solver_ns.names() == ["V/b0"]
    out = kv.attend(0, np.ones((4, 4), np.float32))
    assert out.shape == (4, 4)
    kv.close()
    assert store.namespace("kv").names() == []
    assert solver_ns.names() == ["V/b0"]         # survivors intact


def test_paged_kv_bare_store_unchanged():
    cfg = PagedConfig(page_size=4, n_kv_heads=1, head_dim=4, hot_pages=1)
    kv = PagedKVCache(cfg)
    kv.start(7)
    kv.append(7, np.ones((1, 4), np.float32), np.ones((1, 4), np.float32))
    assert kv._tables[7] == ["kv/7/p0"]          # unprefixed, as before
    assert kv.session_id is None
    kv.close()                                    # no-op teardown


def test_paged_kv_rejects_unnamespaceable_store():
    class Bare:
        pass
    with pytest.raises(TypeError, match="namespace"):
        PagedKVCache(PagedConfig(), Bare(), session_id="kv")


# ====================================== E2E: multi-tenant solves over SAFS
def _serial_eigenvalues(spec):
    """The same JobSpec solved alone on a fresh private store — the parity
    baseline for the shared-store run."""
    s = SolveSession(spec, TieredStore(device_budget_bytes=64 << 20), None)
    assert s.run() == DONE, s.error
    return np.array(s.result["eigenvalues"])


@pytest.mark.disk
def test_multi_tenant_e2e_with_preemption(disk_tmp):
    import os
    specs = [
        JobSpec("bg-embed", kind="eigsh", n=800, nnz=8000, nev=4,
                priority=1, tol=1e-9, max_iters=200),
        JobSpec("bg-lobpcg", kind="lobpcg", n=400, nnz=4000, nev=3,
                priority=0, tol=1e-5, max_iters=60),
        JobSpec("bg-cluster", kind="cluster", n=900, k_classes=3, nev=3,
                priority=0, tol=1e-6),
    ]
    rush = JobSpec("rush", kind="eigsh", n=300, nnz=3000, nev=2,
                   priority=5, tol=1e-5, max_iters=60)
    svc = build_service(
        backend="safs", root=os.path.join(disk_tmp, "pages"),
        device_budget=8 << 20, cache_bytes=4 << 20,
        ckpt_root=os.path.join(disk_tmp, "ckpt"), max_concurrent=1,
        poll_interval=0.005)
    try:
        for spec in specs:
            svc.submit(spec)
        # wait until the long high-ish-priority job is mid-flight, then
        # drop the rush job on the queue → the scheduler must suspend it
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            svc.scheduler.tick()
            running = svc.scheduler.stats_dict()["running"]
            if any(p["steps"] >= 1 for p in running.values()):
                break
            time.sleep(0.01)
        svc.submit(rush)
        svc.drain()
        rep = svc.report()
    finally:
        svc.close()

    assert validate_report(rep) == []
    jobs = {j["job_id"]: j for j in rep["jobs"]}
    assert len(jobs) == 4 and all(j["state"] == DONE
                                  for j in jobs.values())
    assert sum(j["preemptions"] for j in jobs.values()) >= 1
    preempted = [j for j in jobs.values() if j["preemptions"]]
    assert all(j["resumes"] >= 1 for j in preempted)
    # the rush job barely waited; spectra match private serial runs
    assert jobs["rush"]["queue_wait_s"] < jobs["bg-lobpcg"]["queue_wait_s"]
    for spec in specs + [rush]:
        got = np.array(jobs[spec.job_id]["result"]["eigenvalues"])
        np.testing.assert_allclose(got, _serial_eigenvalues(spec),
                                   rtol=1e-5)
    assert jobs["bg-cluster"]["purity"] > 0.9
    # physical accounting: per-namespace sums == backend totals, exactly
    ns, io = rep["backend"]["namespaces"], rep["backend"]["io"]
    for field in ("host_bytes_read", "host_bytes_written"):
        assert sum(d[field] for d in ns.values()) == io[field]
