"""Solver-family dispatch (`core.solver.solve`) + spectral transforms."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.core import (CAP_FUSED_EXPAND, CAP_SPECTRAL_TRANSFORM,
                        ChebyshevFilterOperator, EigResult, GraphOperator,
                        ShiftInvertOperator, TieredStore, capabilities,
                        estimate_spectral_range, solve, solver_names)
from repro.core.solver import _REGISTRY, register_solver
from repro.graphs import pack_tiles


def _op(small_graph, store=None):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    return GraphOperator(tm, store=store, impl="ref")


# ------------------------------------------------------------- dispatch
def test_registry_has_the_family():
    assert {"krylov_schur", "lanczos", "lobpcg", "svd"} <= set(solver_names())


def test_unknown_method_raises():
    with pytest.raises(ValueError, match="unknown method"):
        solve(None, 1, method="nope")


def test_svd_requires_at_op(small_graph):
    with pytest.raises(ValueError, match="at_op"):
        solve(_op(small_graph), 2, method="svd")


def test_register_custom_solver(small_graph):
    sentinel = EigResult(eigenvalues=np.array([42.0]), eigenvectors=None,
                         residuals=np.array([0.0]), n_restarts=0, n_ops=0,
                         m_subspace=0, converged=True, io_stats={})

    class Dummy:
        name = "dummy"

        def solve(self, ctx):
            assert ctx.nev == 1 and ctx.which == "LM"
            return sentinel

    register_solver(Dummy())
    try:
        assert "dummy" in solver_names()
        assert solve(_op(small_graph), 1, method="dummy") is sentinel
    finally:
        del _REGISTRY["dummy"]


def test_methods_agree_on_spectrum(small_graph):
    """Every family member lands on the same top-4 algebraic eigenvalues
    through the one `solve` entrypoint, each with real IOStats attached."""
    n, r, c, v, a = small_graph
    w = np.sort(spla.eigsh(a, k=4, which="LA", return_eigenvectors=False))
    for method, kw in (("krylov_schur", dict(block_size=4, max_iters=100)),
                       ("lanczos", dict(block_size=4, num_blocks=40)),
                       ("lobpcg", dict(block_size=8, max_iters=300))):
        res = solve(_op(small_graph), 4, method=method, which="LA",
                    tol=1e-5, **kw)
        assert isinstance(res, EigResult), method
        assert isinstance(res.io_stats, dict) and res.io_stats["passes"] > 0
        np.testing.assert_allclose(np.sort(res.eigenvalues), w,
                                   rtol=1e-3, atol=1e-3, err_msg=method)


def test_lobpcg_ortho_policy_parity(small_graph):
    """ortho='fused' vs 'unfused' through the dispatch: identical spectra
    (same math, same accumulation order), strictly fewer streamed passes
    on the fused policy."""
    stats, evs = {}, {}
    for ortho in ("fused", "unfused"):
        store = TieredStore()
        res = solve(_op(small_graph), 4, method="lobpcg", tol=1e-4,
                    max_iters=300, block_size=8, store=store, ortho=ortho)
        assert res.converged, ortho
        stats[ortho] = res.io_stats
        evs[ortho] = np.sort(res.eigenvalues)
    np.testing.assert_array_equal(evs["fused"], evs["unfused"])
    assert stats["fused"]["passes"] < stats["unfused"]["passes"]


# ------------------------------------------------------------ transforms
def test_capabilities_declared_vs_sniffed(small_graph):
    op = _op(small_graph)
    assert capabilities(op) == frozenset()
    si = ShiftInvertOperator(op, -1.5, inner_solver="cg")
    assert CAP_SPECTRAL_TRANSFORM in capabilities(si)
    ch = ChebyshevFilterOperator(op, (-1.0, 0.5), degree=6)
    assert CAP_SPECTRAL_TRANSFORM in capabilities(ch)

    class Legacy:                       # pre-protocol operators still work
        supports_fused_expand = True

    assert CAP_FUSED_EXPAND in capabilities(Legacy())


def test_shift_invert_agrees_with_sa(small_graph):
    """Interior-mode machinery on an exterior target it can be checked
    against: σ below the spectrum makes A − σI definite (plain CG inner
    solves) and eigenvalues-nearest-σ IS the smallest-algebraic set, so
    shift-invert through `solve` must reproduce which='SA' eigenpairs —
    with true A-residuals after the untransform."""
    n, r, c, v, a = small_graph
    ref = solve(_op(small_graph), 3, method="krylov_schur", which="SA",
                tol=1e-6, max_iters=100, block_size=4)
    assert ref.converged
    si = ShiftInvertOperator(_op(small_graph), -1.5, inner_solver="cg",
                             cg_tol=1e-10, cg_maxiter=500)
    res = solve(si, 3, method="krylov_schur", tol=1e-6, max_iters=100,
                block_size=4)
    assert si.n_inner_iters > 0
    np.testing.assert_allclose(np.sort(res.eigenvalues),
                               np.sort(ref.eigenvalues), rtol=1e-5)
    assert np.all(res.residuals < 1e-4)     # residuals of A, not (A−σI)⁻¹


def test_chebyshev_filter_recovers_top_pairs(small_graph):
    """Damping [lo, mid(λ₂,λ₃)] leaves the top-2 eigenpairs dominant in
    the filtered operator; untransform (Rayleigh on the inner operator)
    must recover them with small true residuals."""
    n, r, c, v, a = small_graph
    w = np.sort(spla.eigsh(a, k=4, which="LA", return_eigenvectors=False))
    lo, hi = estimate_spectral_range(_op(small_graph))
    assert lo < w[0] and hi > w[-1]          # the estimate brackets
    ch = ChebyshevFilterOperator(_op(small_graph),
                                 (lo, 0.5 * (w[-2] + w[-3])), degree=12)
    res = solve(ch, 2, method="krylov_schur", tol=1e-6, max_iters=100,
                block_size=2)
    np.testing.assert_allclose(np.sort(res.eigenvalues), w[-2:], rtol=1e-4)
    assert np.all(res.residuals < 1e-2)


def test_chebyshev_untransform_needs_vectors(small_graph):
    ch = ChebyshevFilterOperator(_op(small_graph), (-1.0, 0.5), degree=6)
    with pytest.raises(ValueError, match="vec"):
        ch.untransform(np.ones(2), None)
