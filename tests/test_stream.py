"""Fused streamed-pass engine (§3.4.3): parity + byte-exact I/O bounds."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GraphOperator, MultiVector, SubspacePass, TieredStore,
                        bcgs2, eigsh)
from repro.core.krylov_schur import _expand
from repro.graphs import pack_tiles

# the all-blocks-demoted measurement fixture is shared with the bench so
# both assert against the identical I/O state (tier-1 runs pytest from the
# repo root via `python -m`, so `benchmarks` is importable)
from benchmarks.bench_subspace_io import _demoted_mv


# --------------------------------------------------------------- parity
def test_fused_bcgs2_matches_unfused():
    rng = np.random.default_rng(3)
    n = 384
    store = TieredStore()
    basis = MultiVector(store, n, impl="ref")
    qs = np.linalg.qr(rng.standard_normal((n, 12)))[0].astype(np.float32)
    for j in range(0, 12, 4):
        basis.append_block(jnp.asarray(qs[:, j:j + 4]))
    w = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    qf, hf, rf = bcgs2(basis, w, impl="ref", fused=True)
    qu, hu, ru = bcgs2(basis, w, impl="ref", fused=False)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hu),
                               rtol=1e-5, atol=1e-5)
    # both Qs orthogonal to the basis and to themselves
    for q in (qf, qu):
        assert float(jnp.max(jnp.abs(basis.mv_trans_mv(q)))) < 1e-4
    # same subspace: |QfᵀQu| ≈ I up to signs
    g = np.abs(np.asarray(qf).T @ np.asarray(qu))
    np.testing.assert_allclose(g, np.eye(4), atol=1e-3)


def test_compress_fused_matches_unfused_exactly():
    rng = np.random.default_rng(4)
    store = TieredStore()
    mv = _demoted_mv(store, n=256, b=4, nb=6, seed=4)
    q = jnp.asarray(rng.standard_normal((24, 12)), jnp.float32)
    outf = mv.compress(q, [4, 4, 4], fused=True)
    outu = mv.compress(q, [4, 4, 4], fused=False)
    # identical accumulation order per output block → bit-for-bit on ref
    np.testing.assert_array_equal(np.asarray(outf.to_dense()),
                                  np.asarray(outu.to_dense()))


def test_krylov_invariant_with_bcgs2_h_convention(small_graph):
    """Regression for the unified H convention: _expand now takes its H
    column from bcgs2 (h1 + h2, the second-pass correction included —
    previously hand-inlined CGS2 discarded h2). The Krylov invariant
    A·q = V·h + q_next·r must hold with the RETURNED h, on both paths."""
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    for fused in (True, False):
        store = TieredStore()
        op = GraphOperator(tm, store=store, impl="ref")
        mv = MultiVector(store, op.n, impl="ref")
        rng = np.random.default_rng(7)
        q = jnp.asarray(np.linalg.qr(rng.standard_normal((op.n, 4)))[0],
                        jnp.float32)
        h = np.zeros((0, 0))
        for step in range(3):
            aq = np.asarray(op.matmat(q))
            q_next, h, r_next = _expand(op, mv, q, h, "ref",
                                        fused_passes=fused)
            m = h.shape[0]
            h_col = h[:, m - 4:]
            recon = (np.asarray(mv.to_dense()) @ h_col
                     + np.asarray(q_next) @ r_next)
            np.testing.assert_allclose(recon, aq, rtol=2e-3, atol=2e-3,
                                       err_msg=f"fused={fused} step={step}")
            q = q_next


def test_eigsh_fused_vs_unfused_spectrum(small_graph):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    evs = {}
    for fused in (True, False):
        store = TieredStore()
        op = GraphOperator(tm, store=store, impl="ref")
        res = eigsh(op, 4, block_size=4, tol=1e-6, max_restarts=100,
                    store=store, impl="ref", fused_passes=fused)
        assert res.converged
        evs[fused] = np.sort(res.eigenvalues)
    np.testing.assert_allclose(evs[True], evs[False], rtol=1e-5)


@pytest.mark.disk
def test_eigsh_fused_vs_unfused_spectrum_safs(disk_tmp, small_graph):
    """Parity with the subspace genuinely in SAFS page files."""
    import os
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    evs = {}
    for fused in (True, False):
        store = TieredStore(
            device_budget_bytes=2 * n * 4 * 4, backend="safs",
            backend_opts={"root": os.path.join(disk_tmp, f"f{fused}"),
                          "cache_bytes": 3 * n * 4 * 4})
        op = GraphOperator(tm, store=store, impl="ref")
        res = eigsh(op, 4, block_size=4, tol=1e-6, max_restarts=100,
                    store=store, impl="ref", fused_passes=fused)
        assert res.converged
        evs[fused] = np.sort(res.eigenvalues)
        store.close()
    np.testing.assert_allclose(evs[True], evs[False], rtol=1e-5)


# ------------------------------------------------------------ byte counts
def test_fused_expansion_reads_at_most_2x_subspace():
    """An expansion at NB blocks must read the host tier at most ~2× the
    subspace size (two project_out passes); the unfused path reads 4×."""
    n, b, nb = 512, 4, 8
    sub_bytes = n * b * 4 * nb
    w = jnp.asarray(np.random.default_rng(1).standard_normal((n, b)),
                    jnp.float32)
    store = TieredStore()
    mv = _demoted_mv(store, n, b, nb)
    store.reset_stats()
    bcgs2(mv, w, impl="ref", fused=True)
    assert store.stats.host_bytes_read == 2 * sub_bytes
    assert store.stats.passes == 2

    store = TieredStore()
    mv = _demoted_mv(store, n, b, nb)
    store.reset_stats()
    bcgs2(mv, w, impl="ref", fused=False)
    assert store.stats.host_bytes_read == 4 * sub_bytes
    assert store.stats.passes == 4


def test_fused_compress_reads_subspace_exactly_once():
    """Restart compression must read the subspace EXACTLY once regardless
    of k_keep (the pre-fusion path paid one full pass per output block)."""
    n, b, nb = 512, 4, 8
    sub_bytes = n * b * 4 * nb
    for k_blocks in (2, 4, 6):
        q = jnp.asarray(np.random.default_rng(2)
                        .standard_normal((nb * b, k_blocks * b)), jnp.float32)
        store = TieredStore()
        mv = _demoted_mv(store, n, b, nb)
        store.reset_stats()
        mv.compress(q, [b] * k_blocks, fused=True)
        assert store.stats.host_bytes_read == sub_bytes, k_blocks
        assert store.stats.passes == 1

        store = TieredStore()
        mv = _demoted_mv(store, n, b, nb)
        store.reset_stats()
        mv.compress(q, [b] * k_blocks, fused=False)
        assert store.stats.host_bytes_read == k_blocks * sub_bytes


def test_multi_consumer_pass_shares_one_read():
    """N consumers on one pass cost one streamed read, not N."""
    n, b, nb = 512, 4, 6
    sub_bytes = n * b * 4 * nb
    rng = np.random.default_rng(5)
    store = TieredStore()
    mv = _demoted_mv(store, n, b, nb, seed=5)
    dense = np.asarray(mv.to_dense())
    other = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    small = jnp.asarray(rng.standard_normal((nb * b, 2)), jnp.float32)
    store.reset_stats()
    p = SubspacePass(mv)
    hg = p.add_gram(other)
    hm = p.add_matmul(small)
    hn = p.add_norm()
    p.run()
    assert store.stats.host_bytes_read == sub_bytes
    assert store.stats.passes == 1
    np.testing.assert_allclose(np.asarray(hg.value), dense.T @ other,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hm.value[0]),
                               dense @ np.asarray(small),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hn.value),
                               np.linalg.norm(dense, axis=0), rtol=1e-5)


def test_handle_before_run_raises():
    store = TieredStore()
    mv = _demoted_mv(store, n=128, b=2, nb=2)
    p = SubspacePass(mv)
    h = p.add_norm()
    with pytest.raises(RuntimeError, match="before run"):
        h.value


def test_pass_is_single_use():
    """Consumers accumulate across visits; a silent re-run would double
    every result. The second run must be loud."""
    store = TieredStore()
    mv = _demoted_mv(store, n=128, b=2, nb=2)
    p = SubspacePass(mv)
    p.add_norm()
    p.run()
    with pytest.raises(RuntimeError, match="already ran"):
        p.run()


def test_compress_acc_budget_chunks_passes():
    """A pass_acc_bytes smaller than k_keep·n·4 must chunk the fused
    compress into multiple passes (bounded device accumulators at
    billion-row scale) without changing the result — and each output
    column still rides exactly one of the passes."""
    n, b, nb = 256, 4, 6
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((nb * b, 12)), jnp.float32)
    store = TieredStore()
    mv = _demoted_mv(store, n, b, nb, seed=13)
    one_pass = np.asarray(mv.compress(q, [4, 4, 4]).to_dense())
    store.reset_stats()
    # budget fits one 4-wide accumulator (n*4*4 bytes) → 3 passes
    chunked = mv.compress(q, [4, 4, 4], pass_acc_bytes=n * 4 * 4)
    assert store.stats.passes == 3
    np.testing.assert_array_equal(np.asarray(chunked.to_dense()), one_pass)


# ------------------------------------------------------- readahead routing
def test_small_reductions_announce_full_pass(monkeypatch):
    """mv_dot / mv_norm / clone_view / mv_add_mv used to stream with no
    prefetch at all; through the pass engine every walk announces its full
    block list up front."""
    n, b, nb = 256, 2, 4
    store = TieredStore()
    mv = _demoted_mv(store, n, b, nb, seed=6)
    mv2 = _demoted_mv(store, n, b, nb, seed=7)
    calls = []
    orig = store.prefetch
    monkeypatch.setattr(store, "prefetch",
                        lambda names: (calls.append(list(names)),
                                       orig(names))[1])
    for op in (mv.mv_norm, lambda: mv.mv_dot(mv2),
               lambda: mv.clone_view([0, 3]),
               lambda: mv.mv_add_mv(1.0, mv2, 2.0),
               lambda: mv.mv_scale_diag(jnp.ones(nb * b, jnp.float32))):
        calls.clear()
        op()
        # first announcement covers the whole pass
        assert calls and set(calls[0]) >= set(mv.block_names())


def test_mv_scale_diag_single_pass():
    """MvScale2 through the pass engine: one announced streamed pass, the
    whole subspace read exactly once, blocks scaled in place (previously a
    bare get/put loop with no prefetch announcement)."""
    n, b, nb = 256, 2, 4
    store = TieredStore()
    mv = _demoted_mv(store, n, b, nb, seed=10)
    dense = np.asarray(mv.to_dense())
    vec = jnp.asarray(np.random.default_rng(10).standard_normal(nb * b),
                      jnp.float32)
    store.reset_stats()
    mv.mv_scale_diag(vec)
    assert store.stats.passes == 1
    assert store.stats.pass_bytes_read == n * b * 4 * nb
    np.testing.assert_allclose(np.asarray(mv.to_dense()),
                               dense * np.asarray(vec)[None, :],
                               rtol=1e-6, atol=1e-6)


def test_mv_dot_add_mv_still_correct():
    store = TieredStore()
    mv = _demoted_mv(store, n=256, b=2, nb=4, seed=8)
    mv2 = _demoted_mv(store, n=256, b=2, nb=4, seed=9)
    d1, d2 = np.asarray(mv.to_dense()), np.asarray(mv2.to_dense())
    np.testing.assert_allclose(np.asarray(mv.mv_dot(mv2)),
                               np.sum(d1 * d2, axis=0), rtol=1e-4, atol=1e-5)
    out = mv.mv_add_mv(0.5, mv2, -2.0)
    np.testing.assert_allclose(np.asarray(out.to_dense()),
                               0.5 * d1 - 2.0 * d2, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- micro-perf
def test_device_byte_counter_tracks_scan():
    """The running device-byte counter (replacing per-eviction full scans)
    must agree with a fresh scan through put/promote/demote/delete/
    overwrite churn."""
    store = TieredStore(device_budget_bytes=256 * 4 * 6)
    rng = np.random.default_rng(11)

    def scan():
        from repro.core.tiered import DEVICE
        return sum(e.nbytes for e in store._entries.values()
                   if e.tier == DEVICE)

    for i in range(8):
        store.put(f"x{i}", jnp.asarray(rng.standard_normal((256, 2)),
                                       jnp.float32))
        assert store.device_bytes() == scan()
    store.put("x3", jnp.asarray(rng.standard_normal((256, 4)), jnp.float32))
    assert store.device_bytes() == scan()
    store.demote("x3")
    store.promote("x5")
    store.delete("x6")
    store.put("y", jnp.ones((256, 1)), tier="host")
    assert store.device_bytes() == scan()
    # budget respected (nothing pinned here)
    assert store.device_bytes() <= 256 * 4 * 6
    # overwrite while near budget: eviction must not demote the stale
    # entry being replaced nor double-release it from the counter
    store.put("x7", jnp.asarray(rng.standard_normal((256, 4)), jnp.float32))
    assert store.device_bytes() == scan()
    assert store.device_bytes() <= 256 * 4 * 6


def test_passes_counter_in_stats_dict():
    store = TieredStore()
    mv = _demoted_mv(store, n=128, b=2, nb=3)
    store.reset_stats()
    mv.mv_norm()
    d = store.stats.as_dict()
    assert d["passes"] == 1
    assert d["bytes_per_pass"] == 128 * 2 * 4 * 3
