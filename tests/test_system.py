"""End-to-end behaviour tests for the paper's system.

The headline claims, validated at CPU scale:
  1. the out-of-core (tiered) eigensolver returns the same spectrum as an
     in-memory solve (scipy oracle) — §4.3;
  2. the tier traffic is read-dominated (Table 3: 145 TB read / 4 TB
     written) thanks to recent-block caching + lazy scale + restart
     compression;
  3. the solver runs under a device-memory budget a fraction of the
     subspace size (the paper's 120 GB for a 3.4 B-vertex problem);
  4. training/serving substrate: loss goes down; restart-from-checkpoint
     reproduces the uninterrupted run exactly (bitwise state).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro import configs
from repro.core import GraphOperator, TieredStore, eigsh
from repro.graphs import pack_tiles


def test_out_of_core_matches_in_memory(small_graph):
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    # in-memory: generous budget. out-of-core: budget below subspace size.
    res_im = eigsh(GraphOperator(tm, impl="ref"), 6, block_size=2,
                   tol=1e-6, max_restarts=200, impl="ref", seed=0)
    subspace_bytes = tm.shape[0] * 4 * 12
    store = TieredStore(device_budget_bytes=subspace_bytes // 4)
    res_oc = eigsh(GraphOperator(tm, store=store, impl="ref"), 6,
                   block_size=2, tol=1e-6, max_restarts=200, store=store,
                   impl="ref", seed=0)
    np.testing.assert_allclose(np.sort(res_im.eigenvalues),
                               np.sort(res_oc.eigenvalues),
                               rtol=1e-5, atol=1e-5)
    w_sc = spla.eigsh(a, k=6, which="LM", return_eigenvectors=False)
    np.testing.assert_allclose(np.sort(res_oc.eigenvalues), np.sort(w_sc),
                               rtol=1e-4, atol=1e-4)
    # budget respected
    assert store.device_bytes() <= subspace_bytes // 4 + tm.shape[0] * 4 * 2


def test_io_read_write_ratio_matches_paper(small_graph):
    """Table 3's shape: writes are a small fraction of reads."""
    n, r, c, v, a = small_graph
    tm = pack_tiles(n, n, r, c, v, block_shape=(64, 64), min_block_nnz=4)
    store = TieredStore()
    eigsh(GraphOperator(tm, store=store, impl="ref"), 8, block_size=4,
          tol=1e-6, max_restarts=100, store=store, impl="ref")
    s = store.stats
    write_frac = s.host_bytes_written / max(s.host_bytes_read, 1)
    assert write_frac < 0.1          # paper: 4/145 ≈ 2.8 %


def test_training_loss_decreases(tmp_path):
    from repro.data.pipeline import DataConfig
    from repro.train.trainer import TrainConfig, train
    cfg = configs.reduced("qwen2-1.5b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    tcfg = TrainConfig(steps=30, ckpt_every=100, ckpt_dir=str(tmp_path),
                       peak_lr=3e-3, warmup=5, log_every=1000)
    s = train(cfg, tcfg, dcfg, log=lambda *_: None)
    assert s["final_loss"] < s["first_loss"] - 0.3


def test_restart_is_bitwise_identical(tmp_path):
    """Fault tolerance: [train 6] == [train 3, crash, restore, train 3]."""
    from repro.data.pipeline import DataConfig
    from repro.train.trainer import TrainConfig, train
    from repro.ckpt import checkpoint as ck
    from repro.models import steps as S
    cfg = configs.reduced("mamba2-780m")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    a_dir, b_dir = str(tmp_path / "a"), str(tmp_path / "b")
    train(cfg, TrainConfig(steps=6, ckpt_every=100, ckpt_dir=a_dir,
                           log_every=1000), dcfg, log=lambda *_: None)
    train(cfg, TrainConfig(steps=3, ckpt_every=100, ckpt_dir=b_dir,
                           log_every=1000), dcfg, log=lambda *_: None)
    train(cfg, TrainConfig(steps=6, ckpt_every=100, ckpt_dir=b_dir,
                           log_every=1000), dcfg, log=lambda *_: None)
    sa, sb = ck.latest_step(a_dir), ck.latest_step(b_dir)
    params, opt = S.init_all(jax.random.PRNGKey(0), cfg)
    ta, _ = ck.restore(a_dir, sa, (params, opt))
    tb, _ = ck.restore(b_dir, sb, (params, opt))
    for la, lb in zip(jax.tree_util.tree_leaves(ta),
                      jax.tree_util.tree_leaves(tb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_spectral_embedding_clusters_planted_partition():
    """The paper's application: spectral clustering [17,22]. A 3-block
    planted partition must be recovered from the top eigenvectors."""
    rng = np.random.default_rng(0)
    n, k = 600, 3
    sizes = [200, 200, 200]
    labels = np.repeat(np.arange(k), sizes)
    rows, cols = [], []
    for i in range(n):
        for _ in range(8):
            j = int(rng.integers(0, n))
            p = 0.9 if labels[i] == labels[j] else 0.02
            if rng.random() < p and i != j:
                rows.append(i)
                cols.append(j)
    r = np.array(rows + cols, np.int32)
    c = np.array(cols + rows, np.int32)
    v = np.ones(r.size, np.float32)
    from repro.graphs import normalized_adjacency
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    r, c, v = r[idx], c[idx], v[idx]
    r2, c2, v2 = normalized_adjacency(n, r, c, v)
    tm = pack_tiles(n, n, r2, c2, v2, block_shape=(32, 32), min_block_nnz=2)
    res = eigsh(GraphOperator(tm, impl="ref"), k, block_size=3,
                tol=1e-6, max_restarts=200, which="LA", impl="ref")
    emb = np.array(res.eigenvectors[:n])
    emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-12
    # simple k-means on the sphere
    cents = emb[[50, 250, 450]]
    for _ in range(20):
        assign = np.argmax(emb @ cents.T, axis=1)
        cents = np.stack([emb[assign == i].mean(0) if (assign == i).any()
                          else cents[i] for i in range(k)])
        cents /= np.linalg.norm(cents, axis=1, keepdims=True) + 1e-12
    purity = 0
    for i in range(k):
        if (assign == i).sum():
            purity += np.bincount(labels[assign == i]).max()
    assert purity / n > 0.9
