"""Sparse format tests: SCSR+COO codec fidelity + block packer properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graphs import pack_tiles, scsr_encode_tile, scsr_decode_tile
from repro.graphs.tiles import scsr_tile_nbytes, csr_nbytes
from repro.graphs.synth import to_dense, rmat_graph


@st.composite
def tile_entries(draw):
    tm = draw(st.integers(8, 200))
    tn = draw(st.integers(8, 200))
    n = draw(st.integers(0, 300))
    rows = draw(st.lists(st.integers(0, tm - 1), min_size=n, max_size=n))
    cols = draw(st.lists(st.integers(0, tn - 1), min_size=n, max_size=n))
    return tm, tn, np.array(rows, np.int64), np.array(cols, np.int64)


@given(tile_entries())
@settings(max_examples=60, deadline=None)
def test_scsr_roundtrip(entries):
    tm, tn, rows, cols = entries
    # dedup (format stores a set of coordinates)
    key = rows * tn + cols
    _, idx = np.unique(key, return_index=True)
    rows, cols = rows[idx], cols[idx]
    buf = scsr_encode_tile(rows, cols, (tm, tn))
    dr, dc = scsr_decode_tile(buf)
    assert set(zip(dr.tolist(), dc.tolist())) == \
        set(zip(rows.tolist(), cols.tolist()))


def test_scsr_beats_csr_on_sparse_graphs():
    """Paper §3.3.1: hybrid format is smaller than 8-byte-index CSR."""
    r, c, _ = rmat_graph(2000, 12000, seed=1, symmetric=True)
    scsr = scsr_tile_nbytes(r)
    csr = csr_nbytes(r, 2000)
    assert scsr < csr / 3


def test_scsr_max_tile_guard():
    with pytest.raises(ValueError):
        scsr_encode_tile(np.array([0]), np.array([0]), (40000, 100))


@given(st.integers(50, 400), st.integers(100, 2000),
       st.sampled_from([8, 16, 32]), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_pack_tiles_dense_equivalence(n, nnz, bs, min_nnz):
    r, c, v = rmat_graph(n, nnz, seed=n + nnz, symmetric=False)
    tm = pack_tiles(n, n, r, c, v, block_shape=(bs, bs),
                    min_block_nnz=min_nnz)
    dense = np.zeros(tm.shape, np.float32)
    dense[:n, :n] = to_dense(n, r, c, v)
    np.testing.assert_allclose(tm.to_dense(), dense, rtol=1e-6, atol=1e-6)
    # block rows CSR must be consistent
    assert tm.row_ptr[-1] == tm.nblocks
    assert (np.diff(tm.row_ptr) >= 0).all()
    # hybrid split preserves nnz
    assert tm.nnz == len(np.unique(r.astype(np.int64) * n + c))


def test_pack_respects_min_block_nnz():
    r, c, v = rmat_graph(500, 3000, seed=3, symmetric=True)
    t_all = pack_tiles(500, 500, r, c, v, block_shape=(16, 16),
                       min_block_nnz=1)
    t_hyb = pack_tiles(500, 500, r, c, v, block_shape=(16, 16),
                       min_block_nnz=4)
    assert t_hyb.nblocks < t_all.nblocks
    assert t_hyb.coo_vals.size > 0
    # image bytes shrink when sparse blocks go to COO
    assert t_hyb.nbytes_image() < t_all.nbytes_image()
